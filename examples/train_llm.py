"""End-to-end driver: train a ~20M-param dense transformer (qwen3-family
scaled down) for a few hundred steps on CPU, with the paper's technique at
the gradient-aggregation layer: per-shard gradients are LDGM-coded, a
Bernoulli straggler mask erases workers each step, and the master
peel-decodes (unresolved shards zero-filled — Lemma 1's unbiased scaled
estimate).

  PYTHONPATH=src python examples/train_llm.py             # 200 steps (default)
  PYTHONPATH=src python examples/train_llm.py --steps 50  # shorter smoke
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.data.batches import make_batch
from repro.models import Model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--straggler-q0", type=float, default=0.1)
    ap.add_argument("--no-coded", action="store_true")
    args = ap.parse_args(argv)

    # a ~20M-param member of the qwen3 family (qk_norm GQA + swiglu)
    cfg = dataclasses.replace(
        get_config("qwen3-1.7b"),
        n_layers=args.layers, d_model=args.d_model, n_heads=6, n_kv_heads=2,
        head_dim=64, d_ff=4 * args.d_model, vocab=8192, dtype="float32",
    )
    model = Model(cfg, remat=False, attn_chunk=min(128, args.seq))
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {model.param_count(params):,} params "
          f"({cfg.n_layers}L d={cfg.d_model})")

    tcfg = TrainerConfig(
        steps=args.steps, log_every=max(1, args.steps // 20),
        opt=AdamWConfig(lr=3e-4, weight_decay=0.01),
        coded_agg=not args.no_coded, n_shards=min(8, args.batch), redundancy=0.5,
        straggler_q0=args.straggler_q0, decode_iters=8,
    )
    trainer = Trainer(model, tcfg)
    if trainer.agg:
        print(f"coded aggregation: {trainer.agg.n_shards} shards + "
              f"{trainer.agg.code.p} parity workers, Bernoulli({args.straggler_q0})")

    # Zipf-ish synthetic token stream (uniform tokens would already sit at
    # the ln(V) entropy floor — nothing to learn)
    from repro.data import token_batches
    batches = token_batches(cfg.vocab, args.batch, args.seq, seed=7)
    params, _, history = trainer.fit(params, batches)
    print(f"loss: {history[0]:.3f} -> {history[-1]:.3f} "
          f"over {len(history)} steps")
    assert history[-1] < history[0], "training failed to reduce loss"


if __name__ == "__main__":
    main()
