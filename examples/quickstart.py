"""Quickstart: LDPC moment-encoded gradient descent (paper Scheme 2) vs the
uncoded baseline, on a 40-worker simulated cluster with stragglers.

  PYTHONPATH=src python examples/quickstart.py [backend]

``backend`` (optional: auto | dense | sparse | pallas) selects the LDPC
decode implementation — see repro/core/decoder.py for the matrix.
"""
import sys

import jax
import jax.numpy as jnp

from repro.core import (
    FixedCountStragglers,
    Scheme2Blocked,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.core.schemes import Uncoded
from repro.data import make_linear_problem


def main(backend: str = "auto"):
    # least squares: m = 2048 samples, k = 400 features, w = 40 workers,
    # 10 stragglers per step — the paper's Fig. 1 setting.
    prob = make_linear_problem(m=2048, k=400, seed=0)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=0)  # the paper's (40, 20) code
    from repro.core.decoder import resolve_backend
    print(f"LDPC code: N={code.N} K={code.K} rate={code.rate} "
          f"(l={code.l}, r={code.r}); decode backend "
          f"{backend} -> {resolve_backend(backend, code)}")

    ldpc = Scheme2Blocked.build(code, mom, lr=prob.lr, decode_iters=12,
                                decode_backend=backend)
    uncoded = Uncoded(prob.X, prob.y, w=40, lr=prob.lr)

    model = FixedCountStragglers(10)  # wait for the fastest 30 of 40
    for name, scheme in [("ldpc-moment", ldpc), ("uncoded", uncoded)]:
        res = run_pgd(scheme, jnp.zeros(400), model, steps=60,
                      theta_star=prob.theta_star, key=jax.random.PRNGKey(1))
        errs = res.errors / jnp.linalg.norm(prob.theta_star)
        marks = [0, 5, 10, 20, 40, 59]
        curve = "  ".join(f"t={t}: {float(errs[t]):.2e}" for t in marks)
        print(f"{name:12s} {curve}")
    print("LDPC moment encoding converges in fewer steps under the same "
          "straggler budget — the paper's headline result.")


if __name__ == "__main__":
    main(*sys.argv[1:2])
