"""Continuous-admission coded-query serving demo: a mixed stream of light
and heavy straggler queries through ``CodedQueryBatcher`` in both admission
modes, with per-query rounds/launch accounting printed side by side.

Light queries (few stragglers) converge in 1-2 peeling rounds and stream
through their slots; heavy queries (near-threshold erasure rates) pin a
slot across several chunked launches.  Lockstep waves make every query pay
the worst-case round budget; continuous admission retires and refills slots
independently.

  PYTHONPATH=src python examples/serve_coded_continuous.py
"""
import numpy as np

from repro.core import Scheme2, make_regular_ldpc, second_moment
from repro.data import make_linear_problem
from repro.serving import CodedQuery, CodedQueryBatcher

K, N_QUERIES, HEAVY_EVERY = 60, 12, 4


def make_queries(code, rng):
    out = []
    for i in range(N_QUERIES):
        heavy = i % HEAVY_EVERY == 0
        q = 0.42 if heavy else 0.08
        out.append(CodedQuery(i, rng.standard_normal(K).astype(np.float32),
                              rng.random(code.N) < q))
    return out


def main():
    prob = make_linear_problem(m=256, k=K, seed=0)
    code = make_regular_ldpc(K, l=3, r=6, seed=0)
    scheme = Scheme2.build(code, second_moment(prob.X, prob.y), lr=prob.lr,
                           decode_iters=16, decode_backend="sparse")
    for mode, kw in (("lockstep", {}), ("continuous",
                                        {"rounds_per_launch": 2})):
        bat = CodedQueryBatcher(scheme, n_slots=4, mode=mode, **kw)
        # same seed per mode: both policies serve the identical stream
        for q in make_queries(code, np.random.default_rng(0)):
            bat.submit(q)
        done = bat.run()
        total_rounds = sum(q.rounds for q in done)
        print(f"\n== {mode}: {len(done)} queries, {bat.launches} launches, "
              f"{total_rounds} slot-rounds ==")
        for q in sorted(done, key=lambda q: q.qid):
            kind = "heavy" if q.qid % HEAVY_EVERY == 0 else "light"
            print(f"  q{q.qid:02d} {kind}: rounds={q.rounds:2d} "
                  f"launches={q.launches}  admitted@{q.admitted_launch} "
                  f"finished@{q.finished_launch}  unresolved={q.unresolved}")


if __name__ == "__main__":
    main()
