"""Serving example: batched prefill + token-by-token decode across four
mixer families (GQA, MLA-absorbed, Mamba-hybrid, RWKV) on CPU-reduced
configs — the same code paths the decode_32k / long_500k dry-run shapes
lower at production scale.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.batches import make_batch
from repro.models import Model


def demo(name: str, gen: int = 12, batch: int = 2, prompt: int = 12):
    cfg = get_config(name).reduced()
    model = Model(cfg, remat=False, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    offset = cfg.n_patches if cfg.family == "vlm" else 0
    b = make_batch(cfg, batch, prompt + offset)
    cache = model.init_cache(batch, offset + prompt + gen)
    logits, cache = jax.jit(model.prefill)(params, b, cache)
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    t0 = time.time()
    toks = [int(tok[0, 0])]
    pos0 = offset + b["tokens"].shape[1]
    for i in range(gen - 1):
        logits, cache = step(params, tok, jnp.int32(pos0 + i), cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        toks.append(int(tok[0, 0]))
    dt = time.time() - t0
    print(f"{name:24s} [{cfg.family:6s}] {gen * batch / max(dt, 1e-9):7.1f} tok/s"
          f"  ids={toks[:8]}")


def main():
    for name in ("qwen3-1.7b", "deepseek-v2-236b", "jamba-1.5-large-398b",
                 "rwkv6-3b"):
        demo(name)


if __name__ == "__main__":
    main()
