"""Sparse recovery (paper Figs. 2-3): iterative hard thresholding with
LDPC moment-encoded gradients, in both the overdetermined and the
underdetermined regime.

  PYTHONPATH=src python examples/sparse_recovery.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    BernoulliStragglers,
    Scheme2Blocked,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.data import make_sparse_problem
from repro.optim import projections


def recover(m, k, u, q0, steps=400):
    prob = make_sparse_problem(m=m, k=k, u=u, seed=0)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    scheme = Scheme2Blocked.build(code, mom, lr=prob.lr, decode_iters=12,
                                  projection=projections.hard_threshold(u))
    res = run_pgd(scheme, jnp.zeros(k), BernoulliStragglers(q0), steps,
                  theta_star=prob.theta_star, key=jax.random.PRNGKey(0))
    rel = float(res.errors[-1] / jnp.linalg.norm(prob.theta_star))
    # support recovery
    got = set(map(int, jnp.nonzero(res.theta)[0].tolist()))
    true = set(map(int, jnp.nonzero(prob.theta_star)[0].tolist()))
    return rel, len(got & true), u


def main():
    print("overdetermined (m=2048 > k=800), u = 80, Bernoulli(0.15) stragglers")
    rel, hits, u = recover(2048, 800, 80, 0.15)
    print(f"  rel err {rel:.2e}; support recovered {hits}/{u}")

    print("underdetermined (m=1024 < k=2000), u = 100 — IHT regime")
    rel, hits, u = recover(1024, 2000, 100, 0.15, steps=800)
    print(f"  rel err {rel:.2e}; support recovered {hits}/{u}")


if __name__ == "__main__":
    main()
