"""Distributed moment-encoded GD demo: master/worker over a device mesh,
with online straggler telemetry driving wait-for thresholds and decode
budgets.

Runs on whatever devices the process has (fake a worker mesh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``).  Three acts:

  1. parity — the distributed trajectory is bit-identical to the
     single-device Scheme2 under the same per-worker erasures;
  2. telemetry vs fixed budget — a calm→storm→calm straggler climate; the
     EMA estimator's budgets track it, the adaptive decode's rounds stay
     far under the fixed worst-case budget;
  3. wait-for-fastest — shifted-exponential worker latencies
     (``DelayModel``); the master waits for the telemetry-chosen fastest
     ``wait_for`` workers and the simulated step time follows.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python examples/distributed_coded_gd.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BernoulliStragglers,
    DelayModel,
    Scheme2,
    make_regular_ldpc,
    second_moment,
)
from repro.data import make_linear_problem
from repro.distributed import (
    DistributedCodedGD,
    StragglerRateEstimator,
    WorkerTopology,
    WorkerStragglers,
    make_worker_mesh,
)

K, W, MAX_ROUNDS = 128, 8, 32


def main():
    code = make_regular_ldpc(K, l=3, r=6, seed=0)
    prob = make_linear_problem(m=4 * K, k=K, seed=0)
    mom = second_moment(prob.X, prob.y)
    topo = WorkerTopology(W, code.N)
    mesh = make_worker_mesh()
    print(f"mesh: {mesh.devices.size} device(s), {W} logical workers, "
          f"N={code.N} encoded rows ({topo.rows_per_worker}/worker)")

    # --- 1. parity with the single-device Scheme2 -------------------------
    scheme = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                           decode_backend="sparse")
    dist = DistributedCodedGD(scheme, topo, mesh)
    stragglers = WorkerStragglers(BernoulliStragglers(0.2), topo)
    ref_step = jax.jit(scheme.step)
    th_ref = th_dist = jnp.zeros(K)
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    for t in range(8):
        wm = stragglers.sample_workers(keys[t])
        th_ref, _ = ref_step(th_ref, topo.to_symbol_erasure(wm))
        th_dist, _, _, _ = dist.step(th_dist, wm)
    exact = bool((np.asarray(th_ref) == np.asarray(th_dist)).all())
    print(f"\n== parity: 8 steps, worker-granular erasures -> "
          f"bit-identical iterates: {exact} ==")

    # --- 2. telemetry budgets through a shifting climate ------------------
    scheme32 = Scheme2.build(code, mom, lr=prob.lr, decode_iters=MAX_ROUNDS,
                             decode_backend="sparse")
    dist_tel = DistributedCodedGD(scheme32, topo, mesh,
                                  budget_mode="telemetry",
                                  estimator=StragglerRateEstimator(decay=0.8),
                                  max_rounds=MAX_ROUNDS)
    phases = (("calm", 0.05, 10), ("storm", 0.3, 10), ("calm", 0.08, 10))
    th = jnp.zeros(K)
    key = jax.random.PRNGKey(1)
    print(f"\n== telemetry budgets (fixed worst case = {MAX_ROUNDS} "
          "rounds/step) ==")
    for name, q, steps in phases:
        key, sub = jax.random.split(key)
        model = WorkerStragglers(BernoulliStragglers(q), topo)
        rounds, budgets = [], []
        for k_t in jax.random.split(sub, steps):
            th, _, spent, budget = dist_tel.step(
                th, model.sample_workers(k_t))
            rounds.append(spent)
            budgets.append(budget)
        print(f"  {name:6s} q={q:.2f}: q_hat={dist_tel.estimator.rate:.3f} "
              f"mean_budget={np.mean(budgets):4.1f} "
              f"mean_rounds={np.mean(rounds):4.1f}")

    # --- 3. wait-for-fastest under a latency model ------------------------
    dist_dm = DistributedCodedGD(scheme32, topo, mesh,
                                 budget_mode="telemetry",
                                 max_rounds=MAX_ROUNDS)
    res = dist_dm.run(jnp.zeros(K), None, 12, key=jax.random.PRNGKey(2),
                      theta_star=prob.theta_star,
                      delay_model=DelayModel(tau=1.0, mu=2.0))
    print("\n== wait-for-fastest (shifted-exponential delays) ==")
    print(f"  wait_for per step: {res.wait_for.tolist()} (of {W})")
    print(f"  simulated step times: {np.round(res.step_times, 2).tolist()}")
    print(f"  error ||theta-theta*||: {res.errors[0]:.3f} -> "
          f"{res.errors[-1]:.3f}")


if __name__ == "__main__":
    main()
