"""Batched-request serving driver: N requests with different prompt lengths
and budgets scheduled through the wave batcher over a reduced zoo model.

  PYTHONPATH=src python examples/serve_batched.py [--arch qwen3-1.7b]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving.batcher import Request, WaveBatcher


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, remat=False, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    batcher = WaveBatcher(model, params, n_slots=args.slots, max_len=48)

    rng = np.random.default_rng(0)
    total_new = 0
    for i in range(args.requests):
        plen = int(rng.integers(2, 9))
        max_new = int(rng.integers(4, 12))
        total_new += max_new
        batcher.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab, size=plen).tolist(),
            max_new=max_new))

    t0 = time.time()
    done = batcher.run()
    dt = time.time() - t0
    produced = sum(len(r.out) for r in done)
    print(f"{args.arch}: served {len(done)} requests / {produced} tokens in "
          f"{dt:.2f}s over {batcher.ticks} ticks "
          f"({produced / max(dt, 1e-9):.1f} tok/s, {args.slots} slots)")
    for r in sorted(done, key=lambda r: r.rid)[:3]:
        print(f"  rid={r.rid} prompt_len={len(r.prompt)} out={r.out}")


if __name__ == "__main__":
    main()
