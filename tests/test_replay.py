"""Pattern-compiled peeling: symbolic schedule solve, numeric replay
(bit-identity with the flooding backends across all four decode entry
points), the cross-step schedule cache, engine/serving/distributed
dispatch, and the fused replay kernel.

The acceptance-scale bit-identity runs at N = 8192 on a parity-only code
(the decode trajectory depends only on H and the mask, so no generator is
ever needed); structural and error-path tests use a small code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PeelSchedule,
    ScheduleCache,
    Scheme2,
    compile_peel_schedule,
    erasure_mask_key,
    make_regular_ldpc,
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    second_moment,
)
from repro.core.engine import CodedComputeEngine
from repro.core.ldpc import make_parity_only_ldpc
from repro.obs import metrics as obs_metrics

SMALL = make_regular_ldpc(48, l=3, r=6, seed=0)
BIG_N = 8192
BIG = make_parity_only_ldpc(BIG_N // 2, l=3, r=6, seed=0)


def _mask(code, q=0.25, seed=0):
    rng = np.random.default_rng(seed)
    return rng.random(code.N) < q


def _payload(code, seed=0, V=None):
    rng = np.random.default_rng(1000 + seed)
    shape = (code.N,) if V is None else (code.N, V)
    return rng.standard_normal(shape).astype(np.float32)


def _rx(vals, erased):
    v = np.asarray(vals)
    e = np.asarray(erased, bool)
    return np.where(e if v.ndim == e.ndim else e[..., None], 0.0, v)


# -------------------------------------------------------- schedule solve


def test_schedule_structure_and_prefix_property():
    erased = _mask(SMALL, q=0.3, seed=3)
    sched = compile_peel_schedule(SMALL, erased)
    assert isinstance(sched, PeelSchedule)
    assert sched.N == SMALL.N
    assert sched.n_erased == int(erased.sum())
    assert sched.n_resolved == sched.target.size
    # offsets delimit per-round segments: strictly growing, ending at the
    # resolved count (a round that resolves nothing ends the decode)
    off = np.asarray(sched.offsets)
    assert off[0] == 0 and off[-1] == sched.n_resolved
    assert (np.diff(off) > 0).all()
    assert sched.n_rounds == len(off) - 1
    # every resolved variable was erased, and is resolved exactly once
    assert len(set(sched.target.tolist())) == sched.n_resolved
    assert all(erased[t] for t in sched.target)
    assert sched.fully_resolved == (sched.n_resolved == sched.n_erased)
    assert sched.mask_key == erasure_mask_key(erased)
    # prefix property: a budget-D flooding decode resolves exactly the
    # first D rounds' segments
    for D in range(sched.n_rounds + 1):
        dec = peel_decode(SMALL, _payload(SMALL), erased, D,
                          backend="sparse")
        expect = set(sched.target[: int(off[min(D, sched.n_rounds)])])
        got = set(np.flatnonzero(erased & ~np.asarray(dec.erased)))
        assert got == expect, f"round budget {D}"


def test_schedule_is_value_independent():
    erased = _mask(SMALL, q=0.3, seed=4)
    a = compile_peel_schedule(SMALL, erased)
    b = compile_peel_schedule(SMALL, erased)
    np.testing.assert_array_equal(a.target, b.target)
    np.testing.assert_array_equal(a.offsets, b.offsets)
    np.testing.assert_array_equal(a.w_hi, b.w_hi)


def test_compile_schedule_errors():
    with pytest.raises(ValueError, match="LDPCCode"):
        compile_peel_schedule((jnp.zeros((8, 16)), jnp.zeros((8, 16))),
                              np.zeros(16, bool))
    with pytest.raises(ValueError, match="erased must be"):
        compile_peel_schedule(SMALL, np.zeros(SMALL.N + 1, bool))

    def traced(e):
        return compile_peel_schedule(SMALL, e).n_rounds

    with pytest.raises(ValueError, match="CONCRETE erasure mask"):
        jax.jit(traced)(jnp.zeros(SMALL.N, bool))


def test_stale_schedule_fingerprint_rejected():
    e1, e2 = _mask(SMALL, seed=5), _mask(SMALL, seed=6)
    sched = compile_peel_schedule(SMALL, e1)
    with pytest.raises(ValueError, match="does not match the erasure mask"):
        peel_decode(SMALL, _payload(SMALL), e2, 8, backend="replay",
                    schedule=sched)
    other = make_regular_ldpc(24, l=3, r=6, seed=1)
    with pytest.raises(ValueError, match="solved for N"):
        peel_decode(other, _payload(other), _mask(other), 8,
                    backend="replay", schedule=sched)
    with pytest.raises(ValueError, match="only meaningful"):
        peel_decode(SMALL, _payload(SMALL), e1, 8, backend="sparse",
                    schedule=sched)


# ------------------------- bit-identity at N=8192, all four entry points


def test_replay_bit_identical_single_fixed_large():
    erased = _mask(BIG, seed=10)
    rx = _rx(_payload(BIG, seed=10), erased)
    ref = peel_decode(BIG, rx, erased, 8, backend="sparse")
    got = peel_decode(BIG, rx, erased, 8, backend="replay")
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))


def test_replay_bit_identical_single_adaptive_large():
    erased = _mask(BIG, seed=11)
    rx = _rx(_payload(BIG, seed=11, V=2), erased)
    ref = peel_decode_adaptive(BIG, rx, erased, 32, backend="sparse")
    got = peel_decode_adaptive(BIG, rx, erased, 32, backend="replay")
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    assert int(got.rounds_used) == int(ref.rounds_used)


def test_replay_bit_identical_batch_fixed_large():
    B = 3
    erased = np.stack([_mask(BIG, seed=20 + b) for b in range(B)])
    rx = _rx(np.stack([_payload(BIG, seed=20 + b) for b in range(B)]),
             erased)
    ref = peel_decode_batch(BIG, rx, erased, 8, backend="sparse")
    got = peel_decode_batch(BIG, rx, erased, 8, backend="replay")
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))


def test_replay_bit_identical_batch_adaptive_large():
    B = 3
    erased = np.stack([_mask(BIG, seed=30 + b) for b in range(B)])
    rx = _rx(np.stack([_payload(BIG, seed=30 + b) for b in range(B)]),
             erased)
    budgets = jnp.asarray([32, 2, 7], jnp.int32)
    ref = peel_decode_batch_adaptive(BIG, rx, erased, backend="sparse",
                                     budgets=budgets)
    got = peel_decode_batch_adaptive(BIG, rx, erased, backend="replay",
                                     budgets=budgets)
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    np.testing.assert_array_equal(np.asarray(got.rounds_used),
                                  np.asarray(ref.rounds_used))


@pytest.mark.parametrize("D", [0, 1, 3, 8])
def test_replay_budget_prefix_matches_flooding(D):
    erased = _mask(SMALL, q=0.3, seed=40)
    rx = _rx(_payload(SMALL, seed=40), erased)
    ref = peel_decode(SMALL, rx, erased, D, backend="sparse")
    got = peel_decode(SMALL, rx, erased, D, backend="replay")
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))


def test_replay_under_jit_requires_schedules():
    erased = _mask(SMALL, seed=41)
    rx = _rx(_payload(SMALL, seed=41), erased)

    def dec(v, e):
        return peel_decode_batch(SMALL, v, e, 8, backend="replay").values

    with pytest.raises(ValueError, match="schedules= precompiled"):
        jax.jit(dec)(jnp.asarray(rx)[None], jnp.asarray(erased)[None])
    # with pre-solved schedules the same jitted program traces fine
    sched = compile_peel_schedule(SMALL, erased)
    out = jax.jit(lambda v, e: peel_decode_batch(
        SMALL, v, e, 8, backend="replay", schedules=(sched,)))(
        jnp.asarray(rx)[None], jnp.asarray(erased)[None])
    # batch replay follows the "lo" rule, so parity is against the batch
    # flooding executor.  Under the USER'S outer jit the closed-over
    # schedule operands are trace constants, so XLA's reciprocal fold may
    # cost the last ulp on resolved values (bit-exact when called eagerly
    # — the library's own jit keeps operands runtime); the erasure
    # trajectory is exact either way.
    ref = peel_decode_batch(SMALL, jnp.asarray(rx)[None],
                            jnp.asarray(erased)[None], 8, backend="sparse")
    np.testing.assert_array_equal(np.asarray(out.erased),
                                  np.asarray(ref.erased))
    np.testing.assert_allclose(np.asarray(out.values),
                               np.asarray(ref.values), rtol=1e-6)
    eager = peel_decode_batch(SMALL, jnp.asarray(rx)[None],
                              jnp.asarray(erased)[None], 8,
                              backend="replay", schedules=(sched,))
    np.testing.assert_array_equal(np.asarray(eager.values),
                                  np.asarray(ref.values))


# -------------------------------------------------------- schedule cache


def test_cache_hit_miss_lru_and_stats():
    cache = ScheduleCache(capacity=2)
    m1, m2, m3 = (_mask(SMALL, seed=s) for s in (50, 51, 52))
    s1 = cache.get(SMALL, m1)
    assert cache.get(SMALL, m1) is s1          # hit returns same object
    cache.get(SMALL, m2)
    assert (cache.hits, cache.misses) == (1, 2)
    cache.get(SMALL, m3)                       # evicts m1 (LRU)
    assert cache.evictions == 1 and len(cache) == 2
    s1b = cache.get(SMALL, m1)                 # re-solve after eviction
    assert s1b is not s1
    st = cache.stats()
    assert st["misses"] == 4 and st["size"] == 2 and st["capacity"] == 2
    assert st["hit_rate"] == pytest.approx(1 / 5)
    cache.clear()
    assert len(cache) == 0
    assert cache.stats()["misses"] == 4        # counters are lifetime


def test_cache_batch_and_validation():
    cache = ScheduleCache()
    masks = np.stack([_mask(SMALL, seed=s) for s in (60, 60, 61)])
    scheds = cache.get_batch(SMALL, masks)
    assert len(scheds) == 3 and scheds[0] is scheds[1]
    assert cache.misses == 2 and cache.hits == 1
    with pytest.raises(ValueError, match="must be >= 1"):
        ScheduleCache(capacity=0)
    with pytest.raises(ValueError, match="\\(B, N\\)"):
        cache.get_batch(SMALL, masks[0])
    with pytest.raises(ValueError, match="CONCRETE erasure mask"):
        jax.jit(lambda e: cache.get(SMALL, e) and e)(jnp.asarray(masks[0]))


def test_cache_distinct_codes_do_not_collide():
    other = make_regular_ldpc(48, l=3, r=6, seed=9)
    cache = ScheduleCache()
    m = _mask(SMALL, seed=70)
    sa = cache.get(SMALL, m)
    sb = cache.get(other, m)
    assert sa is not sb and cache.misses == 2


def test_cache_obs_counters():
    cache = ScheduleCache()
    m1, m2 = _mask(SMALL, seed=80), _mask(SMALL, seed=81)
    with obs_metrics.recording() as reg:
        cache.get(SMALL, m1)
        cache.get(SMALL, m1)
        cache.get(SMALL, m2)
        assert reg.counter("sched_cache.hit").value == 1
        assert reg.counter("sched_cache.miss").value == 2
        assert reg.gauge("sched_cache.hit_rate").value == pytest.approx(1 / 3)
        assert reg.histogram("sched_cache.solve_s").count == 2


# ------------------------------------------------------- engine dispatch


def _engines(cache=None):
    kw = dict(decode_iters=8)
    return (CodedComputeEngine(SMALL, backend="sparse", **kw),
            CodedComputeEngine(SMALL, backend="replay",
                               schedule_cache=cache, **kw))


def test_engine_replay_matches_sparse_and_uses_cache():
    cache = ScheduleCache()
    ref_eng, rep_eng = _engines(cache)
    erased = jnp.asarray(_mask(SMALL, seed=90))
    rx = jnp.asarray(_rx(_payload(SMALL, seed=90), erased))
    ref = ref_eng.decode(rx, erased)
    got = rep_eng.decode(rx, erased)
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    assert cache.misses == 1
    rep_eng.decode(rx, erased)
    assert cache.hits == 1
    assert rep_eng.debug_info()["schedule_cache_capacity"] == cache.capacity


def test_engine_replay_batch_adaptive_matches_sparse():
    cache = ScheduleCache()
    ref_eng, rep_eng = _engines(cache)
    B = 4
    erased = jnp.asarray(np.stack([_mask(SMALL, seed=100 + b)
                                   for b in range(B)]))
    rx = jnp.asarray(_rx(np.stack([_payload(SMALL, seed=100 + b)
                                   for b in range(B)]), erased))
    budgets = jnp.asarray([8, 1, 3, 8], jnp.int32)
    ref = ref_eng.decode_batch(rx, erased, adaptive=True, budgets=budgets)
    got = rep_eng.decode_batch(rx, erased, adaptive=True, budgets=budgets)
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    np.testing.assert_array_equal(np.asarray(got.rounds_used),
                                  np.asarray(ref.rounds_used))
    assert cache.misses == B


# ------------------------------------------------------ serving batcher


def test_serving_batcher_replay_matches_sparse():
    from repro.data import make_linear_problem
    from repro.serving import CodedQuery, CodedQueryBatcher

    prob = make_linear_problem(m=256, k=SMALL.K, seed=0)
    mom = second_moment(prob.X, prob.y)

    def scheme(backend):
        return Scheme2.build(SMALL, mom, lr=prob.lr, decode_iters=8,
                             decode_backend=backend)

    rng = np.random.default_rng(7)
    pats = rng.random((3, SMALL.N)) < 0.25      # recurring patterns
    queries = {}
    for backend in ("sparse", "replay"):
        queries[backend] = [
            CodedQuery(i, rng_theta, pats[i % 3])
            for i, rng_theta in enumerate(
                np.random.default_rng(8).standard_normal(
                    (9, SMALL.K)).astype(np.float32))]
        bat = CodedQueryBatcher(scheme(backend), n_slots=4,
                                rounds_per_launch=8)
        for q in queries[backend]:
            bat.submit(q)
        bat.run()
        if backend == "replay":
            # 3 recurring patterns -> 3 solves, plus at most one more for
            # the padding mask of the final partial launch; the rest of
            # the 9-query stream hits the cache
            st = bat.schedule_cache.stats()
            assert st["misses"] <= 4 and st["hits"] >= 5
            assert st["hit_rate"] > 0.5
    for qs, qr in zip(queries["sparse"], queries["replay"]):
        assert qr.unresolved == qs.unresolved
        np.testing.assert_array_equal(np.asarray(qr.gradient),
                                      np.asarray(qs.gradient))


def test_serving_batcher_replay_rejects_chunked_budget():
    from repro.data import make_linear_problem
    from repro.serving import CodedQueryBatcher

    prob = make_linear_problem(m=256, k=SMALL.K, seed=0)
    mom = second_moment(prob.X, prob.y)
    scheme = Scheme2.build(SMALL, mom, lr=prob.lr, decode_iters=8,
                           decode_backend="replay")
    with pytest.raises(ValueError, match="rounds_per_launch"):
        CodedQueryBatcher(scheme, n_slots=4, rounds_per_launch=2)


# ----------------------------------------- distributed + pipeline matrix


def test_distributed_master_replay_parity():
    from repro.distributed.selfcheck import check_parity

    assert check_parity(K=64, n_workers=8, steps=4, q0=0.25,
                        backend="sparse", master_decode="replay") == 4


def test_pipeline_master_replay_parity():
    from repro.distributed.selfcheck import check_pipeline_parity

    assert check_pipeline_parity(K=64, n_workers=8, steps=4, q0=0.25,
                                 backend="sparse",
                                 master_decode="replay") == 8


def test_pipeline_rejects_sharded_master_decode():
    from repro.core import Scheme2
    from repro.data import make_linear_problem
    from repro.distributed import WorkerTopology, make_worker_mesh
    from repro.distributed.pipeline import AsyncDistributedCodedGD

    prob = make_linear_problem(m=256, k=64, seed=0)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    scheme = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                           decode_backend="sparse")
    with pytest.raises(ValueError, match="single.*replay"):
        AsyncDistributedCodedGD(
            scheme=scheme, topology=WorkerTopology(8, code.N),
            mesh=make_worker_mesh(), master_decode="sharded")


# --------------------------------------------------- fused replay kernel


def test_replay_kernel_bit_parity_and_single_launch():
    from repro.kernels.ldpc_peel import peel_decode_replay_pallas

    erased = _mask(SMALL, q=0.3, seed=110)
    rx = jnp.asarray(_rx(_payload(SMALL, seed=110, V=2), erased))
    sched = compile_peel_schedule(SMALL, erased)
    ref = peel_decode(SMALL, rx, erased, sched.n_rounds, backend="replay")
    v, e = peel_decode_replay_pallas(sched, rx, jnp.asarray(erased),
                                     rule="hi", bv=8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(e), np.asarray(ref.erased))
    # ONE fused launch: exactly one pallas_call anywhere in the jaxpr
    # (the op jits its impl, so walk nested call jaxprs too)
    jaxpr = jax.make_jaxpr(
        lambda vv, ee: peel_decode_replay_pallas(sched, vv, ee, rule="hi",
                                                 bv=8))(rx,
                                                        jnp.asarray(erased))

    def count_pallas(jx):
        n = 0
        for eq in jx.eqns:
            if "pallas" in eq.primitive.name:
                n += 1
            for v in eq.params.values():
                inner = getattr(v, "jaxpr", None)
                if inner is not None:
                    n += count_pallas(inner)
        return n

    assert count_pallas(jaxpr.jaxpr) == 1


def test_replay_kernel_lo_rule_matches_batch_executor():
    from repro.kernels.ldpc_peel import peel_decode_replay_pallas

    erased = _mask(SMALL, q=0.3, seed=111)
    rx = _rx(_payload(SMALL, seed=111), erased)
    sched = compile_peel_schedule(SMALL, erased)
    ref = peel_decode_batch(SMALL, jnp.asarray(rx)[None],
                            jnp.asarray(erased)[None], sched.n_rounds,
                            backend="replay", schedules=(sched,))
    v, e = peel_decode_replay_pallas(sched, jnp.asarray(rx),
                                     jnp.asarray(erased), rule="lo", bv=8)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ref.values)[0])
    np.testing.assert_array_equal(np.asarray(e), np.asarray(ref.erased)[0])
