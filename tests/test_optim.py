"""Projections (hypothesis property tests), AdamW, schedules, stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # dev-only dep: degrade to per-test skips when missing
    from tests._hypothesis_compat import given, settings, st, hnp
except ImportError:
    from _hypothesis_compat import given, settings, st, hnp

from repro.core.straggler import (
    AdversarialStragglers,
    BernoulliStragglers,
    DelayModel,
    FixedCountStragglers,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update, projections, schedules

VEC = hnp.arrays(np.float32, st.integers(2, 40),
                 elements=st.floats(-100, 100, width=32))


@settings(max_examples=30, deadline=None)
@given(v=VEC, r=st.floats(0.1, 50))
def test_l2_ball_projection_properties(v, r):
    p = projections.l2_ball(r)(jnp.asarray(v))
    assert float(jnp.linalg.norm(p)) <= r * (1 + 1e-5)
    # idempotent
    np.testing.assert_allclose(projections.l2_ball(r)(p), p, rtol=1e-5, atol=1e-6)
    # non-expansive towards any point already in the ball
    q = jnp.zeros_like(p)
    assert float(jnp.linalg.norm(p - q)) <= float(jnp.linalg.norm(jnp.asarray(v) - q)) + 1e-4


@settings(max_examples=30, deadline=None)
@given(v=VEC, r=st.floats(0.1, 50))
def test_l1_ball_projection_properties(v, r):
    p = np.asarray(projections.l1_ball(r)(jnp.asarray(v)))
    # fp32: the simplex threshold is computed in f32, so the constraint can
    # overshoot by a few ulps relative to the INPUT scale, not just r
    assert np.abs(p).sum() <= r + 1e-3 * max(1.0, np.abs(v).sum() * 1e-3)
    p2 = np.asarray(projections.l1_ball(r)(jnp.asarray(p)))
    np.testing.assert_allclose(p2, p, rtol=1e-4, atol=1e-5)
    # optimality sanity: projection is no farther than the naive scaling
    naive = v * min(1.0, r / max(np.abs(v).sum(), 1e-30))
    assert np.linalg.norm(v - p) <= np.linalg.norm(v - naive) + 1e-4


@settings(max_examples=30, deadline=None)
@given(v=VEC, u=st.integers(1, 10))
def test_hard_threshold_properties(v, u):
    p = np.asarray(projections.hard_threshold(u)(jnp.asarray(v)))
    assert (p != 0).sum() <= u
    # kept coordinates are unchanged
    kept = p != 0
    np.testing.assert_allclose(p[kept], v[kept])
    # keeps the largest-|.| coordinates: any dropped |v| <= any kept |v|
    if u < len(v) and kept.any():
        dropped_mask = np.ones(len(v), bool)
        # indices that were kept (including kept zeros are impossible since p==0 there)
        assert np.abs(v)[~kept].max(initial=0.0) <= np.abs(p)[kept].min() + 1e-6


def test_adamw_matches_manual_reference():
    cfg = AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, 0.2, -0.3])}
    st_ = adamw_init(params)
    p1, st1 = adamw_update(params, g, st_, cfg)
    # manual first step: m=0.1g... update = g/(|g|+eps) (bias corrected)
    gn = np.asarray(g["w"])
    expect = np.asarray(params["w"]) - 1e-2 * gn / (np.abs(gn) + 1e-8)
    np.testing.assert_allclose(p1["w"], expect, rtol=1e-5)
    assert int(st1.step) == 1


def test_adamw_decay_and_convergence():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0])}
    state = adamw_init(params)

    for _ in range(200):
        g = {"w": 2.0 * params["w"]}  # d/dw w^2
        params, state = adamw_update(params, g, state, cfg)
    assert abs(float(params["w"][0])) < 0.05


def test_schedules():
    s = schedules.warmup_cosine(1.0, 10, 110)
    assert float(s(0)) == 0.0
    assert float(s(10)) == pytest.approx(1.0)
    assert float(s(110)) == pytest.approx(0.0, abs=1e-6)
    assert float(s(5)) == pytest.approx(0.5)
    assert float(schedules.theorem1_lr(2.0, 4.0, 25)(3)) == pytest.approx(0.1)


def test_bernoulli_straggler_rate():
    model = BernoulliStragglers(0.3)
    keys = jax.random.split(jax.random.PRNGKey(0), 200)
    masks = jnp.stack([model.sample(k, 64) for k in keys])
    assert abs(float(masks.mean()) - 0.3) < 0.03


def test_fixed_count_exact_s():
    model = FixedCountStragglers(7)
    for i in range(5):
        mask = model.sample(jax.random.PRNGKey(i), 40)
        assert int(mask.sum()) == 7
    assert int(FixedCountStragglers(0).sample(jax.random.PRNGKey(0), 40).sum()) == 0


def test_fixed_count_exact_s_always_and_uniform():
    """Permutation-based sampling: EXACTLY s for every key (the old
    score-threshold comparison over-erased on f32 score ties), all workers
    reachable, full-erasure edge included, and jit-able."""
    for s, w in ((1, 8), (5, 40), (39, 40), (40, 40)):
        model = FixedCountStragglers(s)
        keys = jax.random.split(jax.random.PRNGKey(s), 300)
        masks = np.stack([np.asarray(model.sample(k, w)) for k in keys])
        assert (masks.sum(axis=1) == s).all(), (s, w)
        if 0 < s < w:
            assert masks.any(axis=0).all(), "some worker never straggles"
            assert not masks.all(axis=0).any(), "some worker always straggles"
    jitted = jax.jit(lambda k: FixedCountStragglers(3).sample(k, 16))
    assert int(jitted(jax.random.PRNGKey(0)).sum()) == 3


def test_adversarial_fixed_set():
    model = AdversarialStragglers((1, 5))
    m1 = model.sample(jax.random.PRNGKey(0), 10)
    m2 = model.sample(jax.random.PRNGKey(9), 10)
    np.testing.assert_array_equal(m1, m2)
    assert int(m1.sum()) == 2 and bool(m1[1]) and bool(m1[5])


def test_delay_model():
    dm = DelayModel(tau=1.0, mu=2.0)
    d = dm.sample_delays(jax.random.PRNGKey(0), 1000)
    assert float(d.min()) >= 1.0
    assert abs(float(d.mean()) - 1.5) < 0.1  # tau + 1/mu
    mask, t = DelayModel.mask_and_time(d, wait_for=900)
    assert int((~mask).sum()) >= 900
    assert float(t) >= 1.0
