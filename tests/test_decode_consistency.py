"""Serving-path correctness: prefill + stepwise decode must reproduce the
full-sequence forward logits, for every mixer family (GQA, MLA-absorbed,
Mamba, RWKV-6, enc-dec cross-attention, VLM prefix).

This is the strongest functional check of the KV-cache / recurrent-state
plumbing: any rope offset bug, cache-slot bug, or state-handoff bug shows up
as a logits mismatch.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data.batches import make_batch
from repro.models import Model

ARCHS = [
    "qwen3-1.7b",        # GQA + qk-norm
    "qwen2-1.5b",        # GQA + QKV bias
    "deepseek-v2-236b",  # MLA: naive train vs absorbed decode
    "jamba-1.5-large-398b",  # mamba + attention + MoE
    "rwkv6-3b",          # rwkv time/channel mix state
    "whisper-medium",    # enc-dec with cross attention
    "internvl2-2b",      # vlm patch prefix
    "minitron-8b",       # relu2 MLP
]

B, SEQ = 2, 12


def _text_positions(cfg):
    return cfg.n_patches if cfg.family == "vlm" else 0


@pytest.mark.parametrize("name", ARCHS)
def test_prefill_then_decode_matches_forward(name):
    import dataclasses
    cfg = get_config(name).reduced()
    if cfg.moe is not None:
        # capacity dropping legitimately differs between a short prefill and
        # the full forward; crank capacity so no token is ever dropped and
        # the equivalence is exact.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    model = Model(cfg, remat=False, attn_chunk=4)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, SEQ, key=jax.random.PRNGKey(7))
    full_logits, _ = model.forward(params, batch)
    full_logits = np.asarray(full_logits, np.float32)

    toks = batch["tokens"]
    S_text = toks.shape[1]
    t0 = S_text // 2
    offset = _text_positions(cfg)  # decode positions continue after patches
    total_len = offset + S_text

    prefill_batch = dict(batch)
    prefill_batch["tokens"] = toks[:, :t0]
    cache = model.init_cache(B, total_len)
    last_logits, cache = model.prefill(params, prefill_batch, cache)

    # prefill's last logits == forward logits at position (offset + t0 - 1)
    np.testing.assert_allclose(np.asarray(last_logits[:, 0]),
                               full_logits[:, offset + t0 - 1],
                               rtol=2e-3, atol=2e-3)

    # stepwise decode over the remaining tokens
    for t in range(t0, S_text):
        tok = toks[:, t][:, None]
        logits, cache = model.decode_step(params, tok, jnp.int32(offset + t), cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), full_logits[:, offset + t],
            rtol=3e-3, atol=3e-3,
            err_msg=f"{name}: decode mismatch at t={t}")


def test_sliding_window_ring_buffer_matches_reference():
    """gqa_decode with a ring-buffer window cache == brute-force attention
    over exactly the last W tokens' K/V (module-level check: a window cache
    is NOT equivalent to truncating the model input, so the reference is
    built at the attention layer, where the semantics are exact)."""
    from repro.models.attention import init_gqa, gqa_decode
    from repro.serving.kvcache import make_attn_cache

    d, H, KV, Dh, W, S = 64, 4, 2, 16, 6, 15
    p = init_gqa(jax.random.PRNGKey(0), d, H, KV, Dh)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, S, d), jnp.float32) * 0.3

    cache = make_attn_cache(B, W, KV, Dh, jnp.float32)
    ks_all, vs_all = [], []
    from repro.models.layers import apply_rope, dense
    for t in range(S):
        xt = xs[:, t:t + 1]
        y, cache = gqa_decode(p, xt, n_heads=H, n_kv=KV, head_dim=Dh,
                              pos=jnp.int32(t), cache=cache, rope_theta=1e4)
        # reference: recompute k/v for ALL tokens so far, attend to last W
        kt = dense(p["wk"], xt).reshape(B, 1, KV, Dh)
        vt = dense(p["wv"], xt).reshape(B, 1, KV, Dh)
        kt = apply_rope(kt, jnp.arange(t, t + 1), 1e4)
        ks_all.append(kt); vs_all.append(vt)
        lo = max(0, t + 1 - W)
        k_ref = jnp.concatenate(ks_all[lo:], axis=1)
        v_ref = jnp.concatenate(vs_all[lo:], axis=1)
        q = dense(p["wq"], xt).reshape(B, 1, H, Dh)
        q = apply_rope(q, jnp.arange(t, t + 1), 1e4)
        qg = q.reshape(B, 1, KV, H // KV, Dh)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k_ref) / np.sqrt(Dh)
        attn = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bqkgd", attn, v_ref).reshape(B, 1, H * Dh)
        y_ref = dense(p["wo"], o)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4,
                                   err_msg=f"window mismatch at t={t}")


def _forward_with_positions(model, params, batch, positions):
    """forward() but with explicit absolute positions (test helper)."""
    x = params["embed"]["table"][batch["tokens"]]
    aux = jnp.zeros((), jnp.float32)
    for i in range(model.prefix_len):
        x, _, _ = model._apply_layer(params["prefix"][str(i)], model.specs[i], x,
                                     positions=positions, mode="train")
    body_specs = [model.specs[model.prefix_len + j] for j in range(model.period)]

    def block_fn(carry, bp):
        h, a = carry
        for j in range(model.period):
            h, _, aa = model._apply_layer(bp[f"sub{j}"], body_specs[j], h,
                                          positions=positions, mode="train")
            a = a + aa
        return (h, a), None

    (x, aux), _ = jax.lax.scan(block_fn, (x, aux), params["blocks"])
    x = model._norm(params["final_norm"], x)
    return (x @ params["unembed"]["w"].T).astype(jnp.float32), aux


def test_decode_is_jittable_fixed_cache_shape():
    cfg = get_config("qwen3-1.7b").reduced()
    model = Model(cfg, remat=False)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 16)

    step = jax.jit(model.decode_step)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits1, cache = step(params, tok, jnp.int32(0), cache)
    logits2, cache = step(params, tok, jnp.int32(1), cache)  # no recompile crash
    assert logits1.shape == (B, 1, cfg.vocab)
    assert np.isfinite(np.asarray(logits2)).all()


def test_mla_absorbed_cache_is_latent_sized():
    cfg = get_config("deepseek-v2-236b").reduced()
    model = Model(cfg, remat=False)
    cache = model.init_cache(B, 16)
    # per-layer per-token cache entries: kv_lora + qk_rope, NOT H*(nope+v)
    c = cache["blocks"]["sub0"]
    per_tok = c["c_kv"].shape[-1] + c["k_rope"].shape[-1]
    naive = cfg.n_heads * (cfg.mla.qk_nope + cfg.mla.v_head)
    assert per_tok < naive / 2
