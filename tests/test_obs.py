"""Observability layer: metrics registry semantics, span tracer export,
ObsSession lifecycle, and the JSONL report renderer."""
import json
import logging

import numpy as np
import pytest

from repro.obs import (
    ObsSession,
    enable_default_logging,
    metrics,
    report,
    trace,
)


# ------------------------------------------------------------- registry


def test_counter_gauge_info_semantics():
    reg = metrics.MetricsRegistry()
    c = reg.counter("x.total", driver="sync")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1.0)
    g = reg.gauge("x.gauge")
    assert not g.updated
    g.set(1.0)
    g.set(2.0)                          # last write wins
    assert g.value == 2.0 and g.updated
    i = reg.info("x.info", {"a": 1})
    reg.info("x.info", {"b": 2})        # last write wins
    assert i.info == {"b": 2}


def test_labels_make_distinct_series_and_render_sorted():
    reg = metrics.MetricsRegistry()
    reg.counter("steps", driver="sync").inc()
    reg.counter("steps", driver="pipeline").inc(2)
    assert reg.counter("steps", driver="sync").value == 1.0
    snap = reg.snapshot()
    assert snap["steps{driver=sync}"]["value"] == 1.0
    assert snap["steps{driver=pipeline}"]["value"] == 2.0
    # label values are stringified; keys render sorted
    reg.gauge("g", b=2, a=1)
    assert "g{a=1,b=2}" in reg.snapshot()


def test_kind_mismatch_rejected():
    reg = metrics.MetricsRegistry()
    reg.counter("m")
    with pytest.raises(ValueError, match="already"):
        reg.gauge("m")
    with pytest.raises(ValueError, match="already"):
        reg.histogram("m", bins=(0, 1))


def test_histogram_buckets_and_bin_contract():
    reg = metrics.MetricsRegistry()
    h = reg.histogram("rounds", bins=(0, 1, 2, 4))
    # edges E define E+1 buckets: (-inf,0], (0,1], (1,2], (2,4], (4,inf)
    h.observe_many([0, 1, 2, 3, 5])
    assert h.counts.tolist() == [1, 1, 1, 1, 1]
    assert h.count == 5 and h.total == pytest.approx(11.0)
    assert h.min == 0.0 and h.max == 5.0
    assert h.mean == pytest.approx(11.0 / 5)
    # re-fetch without bins returns the same series; different bins reject
    assert reg.histogram("rounds") is h
    with pytest.raises(ValueError, match="different bin edges"):
        reg.histogram("rounds", bins=(0, 1))
    with pytest.raises(ValueError, match="needs bins"):
        reg.histogram("fresh")
    # 'name' stays usable as a label key (positional-only metric name)
    reg.counter("c", name="x").inc()


def test_recording_scopes_and_restores():
    assert metrics.active() is None
    with metrics.recording() as outer:
        assert metrics.active() is outer
        inner = metrics.MetricsRegistry()
        with metrics.recording(inner):
            assert metrics.active() is inner
        assert metrics.active() is outer
    assert metrics.active() is None


def test_export_jsonl_round_trips(tmp_path):
    reg = metrics.MetricsRegistry()
    reg.counter("a.total").inc(3)
    reg.histogram("a.h", bins=metrics.ROUND_BINS, driver="sync").observe(4)
    path = reg.export_jsonl(tmp_path / "obs" / "m.jsonl")
    meta, entries = report.load_jsonl(path)
    assert meta["schema"] == 1 and meta["n_metrics"] == 2
    by_kind = {e["kind"] for e in entries}
    assert by_kind == {"counter", "histogram"}
    h = next(e for e in entries if e["kind"] == "histogram")
    assert h["labels"] == {"driver": "sync"}
    assert sum(h["counts"]) == 1 and h["sum"] == 4.0
    # every line is plain JSON (the export IS the wire format)
    for line in path.read_text().splitlines():
        json.loads(line)


# --------------------------------------------------------------- tracer


def test_tracer_spans_lanes_and_metric_feed(tmp_path):
    tr = trace.Tracer()
    with metrics.recording() as reg, trace.tracing(tr):
        with trace.span("master/decode", lane="master", step=3):
            pass
        tr.complete("pipeline/step", trace.now_us() - 50, 50,
                    lane="pipeline", step=0)
    assert [e["name"] for e in tr.events] == ["master/decode",
                                              "pipeline/step"]
    assert all(e["ph"] == "X" and e["dur"] >= 0 for e in tr.events)
    # distinct lanes -> distinct synthetic tids
    assert tr.events[0]["tid"] != tr.events[1]["tid"]
    assert tr.events[0]["args"]["step"] == 3
    # finished spans feed per-phase counters into the active registry
    assert reg.counter("trace.span_count", name="master/decode").value == 1
    assert reg.counter("trace.span_seconds",
                       name="pipeline/step").value > 0
    doc = json.loads(tr.export(tmp_path / "t.trace.json").read_text())
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e["ph"] == "M" and e["name"] == "thread_name"}
    assert names == {"master", "pipeline"}


def test_span_is_null_context_when_tracing_off():
    assert trace.active_tracer() is None
    cm = trace.span("anything", lane="x")
    with cm:
        pass                        # shared null context: free, reusable
    assert cm is trace.span("other")


# ------------------------------------------------------------ ObsSession


def test_obs_session_exports_both_files(tmp_path, capsys):
    path = tmp_path / "run.jsonl"
    session = ObsSession.start(path)
    reg, tr = metrics.active(), trace.active_tracer()
    assert reg is not None and tr is not None
    reg.counter("x").inc()
    with trace.span("phase/a"):
        pass
    session.finish()
    session.finish()                      # idempotent
    assert metrics.active() is None and trace.active_tracer() is None
    meta, entries = report.load_jsonl(path)
    assert any(e["name"] == "x" for e in entries)
    doc = json.loads(path.with_suffix(".trace.json").read_text())
    assert any(e["name"] == "phase/a" for e in doc["traceEvents"])
    # status line goes to stderr — stdout stays pure for --json surfaces
    out = capsys.readouterr()
    assert "[obs]" in out.err and out.out == ""


def test_null_session_is_inert():
    session = ObsSession.start(None)
    assert metrics.active() is None and trace.active_tracer() is None
    session.finish()                      # no-op, no files


def test_report_renders_summary(tmp_path, capsys):
    path = tmp_path / "run.jsonl"
    session = ObsSession.start(path)
    reg = metrics.active()
    reg.counter("distributed.steps_total", driver="pipeline").inc(4)
    reg.histogram("distributed.step.rounds", bins=metrics.ROUND_BINS,
                  driver="pipeline").observe_many([3, 4, 5, 6])
    reg.histogram("distributed.straggler.tracking_error",
                  bins=metrics.FRACTION_BINS,
                  driver="pipeline").observe_many([0.1, 0.2])
    reg.info("engine.dispatch", {"resolved_backend": "sparse"},
             backend="auto", resolved="sparse", N=128)
    with trace.span("master/decode"):
        pass
    session.finish(quiet=True)
    assert report.main([str(path),
                        "--trace", str(path.with_suffix(".trace.json"))]) == 0
    out = capsys.readouterr().out
    assert "distributed.steps_total" in out or "steps" in out
    assert "master/decode" in out
    assert "tracking" in out


# ------------------------------------------- report on partial inputs


def test_report_metrics_only_no_trace_file(tmp_path, capsys):
    # The common partial export: a metrics JSONL with no .trace.json
    # sibling (no tracer was active, or the file was not shipped).  The
    # report must render from the JSONL alone — --trace is opt-in.
    path = tmp_path / "run.jsonl"
    with metrics.recording() as reg:
        reg.counter("distributed.steps_total", driver="sync").inc(2)
        reg.export_jsonl(path)
    assert not path.with_suffix(".trace.json").exists()
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "steps" in out


def test_report_empty_run(tmp_path, capsys):
    # A registry that recorded nothing still exports a meta header; the
    # report must render the run section, not crash on zero entries.
    path = tmp_path / "empty.jsonl"
    metrics.MetricsRegistry().export_jsonl(path)
    assert report.main([str(path)]) == 0
    assert "metrics: 0" in capsys.readouterr().out
    # ... and a completely empty file (no meta line either) works too
    bare = tmp_path / "bare.jsonl"
    bare.write_text("")
    assert report.main([str(bare)]) == 0
    assert "metrics: 0" in capsys.readouterr().out


def test_report_unknown_metric_names(tmp_path, capsys):
    # Names no report section knows about (user-defined instrumentation,
    # or a newer exporter than this report) must not crash the renderer —
    # they count toward the run total and are otherwise skipped.
    path = tmp_path / "unknown.jsonl"
    with metrics.recording() as reg:
        reg.counter("sched_cache.hit").inc(7)
        reg.gauge("sched_cache.hit_rate").set(0.875)
        reg.histogram("my.custom.latency",
                      bins=metrics.LATENCY_BINS).observe(0.25)
        reg.info("user.build", {"commit": "abc123"})
        reg.export_jsonl(path)
    assert report.main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "metrics: 4" in out


def test_report_zero_count_histogram_renders():
    # A histogram that was created but never observed has count 0; the
    # known-name sections must render its mean as nan, not divide away.
    reg = metrics.MetricsRegistry()
    reg.histogram("distributed.step.rounds", bins=metrics.ROUND_BINS,
                  driver="sync")
    entries = [v for v in reg.snapshot().values()]
    text = report.summarize({}, entries)
    assert "rounds_used" in text and "nan" in text


def test_enable_default_logging_idempotent():
    logger = logging.getLogger("repro")
    before_handlers = list(logger.handlers)
    before_level = logger.level
    try:
        assert enable_default_logging() is logger
        n = len(logger.handlers)
        assert n == len(before_handlers) + 1
        enable_default_logging()              # idempotent: no second handler
        assert len(logger.handlers) == n
        assert logger.level == logging.DEBUG
    finally:
        logger.handlers = before_handlers
        logger.setLevel(before_level)


# ------------------------------------- engine dispatch discoverability


def test_engine_debug_info_surfaces_in_snapshot():
    from repro.core import make_regular_ldpc
    from repro.core.engine import CodedComputeEngine

    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    with metrics.recording() as reg:
        CodedComputeEngine(code, backend="sparse", decode_iters=4)
    snap = reg.snapshot()
    infos = [v for v in snap.values() if v["name"] == "engine.dispatch"]
    assert len(infos) == 1
    assert infos[0]["info"]["resolved_backend"] == "sparse"
    resolves = [v for v in snap.values()
                if v["name"] == "decoder.resolve_total"]
    assert resolves and resolves[0]["labels"]["resolved"] == "sparse"
