"""Seeded on-the-fly LDPC structure: determinism, kernel parity, encode.

The seeded construction's contract (core/ldpc.py): every check row of the
(l, r)-regular layered-permutation ensemble is a pure O(r) function of
``(seed, row)`` — the same bits on every host, device, and process — so
kernels regenerate H tiles in-register (``backend="pallas_seeded"``, zero H
operand traffic) and workers regenerate generator rows instead of holding
encoded-operator rows.  These tests pin:

* per-row determinism ACROSS PROCESSES (hash equality in subprocesses);
* the in-kernel tile generator (`seeded_h_tile`, jnp) bit-exact against
  the host-side reference (`seeded_h_rows`, NumPy), including check-row
  and column padding;
* exact (l, r)-biregularity of the materialized ensemble;
* all four seeded decode entry points at N = 8192 (interpret mode),
  erasure trajectories bit-identical to the sparse backend and VALUES
  bit-identical to the tiled kernel (same tile-shaped summation);
* structure-only decode (`SeededLDPC` — no materialized H anywhere);
* the seeded encode path (`encode_moment_seeded`, `Scheme2.build_seeded`)
  against the materialized generator, and its error paths;
* the benchmark-side failover when ``pallas_seeded`` is forced on a code
  that carries no seed.
"""
import functools
import hashlib
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Scheme2, second_moment
from repro.core.decoder import (
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    resolve_backend,
)
from repro.core.encoding import (
    encode_moment,
    encode_moment_seeded,
    gather_encode,
    generator_gather_tables,
)
from repro.core.ldpc import (
    SeededLDPC,
    make_parity_only_ldpc,
    make_seeded_ldgm,
    make_seeded_ldpc,
    seeded_generator_rows,
    seeded_h_rows,
    seeded_structure,
    seeded_structure_of,
)
from repro.data import make_linear_problem

REPO = Path(__file__).resolve().parents[1]
D = 5


@functools.lru_cache(maxsize=None)
def _seeded_code(K):
    return make_seeded_ldpc(K, l=4, r=8, seed=0)


def _instance(code, *, q=0.25, seed=0, V=None):
    rng = np.random.default_rng(seed)
    shape = (code.N,) if V is None else (code.N, V)
    vals = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < q)
    rx = jnp.where(erased if V is None else erased[:, None], 0.0, vals)
    return rx, erased


# ---------------------------------------------------------- determinism --


def test_seeded_rows_deterministic_across_processes():
    """The whole point of the counter-based construction: any process can
    regenerate any row range bit-for-bit from (seed, row) alone."""
    st = seeded_structure(1024, 2048, 8, seed=7)
    here = hashlib.sha256(
        np.ascontiguousarray(seeded_h_rows(st, 64, 192)).tobytes()
    ).hexdigest()
    prog = (
        "import hashlib, numpy as np\n"
        "from repro.core.ldpc import seeded_structure, seeded_h_rows\n"
        "st = seeded_structure(1024, 2048, 8, seed=7)\n"
        "h = hashlib.sha256(np.ascontiguousarray("
        "seeded_h_rows(st, 64, 192)).tobytes()).hexdigest()\n"
        "print(h)\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    for _ in range(2):
        res = subprocess.run([sys.executable, "-c", prog],
                             capture_output=True, text=True, timeout=300,
                             env=env, cwd=REPO)
        assert res.returncode == 0, res.stderr
        assert res.stdout.strip() == here


@pytest.mark.parametrize("row0,bp,n_pad", [
    (0, 128, 2048),      # interior tile, no padding
    (896, 256, 2176),    # column padding (n_pad > cols)
    (960, 128, 2048),    # last tile crosses spec.rows (check padding)
])
def test_kernel_tile_matches_host_rows(row0, bp, n_pad):
    """The jnp in-kernel tile generator is bit-exact against the NumPy
    reference, including zeroed pad rows and pad columns — f32 weights are
    sign·(1 + m·2^-23), exact in both arithmetics."""
    from repro.kernels.ldpc_peel import seeded_h_tile

    st = seeded_structure(1024, 2048, 8, seed=3)
    tile = np.asarray(seeded_h_tile(st, row0, bp, n_pad))
    assert tile.shape == (bp, n_pad)
    hi = min(row0 + bp, st.rows)
    ref = seeded_h_rows(st, row0, hi)
    np.testing.assert_array_equal(tile[: hi - row0, : st.cols], ref)
    assert (tile[hi - row0:] == 0.0).all()          # padded check rows
    assert (tile[:, st.cols:] == 0.0).all()         # padded columns


def test_degree_profile_exactly_biregular():
    """Every check row has exactly r nonzeros, every variable column
    exactly l — the layered-permutation ensemble is biregular by
    construction, not in expectation; weights have magnitude in [1, 2)."""
    for seed in range(3):
        code = make_seeded_ldpc(512, l=4, r=8, seed=seed)
        H = np.asarray(code.H)
        nz = H != 0.0
        np.testing.assert_array_equal(nz.sum(axis=1), 8)
        np.testing.assert_array_equal(nz.sum(axis=0), 4)
        mags = np.abs(H[nz])
        assert ((mags >= 1.0) & (mags < 2.0)).all()


def test_distinct_seeds_distinct_structures():
    a = seeded_h_rows(seeded_structure(256, 512, 8, seed=0), 0, 256)
    b = seeded_h_rows(seeded_structure(256, 512, 8, seed=1), 0, 256)
    assert (a != b).any()


# ---------------------------------------------------------- decode parity --


def test_seeded_values_bit_identical_to_tiled():
    """The seeded round is the tiled round with generation replacing DMA:
    same tile-shaped summation, same merge winner — VALUES (not just the
    trajectory) must match the tiled kernel bit for bit."""
    code = _seeded_code(1024)
    rx, erased = _instance(code, seed=1)
    for bp in (128, 512):
        tiled = peel_decode(code, rx, erased, D, backend="pallas_tiled",
                            bp=bp, bv=8)
        seeded = peel_decode(code, rx, erased, D, backend="pallas_seeded",
                             bp=bp, bv=8)
        np.testing.assert_array_equal(np.asarray(seeded.values),
                                      np.asarray(tiled.values))
        np.testing.assert_array_equal(np.asarray(seeded.erased),
                                      np.asarray(tiled.erased))


def test_all_four_seeded_variants_at_8192():
    """The acceptance config: fixed, adaptive, batch, and batch-adaptive
    seeded decodes at N = 8192 (interpret mode), erasure trajectories
    bit-identical to the sparse backend on the same code."""
    code = _seeded_code(4096)
    kw = dict(backend="pallas_seeded", bp=512, bv=8)

    # fixed
    rx, erased = _instance(code, seed=2)
    ref = peel_decode(code, rx, erased, D, backend="sparse")
    got = peel_decode(code, rx, erased, D, **kw)
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    still = ~np.asarray(erased)
    np.testing.assert_array_equal(np.asarray(got.values)[still],
                                  np.asarray(ref.values)[still])

    # adaptive: same fixpoint, real round count
    ref_a = peel_decode_adaptive(code, rx, erased, 16, backend="sparse")
    got_a = peel_decode_adaptive(code, rx, erased, 16, **kw)
    np.testing.assert_array_equal(np.asarray(got_a.erased),
                                  np.asarray(ref_a.erased))
    assert int(got_a.rounds_used) == int(ref_a.rounds_used)

    # batch of independent patterns
    B = 3
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.standard_normal((B, code.N)), jnp.float32)
    er_B = jnp.asarray(rng.random((B, code.N)) < 0.25)
    rx_B = jnp.where(er_B, 0.0, vals)
    ref_b = peel_decode_batch(code, rx_B, er_B, D, backend="sparse")
    got_b = peel_decode_batch(code, rx_B, er_B, D, **kw)
    np.testing.assert_array_equal(np.asarray(got_b.erased),
                                  np.asarray(ref_b.erased))

    # batch-adaptive with per-slot budgets
    budgets = jnp.asarray([1, 3, 16], jnp.int32)
    ref_ba = peel_decode_batch_adaptive(code, rx_B, er_B, 16,
                                        backend="sparse", budgets=budgets)
    got_ba = peel_decode_batch_adaptive(code, rx_B, er_B, 16, budgets=budgets,
                                        **kw)
    np.testing.assert_array_equal(np.asarray(got_ba.erased),
                                  np.asarray(ref_ba.erased))
    np.testing.assert_array_equal(np.asarray(got_ba.rounds_used),
                                  np.asarray(ref_ba.rounds_used))


def test_structure_only_decode_no_materialized_h():
    """A SeededLDPC carries (N, K, l, r, seed) and nothing else — the
    decode must match the materialized code's seeded decode bit for bit,
    and every H-needing backend must refuse it loudly."""
    code = _seeded_code(1024)
    sl = SeededLDPC(N=code.N, K=code.K, l=4, r=8, seed=0)
    rx, erased = _instance(code, seed=9)
    ref = peel_decode(code, rx, erased, D, backend="pallas_seeded", bv=8)
    got = peel_decode(sl, rx, erased, D, backend="auto", bv=8)
    np.testing.assert_array_equal(np.asarray(got.values),
                                  np.asarray(ref.values))
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    assert resolve_backend("auto", sl) == "pallas_seeded"
    with pytest.raises(ValueError):
        resolve_backend("sparse", sl)


def test_pallas_seeded_rejected_without_seed():
    code = make_parity_only_ldpc(512, l=3, r=6, seed=0)
    with pytest.raises(ValueError):
        resolve_backend("pallas_seeded", code)
    with pytest.raises(ValueError):
        seeded_structure_of(code)


def test_seeded_structure_validation():
    with pytest.raises(ValueError):
        seeded_structure(10, 20, 8, 0)       # cols % row_weight != 0
    with pytest.raises(ValueError):
        seeded_structure(10, 64, 8, 0)       # rows % rows_per_layer != 0


# ----------------------------------------------------------------- encode --


def test_encode_moment_seeded_matches_materialized():
    """The gather+sum over regenerated generator rows reproduces G @ M up
    to f32 summation order (the gather sums r terms in index order; the
    matvec may block differently)."""
    code = make_seeded_ldgm(64, 32, row_weight=8, seed=0)
    rng = np.random.default_rng(0)
    M = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    ref = encode_moment(code, M)
    got = encode_moment_seeded(code, M)
    assert got.shape == ref.shape == (code.N, 64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # systematic prefix is an exact copy either way
    np.testing.assert_array_equal(np.asarray(got[:64]), np.asarray(M))


def test_gather_encode_2d_matches_columnwise_1d():
    """The 2-D payload path (coded aggregation) is the 1-D gather applied
    per column — bit for bit, since each output element is the same
    r-term sum."""
    code = make_seeded_ldgm(64, 32, row_weight=8, seed=1)
    idx, coeff = generator_gather_tables(code)
    rng = np.random.default_rng(1)
    Y = jnp.asarray(rng.standard_normal((64, 5)), jnp.float32)
    got = np.asarray(gather_encode(idx, coeff, Y))
    for j in range(5):
        np.testing.assert_array_equal(
            got[:, j], np.asarray(gather_encode(idx, coeff, Y[:, j])))


def test_seeded_generator_rows_requires_ldgm():
    with pytest.raises(ValueError):
        seeded_generator_rows(_seeded_code(512), 0, 8)


def test_scheme2_build_seeded_matches_materialized():
    """Same code, same masks: the seeded scheme (C = raw M, per-step
    generator gather) tracks the materialized scheme (C = G @ M) to f32
    summation order, with identical unresolved sets."""
    K = 64
    code = make_seeded_ldgm(K, 32, row_weight=8, seed=0)
    prob = make_linear_problem(m=4 * K, k=K, seed=0)
    mom = second_moment(prob.X, prob.y)
    mat = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                        decode_backend="sparse")
    sed = Scheme2.build_seeded(code, mom, lr=prob.lr, decode_iters=8,
                               decode_backend="sparse")
    assert sed.seeded_encode and sed.C.shape == (K, K)
    rng = np.random.default_rng(2)
    theta = jnp.asarray(rng.standard_normal(K), jnp.float32)
    mask = jnp.asarray(rng.random(code.N) < 0.25)
    g_m, u_m = mat.gradient(theta, mask)
    g_s, u_s = sed.gradient(theta, mask)
    np.testing.assert_allclose(np.asarray(g_s), np.asarray(g_m),
                               rtol=2e-4, atol=2e-4)
    assert int(u_s) == int(u_m)
    # batched queries too
    theta_B = jnp.asarray(rng.standard_normal((3, K)), jnp.float32)
    mask_B = jnp.asarray(rng.random((3, code.N)) < 0.25)
    gb_m, ub_m = mat.gradient_batch(theta_B, mask_B)
    gb_s, ub_s = sed.gradient_batch(theta_B, mask_B)
    np.testing.assert_allclose(np.asarray(gb_s), np.asarray(gb_m),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_array_equal(np.asarray(ub_s), np.asarray(ub_m))


# -------------------------------------------------------- bench failover --


def test_resolve_bench_backend_seeded_failover():
    from benchmarks.common import resolve_bench_backend

    # no seed on the code → clean failover with a message
    code = make_parity_only_ldpc(1024, l=3, r=6, seed=0)
    backend, msg = resolve_bench_backend(code, "pallas_seeded")
    assert backend == "sparse"
    assert msg and "seeded" in msg
    # small materialized seeded code → the request stands
    small = make_seeded_ldpc(128, l=4, r=8, seed=0)
    assert resolve_bench_backend(small, "pallas_seeded") == \
        ("pallas_seeded", None)
    # structure-only code past the interpret limit: no H to fall back on,
    # the seeded kernel IS the decode
    sl = SeededLDPC(N=2048, K=1024, l=4, r=8, seed=0)
    assert resolve_bench_backend(sl, "pallas_seeded") == \
        ("pallas_seeded", None)
