"""The depth-k pipelined runtime: degenerate-corner bit-parity with the
synchronous driver, late-arrival folding against a host-side reference,
drop-semantics equivalence of w ≡ 0, no-recompile guarantees, and the
simulated pipeline clock.

Like ``tests/test_distributed.py``, the in-process tests run on whatever
mesh this process has (1 CPU device in tier-1; 8 fake devices in the CI
distributed job) — logical workers are decoupled from devices.  The
subprocess test forces the fake 8-device mesh (the acceptance
configuration) through ``selfcheck --pipeline``.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BernoulliStragglers,
    ScheduledDelays,
    Scheme2,
    make_regular_ldpc,
    second_moment,
)
from repro.core.straggler import DelayModel
from repro.data import make_linear_problem
from repro.distributed import (
    AsyncDistributedCodedGD,
    DistributedCodedGD,
    WorkerTopology,
    delay_step_control,
    pipeline_timeline,
)
from repro.distributed.selfcheck import check_pipeline_parity
from repro.distributed.telemetry import pick_wait_for_cached

REPO = Path(__file__).resolve().parents[1]

K = 64
W = 8
CODE = make_regular_ldpc(K, l=3, r=6, seed=0)
PROB = make_linear_problem(m=4 * K, k=K, seed=0)
MOM = second_moment(PROB.X, PROB.y)
TOPO = WorkerTopology(W, CODE.N)


def _scheme(backend="sparse", decode_iters=8, **kw):
    return Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=decode_iters,
                         decode_backend=backend, **kw)


# A deterministic delay table that exercises every arrival class: per step
# the three slowest workers miss the 5-of-8 cutoff (delay 1.0) at lags
# 1, 2, and never; positions rotate so different symbols are erased.
def _fold_schedule(steps):
    row = np.full(W, 1.0)
    row[5], row[6], row[7] = 1.6, 2.9, 9.0
    return np.stack([np.roll(row, t) for t in range(steps)])


# ------------------------------------------------------- depth-1 bit parity


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_depth1_zero_window_bit_parity(backend):
    """depth=1, max_staleness=0 walks the synchronous trajectory exactly —
    both the straggler-model and the delay-model (telemetry control plane)
    legs, checked inside ``check_pipeline_parity``."""
    assert check_pipeline_parity(K=K, n_workers=W, steps=4, q0=0.25,
                                 backend=backend) == 8


def test_depth1_bit_parity_pallas():
    assert check_pipeline_parity(K=K, n_workers=W, steps=2, q0=0.25,
                                 backend="pallas") == 4


def test_depth1_bit_parity_seeded_worker_encode():
    assert check_pipeline_parity(K=K, n_workers=W, steps=3, q0=0.25,
                                 backend="sparse",
                                 worker_encode="seeded") == 6


def test_pipeline_parity_on_fake_8_device_mesh_subprocess():
    """The acceptance configuration: real 8-device mesh, both worker
    encode modes, through the selfcheck CLI."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    for extra in ([], ["--worker-encode", "seeded"]):
        res = subprocess.run(
            [sys.executable, "-m", "repro.distributed.selfcheck",
             "--pipeline", "--workers", "8", "--steps", "3",
             "--backends", "sparse", *extra],
            capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
        assert res.returncode == 0, res.stderr
        assert "parity OK: pipeline" in res.stdout


def test_telemetry_budget_mode_depth1_parity():
    """The adaptive-budget control plane (EMA → decode budget) must also
    survive the pipelined driver unchanged at depth 1."""
    scheme = _scheme(decode_iters=16)
    sync = DistributedCodedGD(scheme, TOPO, budget_mode="telemetry",
                              max_rounds=16)
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=1, max_staleness=0,
                                   budget_mode="telemetry", max_rounds=16)
    key = jax.random.PRNGKey(3)
    theta0 = jnp.zeros(K)
    rs = sync.run(theta0, None, 5, key=key, theta_star=PROB.theta_star,
                  delay_model=DelayModel(tau=1.0, mu=1.0))
    rp = pipe.run(theta0, None, 5, key=key, theta_star=PROB.theta_star,
                  delay_model=DelayModel(tau=1.0, mu=1.0))
    assert (np.asarray(rs.theta) == np.asarray(rp.theta)).all()
    assert (rs.budgets == rp.budgets).all()
    assert (rs.rounds == rp.rounds).all()
    assert (rs.unresolved == rp.unresolved).all()
    assert rs.rates == pytest.approx(rp.rates)


# --------------------------------------------------- fold path correctness


def test_zero_decay_is_bit_exact_drop_semantics():
    """w ≡ 0 (staleness_decay=0) must reproduce max_staleness=0 exactly:
    no fold dispatches, no ±0 sign flips from adding a zero delta."""
    scheme = _scheme()
    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(1)
    dm = ScheduledDelays.build(_fold_schedule(6))
    drop = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=0)
    w0 = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=2,
                                 staleness_decay=0.0)
    rd = drop.run(theta0, None, 6, key=key, theta_star=PROB.theta_star,
                  delay_model=dm)
    dm.reset()
    r0 = w0.run(theta0, None, 6, key=key, theta_star=PROB.theta_star,
                delay_model=dm)
    assert (np.asarray(rd.theta) == np.asarray(r0.theta)).all()
    assert (rd.errors == r0.errors).all()
    assert r0.fold_rounds.sum() == 0
    assert r0.resolved_late.sum() == 0


def test_fold_matches_host_reference():
    """The device-side fold pipeline (stored survivors, re-decode with the
    remaining mask, staleness-weighted delta on NEWLY resolved coords,
    no double-counting) against a step-by-step host reference built from
    the engine primitives at depth 1."""
    decay, window, steps = 0.7, 2, 6
    scheme = _scheme(decode_iters=8)
    eng = scheme.engine
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=1,
                                   max_staleness=window,
                                   staleness_decay=decay)
    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(0)
    sched = _fold_schedule(steps)
    dm = ScheduledDelays.build(sched)
    res = pipe.run(theta0, None, steps, key=key,
                   theta_star=PROB.theta_star, delay_model=dm,
                   record_thetas=True)

    # ---- host reference -------------------------------------------------
    theta = theta0
    entries = []                                # (step, z, u, cut, lags)
    thetas_ref, unres_pre, newly_by_src = [], [], {}
    for t in range(steps):
        wait = pick_wait_for_cached(0.3, W, CODE.l, CODE.r)
        cut, cutoff, _ = delay_step_control(sched[t], wait, 2.0)
        lags = DelayModel.arrival_lags(sched[t], cutoff)
        never = cut & (lags > window)
        z = jnp.where(TOPO.to_symbol_erasure(never), 0.0, scheme.C @ theta)
        fold_dg = jnp.zeros(K)
        still = []
        for (s, z_s, u_s, cut_s, lags_s) in entries:
            lag = t - s
            if (cut_s & (lags_s == lag)).any():
                remaining = cut_s & (lags_s > lag)
                er = TOPO.to_symbol_erasure(remaining)
                dec = eng.decode_batch(eng.erase(z_s, er)[None], er[None],
                                       adaptive=True,
                                       budgets=np.asarray([8], np.int32))
                c2, u2 = eng.systematic(dec)
                c2, u2 = c2[0], u2[0]
                newly = u_s & ~u2
                fold_dg = fold_dg + scheme._debias(
                    jnp.where(newly, c2 - scheme.b, 0.0)) * (decay ** lag)
                newly_by_src[s] = newly_by_src.get(s, 0) + int(newly.sum())
                u_s = u_s & u2
            if lag < window and (cut_s & (lags_s > lag)).any():
                still.append((s, z_s, u_s, cut_s, lags_s))
        entries = still
        c_hat, u = eng.recover(z, TOPO.to_symbol_erasure(cut))
        g, n_unres = scheme.finish_gradient(c_hat, u)
        theta = scheme.projection(theta - scheme.lr * (g + fold_dg))
        thetas_ref.append(np.asarray(theta))
        unres_pre.append(int(n_unres))
        if (cut & (lags > 0) & (lags <= window)).any():
            entries.append((t, z, u, cut, lags))

    # The reference is EAGER, so fused-multiply-add choices differ from the
    # jitted programs and the peeling chains amplify that f32 noise a few
    # orders (observed ≤ 2e-3 over 6 steps); any WIRING error — wrong
    # w(τ), skipped or double-counted fold — lands at O(0.1) and up.
    assert res.thetas == pytest.approx(np.stack(thetas_ref), abs=2e-2,
                                       rel=2e-2)
    # the run must actually have folded something, and the bookkeeping
    # (post-fold unresolved = pre-fold − newly per SOURCE step) must agree
    assert res.resolved_late.sum() > 0
    for s in range(steps):
        assert res.resolved_late[s] == newly_by_src.get(s, 0)
        assert res.unresolved[s] == unres_pre[s] - newly_by_src.get(s, 0)


def test_folds_recover_unresolved_coordinates():
    """With a tight round budget the main decode gives up on some
    coordinates; the fold window must claw a measurable share back and
    not hurt convergence."""
    scheme = _scheme(decode_iters=4)
    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(0)
    drop = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=0)
    fold = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=2,
                                   staleness_decay=0.5)
    steps = 8
    dm = ScheduledDelays.build(_fold_schedule(steps))
    rd = drop.run(theta0, None, steps, key=key,
                  theta_star=PROB.theta_star, delay_model=dm)
    dm.reset()
    rf = fold.run(theta0, None, steps, key=key,
                  theta_star=PROB.theta_star, delay_model=dm)
    assert rd.unresolved.sum() > 0          # budget genuinely runs out
    assert rf.resolved_late.sum() > 0       # folds landed
    assert rf.unresolved.sum() < rd.unresolved.sum()
    assert rf.errors[-1] <= rd.errors[-1] * 1.05


# ------------------------------------------------- compile-once guarantees


def test_no_recompile_across_masks_budgets_and_weights():
    """Masks, budgets, step index, and staleness weights are all traced
    operands: one compiled master program and one fold program serve the
    whole run."""
    scheme = _scheme(decode_iters=16)
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=2,
                                   staleness_decay=0.5,
                                   budget_mode="telemetry", max_rounds=16)
    dm = ScheduledDelays.build(_fold_schedule(7))
    pipe.run(jnp.zeros(K), None, 7, key=jax.random.PRNGKey(0),
             theta_star=PROB.theta_star, delay_model=dm)
    assert pipe._cache_size() == 1
    assert pipe._fold_program._cache_size() == 1


# ------------------------------------------------------------ control plane


def test_auto_staleness_adapts_window():
    """auto_staleness starts from the prior (window = cap, the uniform
    late prior can't reach 0.9 coverage at s ≤ 4) and shrinks to the
    observed lag support (all late arrivals at lag ≤ 2 here)."""
    scheme = _scheme()
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=4,
                                   auto_staleness=True)
    row = np.full(W, 1.0)
    row[5], row[6], row[7] = 1.6, 2.9, 2.9     # lags 1, 2, 2 — no nevers
    dm = ScheduledDelays.build(np.stack([np.roll(row, t)
                                         for t in range(10)]))
    res = pipe.run(jnp.zeros(K), None, 10, key=jax.random.PRNGKey(0),
                   theta_star=PROB.theta_star, delay_model=dm)
    assert res.staleness[0] == 4               # prior: cap
    assert res.staleness[-1] == 2              # learned: lag support


def test_validates_construction():
    scheme = _scheme()
    with pytest.raises(ValueError, match="depth"):
        AsyncDistributedCodedGD(scheme, TOPO, depth=0)
    with pytest.raises(ValueError, match="max_staleness"):
        AsyncDistributedCodedGD(scheme, TOPO, max_staleness=-1)
    with pytest.raises(ValueError, match="staleness_decay"):
        AsyncDistributedCodedGD(scheme, TOPO, staleness_decay=1.5)
    with pytest.raises(ValueError, match="auto_staleness"):
        AsyncDistributedCodedGD(scheme, TOPO, auto_staleness=True,
                                max_staleness=0)


def test_depth2_uses_stale_iterate_and_converges():
    """Depth 2 launches workers at θ_{t-2} — a delayed-gradient chain that
    still converges at a conservative stepsize."""
    scheme = Scheme2.build(CODE, MOM, lr=PROB.lr * 0.5, decode_iters=8,
                           decode_backend="sparse")
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=0)
    res = pipe.run(jnp.zeros(K), BernoulliStragglers(0.15), 25,
                   key=jax.random.PRNGKey(0), theta_star=PROB.theta_star)
    assert res.errors[-1] < 0.25 * res.errors[0]


# --------------------------------------------------------- simulated clock


def test_pipeline_timeline_depth1_is_barrier():
    waits = np.array([1.0, 2.0, 1.5])
    decodes = np.array([0.5, 0.5, 1.0])
    _, m_end = pipeline_timeline(waits, decodes, 1)
    assert m_end[-1] == pytest.approx(waits.sum() + decodes.sum())


def test_pipeline_timeline_depth2_overlaps():
    """Balanced phases: depth 2 hides all but one worker phase behind the
    master — makespan T+1 units instead of the barrier's 2T."""
    T = 8
    waits = np.ones(T)
    decodes = np.ones(T)
    _, barrier = pipeline_timeline(waits, decodes, 1)
    w_end, m_end = pipeline_timeline(waits, decodes, 2)
    assert barrier[-1] == pytest.approx(2.0 * T)
    assert m_end[-1] == pytest.approx(T + 1.0)
    # worker t may start before master t-1 finished, never before t-2
    for t in range(2, T):
        assert w_end[t] - waits[t] >= m_end[t - 2] - 1e-12


def test_pipeline_timeline_validates():
    with pytest.raises(ValueError, match="depth"):
        pipeline_timeline([1.0], [1.0], 0)


# ----------------------------------------------------------- observability


def _reset_estimators(pipe):
    est, lag = pipe.estimator, pipe.lag_estimator
    est._ema, est._norm, est.steps = 0.0, 0.0, 0
    lag._mass[:] = 0.0
    lag._norm, lag.steps = 0.0, 0


def test_obs_instrumentation_preserves_trajectory_and_caches():
    """Obs on vs off: bit-identical iterates/rounds/unresolved, the
    compile-once invariants hold, and the streams are non-vacuous."""
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace

    scheme = _scheme(decode_iters=16)
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=2, max_staleness=2,
                                   staleness_decay=0.5,
                                   budget_mode="telemetry", max_rounds=16)
    dm = ScheduledDelays.build(_fold_schedule(6))
    key = jax.random.PRNGKey(0)

    def run():
        _reset_estimators(pipe)
        return pipe.run(jnp.zeros(K), None, 6, key=key,
                        theta_star=PROB.theta_star, delay_model=dm)

    r_plain = run()
    with obs_metrics.recording() as reg, obs_trace.tracing() as tr:
        r_obs = run()
    assert (np.asarray(r_plain.theta) == np.asarray(r_obs.theta)).all()
    assert (r_plain.rounds == r_obs.rounds).all()
    assert (r_plain.unresolved == r_obs.unresolved).all()
    assert (r_plain.budgets == r_obs.budgets).all()
    assert pipe._cache_size() == 1
    assert pipe._fold_program._cache_size() == 1
    assert reg.counter("distributed.steps_total",
                       driver="pipeline").value == 6
    assert reg.get("distributed.step.rounds", driver="pipeline").count == 6
    names = {e["name"] for e in tr.events}
    assert {"worker/launch", "master/dispatch", "pipeline/step"} <= names


def test_sync_and_pipeline_metric_streams_agree_at_depth1():
    """depth=1 / zero window walks the synchronous trajectory, so the two
    drivers' per-step metric streams must be identical histograms —
    distinguished only by the driver label."""
    from repro.obs import metrics as obs_metrics

    scheme = _scheme(decode_iters=8)
    sync = DistributedCodedGD(scheme, TOPO, budget_mode="fixed")
    pipe = AsyncDistributedCodedGD(scheme, TOPO, depth=1, max_staleness=0,
                                   budget_mode="fixed")
    key = jax.random.PRNGKey(1)
    theta0 = jnp.zeros(K)
    with obs_metrics.recording() as reg:
        sync.run(theta0, None, 5, key=key, theta_star=PROB.theta_star,
                 delay_model=DelayModel(tau=1.0, mu=1.0))
        pipe.run(theta0, None, 5, key=key, theta_star=PROB.theta_star,
                 delay_model=DelayModel(tau=1.0, mu=1.0))
    for name in ("distributed.step.rounds", "distributed.step.unresolved",
                 "distributed.step.budget",
                 "distributed.step.budget_headroom"):
        hs = reg.get(name, driver="sync")
        hp = reg.get(name, driver="pipeline")
        assert hs.count == hp.count == 5, name
        assert hs.counts.tolist() == hp.counts.tolist(), name
        assert hs.total == hp.total, name
    assert reg.counter("distributed.steps_total", driver="sync").value == 5
    assert reg.counter("distributed.steps_total",
                       driver="pipeline").value == 5
