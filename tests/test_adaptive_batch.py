"""Per-slot adaptive batched decode: parity with the sequential adaptive
decode across every backend, per-slot round budgets, edge cases, and the
one-launch property of the fused kernel.

Contract (mirrors test_engine.py's batched fixed-D contract):
``peel_decode_batch_adaptive`` of B independent patterns follows
BIT-IDENTICAL erasure trajectories AND per-slot round counts to a Python
loop of B sequential ``peel_decode_adaptive`` calls, on every backend;
decoded values agree up to f32 summation order, so value agreement is
anchored to the single decode's own deviation from the true codeword.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedComputeEngine,
    Scheme2,
    make_regular_ldpc,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    second_moment,
)
from repro.data import make_linear_problem

BACKENDS = ("dense", "sparse", "pallas")


def _batch_instance(code, *, B, V, qs, seed):
    rng = np.random.default_rng(seed)
    sh = (B, code.K) if V is None else (B, code.K, V)
    msgs = rng.standard_normal(sh)
    cws = np.einsum("nk,bk...->bn...", code.G, msgs)
    erased = rng.random((B, code.N)) < np.asarray(qs)[:, None]
    emask = erased if V is None else erased[:, :, None]
    rx = jnp.asarray(np.where(emask, 0.0, cws), jnp.float32)
    return cws, rx, jnp.asarray(erased)


def _assert_matches_sequential(code, cws, rx, erased, budgets):
    B = rx.shape[0]
    for backend in BACKENDS:
        bat = peel_decode_batch_adaptive(code, rx, erased, backend=backend,
                                         budgets=jnp.asarray(budgets))
        assert bat.rounds_used.shape == (B,)
        for i in range(B):
            single = peel_decode_adaptive(code, rx[i], erased[i],
                                          int(budgets[i]), backend=backend)
            # bit-for-bit: same per-slot round count and erasure endpoint
            assert int(bat.rounds_used[i]) == int(single.rounds_used), \
                f"backend={backend} slot={i}: round count diverged"
            np.testing.assert_array_equal(
                np.asarray(bat.erased[i]), np.asarray(single.erased),
                err_msg=f"backend={backend} slot={i}: mask diverged")
            # values: both decodes deviate independently from the true
            # codeword (different f32 summation orders), so their mutual
            # difference is bounded by the SUM of the two deviations
            # (triangle inequality), not by the single decode's alone
            ok = ~np.asarray(single.erased)
            truth, got_s = np.asarray(cws[i]), np.asarray(single.values)
            got_b = np.asarray(bat.values[i])
            dev = float(np.max(np.abs(got_s[ok] - truth[ok]), initial=0.0))
            dev += float(np.max(np.abs(got_b[ok] - truth[ok]), initial=0.0))
            atol = max(5e-4, 3.0 * dev)
            np.testing.assert_allclose(
                np.asarray(bat.values[i]), got_s, rtol=atol, atol=atol,
                err_msg=f"backend={backend} slot={i}: values diverged")


@pytest.mark.parametrize("K,B,V,qs,seed", [
    # ragged mix: clean, light, moderate, heavy slots -> ragged round counts
    (20, 4, None, (0.0, 0.1, 0.25, 0.4), 0),
    (60, 5, 3, (0.05, 0.2, 0.3, 0.4, 0.25), 1),   # N=120 (not 128k), payload V
    (100, 4, None, (0.4, 0.0, 0.3, 0.4), 2),      # heavy first, clean inside
])
def test_batched_adaptive_matches_sequential(K, B, V, qs, seed):
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    cws, rx, erased = _batch_instance(code, B=B, V=V, qs=qs, seed=seed)
    _assert_matches_sequential(code, cws, rx, erased, [code.N] * B)


def test_batched_adaptive_per_slot_budgets():
    """Per-slot budgets truncate exactly like sequential max_iters — and are
    traced (two different budget vectors reuse one compilation)."""
    code = make_regular_ldpc(60, l=3, r=6, seed=3)
    cws, rx, erased = _batch_instance(code, B=4, V=None,
                                      qs=(0.3, 0.3, 0.3, 0.3), seed=3)
    _assert_matches_sequential(code, cws, rx, erased, [0, 1, 2, code.N])


def test_batched_adaptive_all_and_none_converged_edges():
    """Edges: nothing erased anywhere (0 rounds per slot) and everything
    erased everywhere (never solvable: one probe round, all unresolved)."""
    code = make_regular_ldpc(32, l=3, r=6, seed=4)
    rng = np.random.default_rng(4)
    msgs = rng.standard_normal((3, code.K))
    cws = np.einsum("nk,bk->bn", code.G, msgs)
    rx = jnp.asarray(cws, jnp.float32)
    clean = jnp.zeros((3, code.N), bool)
    full = jnp.ones((3, code.N), bool)
    for backend in BACKENDS:
        dec = peel_decode_batch_adaptive(code, rx, clean, backend=backend)
        assert np.asarray(dec.rounds_used).tolist() == [0, 0, 0]
        assert not bool(dec.erased.any())
        np.testing.assert_allclose(np.asarray(dec.values), cws,
                                   rtol=1e-6, atol=1e-6)
        dec = peel_decode_batch_adaptive(code, jnp.zeros_like(rx), full,
                                         backend=backend)
        # r >= 2: no check is ever solvable -> one no-progress probe round
        assert np.asarray(dec.rounds_used).tolist() == [1, 1, 1]
        assert bool(dec.erased.all())


def test_batched_adaptive_is_one_pallas_launch():
    """The per-slot adaptive batched decode must stay ONE pallas_call —
    grid over slots, in-kernel while_loop, budgets a traced operand."""
    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    B = 3
    vals = jnp.zeros((B, code.N), jnp.float32)
    er = jnp.zeros((B, code.N), bool)
    budgets = jnp.full((B,), 5, jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda v, e, bu: peel_decode_batch_adaptive(
            code, v, e, backend="pallas", budgets=bu).values
    )(vals, er, budgets)
    assert str(jaxpr).count("pallas_call") == 1


def test_batched_adaptive_rejects_bad_shapes():
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    with pytest.raises(ValueError):
        peel_decode_batch_adaptive(code, jnp.zeros((code.N,)),
                                   jnp.zeros((code.N,), bool))
    with pytest.raises(ValueError):
        peel_decode_batch_adaptive(code, jnp.zeros((2, code.N)),
                                   jnp.zeros((2, code.N), bool),
                                   budgets=jnp.zeros((3,), jnp.int32))


def test_adaptive_matches_fixed_point_of_fixed_d():
    """At a budget >= convergence, per-slot adaptive reaches the same
    endpoint as the fixed-D batch run at the full budget (the surplus
    fixed-D rounds are no-ops) — the efficiency is free."""
    code = make_regular_ldpc(48, l=3, r=6, seed=6)
    cws, rx, erased = _batch_instance(code, B=4, V=None,
                                      qs=(0.0, 0.1, 0.3, 0.2), seed=6)
    for backend in BACKENDS:
        ada = peel_decode_batch_adaptive(code, rx, erased, code.N,
                                         backend=backend)
        fix = peel_decode_batch(code, rx, erased, code.N, backend=backend)
        np.testing.assert_array_equal(np.asarray(ada.erased),
                                      np.asarray(fix.erased))
        np.testing.assert_allclose(np.asarray(ada.values),
                                   np.asarray(fix.values),
                                   rtol=1e-5, atol=1e-5)
        assert int(jnp.max(ada.rounds_used)) <= code.N


# --------------------------------------------------------- engine / scheme


def test_engine_decode_batch_adaptive_stats_and_override():
    code = make_regular_ldpc(64, l=3, r=6, seed=5)
    rng = np.random.default_rng(5)
    msgs = rng.standard_normal((4, code.K))
    sym = np.einsum("nk,bk->bn", code.G, msgs)
    er = rng.random((4, code.N)) < np.array([0.0, 0.1, 0.3, 0.5])[:, None]
    rx = jnp.asarray(np.where(er, 0.0, sym), jnp.float32)
    erj = jnp.asarray(er)

    eng = CodedComputeEngine(code, decode_iters=10, adaptive=True,
                             backend="sparse")
    dec = eng.decode_batch(rx, erj)
    assert dec.rounds_used.shape == (4,)           # per-slot stats
    assert int(dec.rounds_used[0]) == 0            # clean slot: zero rounds
    assert int(dec.rounds_used[2]) > int(dec.rounds_used[1])
    # per-slot unresolved counts are derivable from the per-slot mask
    assert np.asarray(dec.erased.sum(axis=1)).shape == (4,)

    # explicit override: fixed-D on an adaptive engine and vice versa
    assert eng.decode_batch(rx, erj, adaptive=False).rounds_used.ndim == 0
    fixed_eng = CodedComputeEngine(code, decode_iters=10, backend="sparse")
    assert fixed_eng.decode_batch(rx, erj, adaptive=True
                                  ).rounds_used.shape == (4,)
    # budgets on a fixed-D decode would be silently ignored -> hard error
    with pytest.raises(ValueError):
        fixed_eng.decode_batch(rx, erj, budgets=jnp.array([1, 1, 1, 1]))

    # budgets thread through recover_batch too
    c_hat, unres = eng.recover_batch(jnp.asarray(sym, jnp.float32), erj,
                                     budgets=jnp.array([0, 0, 0, 0]))
    assert c_hat.shape == (4, code.K)
    np.testing.assert_array_equal(np.asarray(unres), er[:, :code.K])


@pytest.mark.parametrize("backend", BACKENDS)
def test_scheme2_adaptive_gradient_batch_matches_loop(backend):
    """Adaptive Scheme2.gradient_batch == per-query adaptive gradient."""
    prob = make_linear_problem(m=256, k=60, seed=1)
    code = make_regular_ldpc(60, l=3, r=6, seed=1)
    mom = second_moment(prob.X, prob.y)
    s2 = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8, adaptive=True,
                       decode_backend=backend)
    rng = np.random.default_rng(2)
    B = 5
    theta_B = jnp.asarray(rng.standard_normal((B, 60)), jnp.float32)
    mask_B = jnp.asarray(rng.random((B, code.N)) < 0.2)
    g_B, u_B = s2.gradient_batch(theta_B, mask_B)
    for i in range(B):
        g, u = s2.gradient(theta_B[i], mask_B[i])
        assert int(u_B[i]) == int(u)
        np.testing.assert_allclose(np.asarray(g_B[i]), np.asarray(g),
                                   rtol=2e-3, atol=2e-3)
