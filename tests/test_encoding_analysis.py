"""Moment encoding + roofline analysis plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    encode_moment,
    encode_moment_blocks,
    make_regular_ldpc,
    second_moment,
)
from repro.launch.analysis import HW, collective_bytes, model_flops


def test_second_moment():
    rng = np.random.default_rng(0)
    X = jnp.asarray(rng.standard_normal((50, 10)), jnp.float32)
    y = jnp.asarray(rng.standard_normal(50), jnp.float32)
    M, b = second_moment(X, y)
    np.testing.assert_allclose(M, np.asarray(X).T @ np.asarray(X),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(b, np.asarray(X).T @ np.asarray(y),
                               rtol=1e-4, atol=1e-4)


def test_encode_moment_systematic_and_codeword():
    code = make_regular_ldpc(24, l=3, r=6, seed=0)
    rng = np.random.default_rng(1)
    M = jnp.asarray(rng.standard_normal((24, 24)), jnp.float32)
    C = encode_moment(code, M)
    assert C.shape == (code.N, 24)
    np.testing.assert_allclose(C[:24], M, rtol=1e-5)       # systematic
    theta = jnp.asarray(rng.standard_normal(24), jnp.float32)
    z = C @ theta
    # C @ theta is a codeword whose first K coords are M @ theta
    np.testing.assert_allclose(code.H @ np.asarray(z), 0.0, atol=1e-3)
    np.testing.assert_allclose(z[:24], M @ theta, rtol=1e-4, atol=1e-4)


def test_encode_moment_blocks():
    code = make_regular_ldpc(8, l=3, r=6, seed=0)
    rng = np.random.default_rng(2)
    k = 24  # 3 blocks of K=8
    M = jnp.asarray(rng.standard_normal((k, k)), jnp.float32)
    C = encode_moment_blocks(code, M)
    assert C.shape == (3, code.N, k)
    for i in range(3):
        np.testing.assert_allclose(C[i], code.G @ np.asarray(M)[8 * i: 8 * (i + 1)],
                                   rtol=1e-4, atol=1e-4)
    with pytest.raises(ValueError):
        encode_moment(code, M)  # K != k requires the blocked form


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[16,128]{1,0} all-gather(bf16[1,128]{1,0} %x), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %y), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %z), dimensions={0}
  %aa = bf16[8,64]{1,0} all-to-all(bf16[8,64]{1,0} %w), dimensions={0}
  %cp = f32[4]{0} collective-permute(f32[4]{0} %v), source_target_pairs={{0,1}}
  %notacoll = f32[10]{0} add(f32[10]{0} %a, f32[10]{0} %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 128 * 2
    assert out["all-reduce"] == 256 * 4 * 2     # x2 ring factor
    assert out["reduce-scatter"] == 32 * 4
    assert out["all-to-all"] == 8 * 64 * 2
    assert out["collective-permute"] == 4 * 4
    assert out["count"] == 5


def test_model_flops_dense_vs_moe():
    from repro.configs import get_config
    from repro.models import Model

    dense_cfg = get_config("qwen3-1.7b")
    model = Model(dense_cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    f_train = model_flops(dense_cfg, model, shapes, "train", 256, 4096)
    # 6*N*D within 2x (embed excluded, attention flops not counted)
    n_nonembed = sum(
        int(np.prod(l.shape)) for p, l in
        jax.tree_util.tree_flatten_with_path(shapes)[0]
        if not any("embed" in str(getattr(k, "key", "")) for k in p))
    approx = 6 * n_nonembed * 256 * 4096
    assert 0.5 < f_train / approx < 2.0

    moe_cfg = get_config("kimi-k2-1t-a32b")
    m2 = Model(moe_cfg)
    shapes2 = jax.eval_shape(m2.init, jax.random.PRNGKey(0))
    f_moe = model_flops(moe_cfg, m2, shapes2, "train", 8, 128)
    f_moe_dense_equiv = 6 * m2.param_count(shapes2) * 8 * 128
    # active << total for a 1T-param top-8-of-384 MoE
    assert f_moe < 0.15 * f_moe_dense_equiv

    # decode counts one token
    f_dec = model_flops(dense_cfg, model, shapes, "decode", 128, 32768)
    assert f_dec < f_train / 1000


def test_hw_constants():
    assert HW["peak_flops"] == 197e12
    assert HW["hbm_bw"] == 819e9
    assert HW["ici_bw"] == 50e9
