"""CodedComputeEngine: batched decode parity, pipeline-stage equivalence,
and Scheme-Protocol conformance.

Batched-decode contract (mirrors the backend-parity contract in
test_decoder_backends.py): ``decode_batch`` of B independent erasure
patterns follows BIT-IDENTICAL erasure trajectories to a Python loop of B
single-pattern ``decode`` calls on every backend — solvability is an exact
count and the resolved neighbour per check is uniquely determined — while
decoded *values* agree up to f32 summation order (the batched dense path
lowers matvecs to batched GEMMs, the batch-major sparse round re-associates
row sums), so value agreement is anchored to the single decode's own
deviation from the true codeword, exactly as the backend-parity tests do.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CodedComputeEngine,
    FixedCountStragglers,
    Scheme,
    Scheme2,
    make_ldgm,
    make_regular_ldpc,
    peel_decode,
    peel_decode_batch,
    run_pgd,
    scheme_registry,
    second_moment,
)
from repro.data import make_linear_problem

BACKENDS = ("dense", "sparse", "pallas")


def _batch_instance(code, *, B, V, q, seed):
    rng = np.random.default_rng(seed)
    sh = (B, code.K) if V is None else (B, code.K, V)
    msgs = rng.standard_normal(sh)
    cws = np.einsum("nk,bk...->bn...", code.G, msgs)
    erased = rng.random((B, code.N)) < q
    emask = erased if V is None else erased[:, :, None]
    rx = jnp.asarray(np.where(emask, 0.0, cws), jnp.float32)
    return cws, rx, jnp.asarray(erased)


def _assert_batch_matches_loop(code, cws, rx, erased, iters):
    B = rx.shape[0]
    for backend in BACKENDS:
        bat = peel_decode_batch(code, rx, erased, iters, backend=backend)
        assert bat.values.shape == rx.shape
        assert bat.erased.shape == erased.shape
        assert int(bat.rounds_used) == iters
        for i in range(B):
            single = peel_decode(code, rx[i], erased[i], iters,
                                 backend=backend)
            # bit-for-bit: identical erasure trajectory endpoint per element
            np.testing.assert_array_equal(
                np.asarray(bat.erased[i]), np.asarray(single.erased),
                err_msg=f"backend={backend} element={i}: mask diverged")
            # values: anchored to the single decode's own f32 conditioning
            ok = ~np.asarray(single.erased)
            truth, got_s = np.asarray(cws[i]), np.asarray(single.values)
            dev = float(np.max(np.abs(got_s[ok] - truth[ok]), initial=0.0))
            atol = max(5e-4, 3.0 * dev)
            np.testing.assert_allclose(
                np.asarray(bat.values[i]), got_s, rtol=atol, atol=atol,
                err_msg=f"backend={backend} element={i}: values diverged")


@pytest.mark.parametrize("K,B,V,q,seed", [
    (20, 6, None, 0.25, 0),      # the paper's (40, 20) code, scalar queries
    (60, 5, 3, 0.30, 1),         # N = 120: not a multiple of 128, payload V
    (100, 9, None, 0.40, 2),     # heavy erasures: ragged unresolved counts
    (128, 4, 1, 0.20, 3),        # explicit V=1 (not squeezed)
])
def test_batched_decode_matches_single_loop(K, B, V, q, seed):
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    cws, rx, erased = _batch_instance(code, B=B, V=V, q=q, seed=seed)
    _assert_batch_matches_loop(code, cws, rx, erased, iters=8)


def test_batched_decode_matches_single_loop_ldgm():
    code = make_ldgm(32, 16, row_weight=4, seed=2)
    cws, rx, erased = _batch_instance(code, B=6, V=4, q=0.3, seed=5)
    _assert_batch_matches_loop(code, cws, rx, erased, iters=6)


def test_batched_ragged_unresolved_counts():
    """Batch elements with wildly different straggler loads (0%..100%) keep
    per-element trajectories: the clean element fully recovers while the
    saturated one stays fully erased, in ONE launch."""
    code = make_regular_ldpc(64, l=3, r=6, seed=4)
    rng = np.random.default_rng(4)
    msgs = rng.standard_normal((4, code.K))
    cws = np.einsum("nk,bk->bn", code.G, msgs)
    erased = np.zeros((4, code.N), bool)
    erased[1] = rng.random(code.N) < 0.15
    erased[2] = rng.random(code.N) < 0.45
    erased[3] = True
    rx = jnp.asarray(np.where(erased, 0.0, cws), jnp.float32)
    for backend in BACKENDS:
        bat = peel_decode_batch(code, rx, jnp.asarray(erased), code.N,
                                backend=backend)
        counts = np.asarray(bat.erased.sum(axis=1))
        assert counts[0] == 0
        assert counts[3] == code.N  # r >= 2: nothing ever solvable
        for i in range(4):
            single = peel_decode(code, rx[i], jnp.asarray(erased[i]), code.N,
                                 backend=backend)
            np.testing.assert_array_equal(np.asarray(bat.erased[i]),
                                          np.asarray(single.erased))


def test_batched_rejects_bad_rank():
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    with pytest.raises(ValueError):
        peel_decode_batch(code, jnp.zeros((code.N,)), jnp.zeros((code.N,), bool), 2)


# ------------------------------------------------------------ engine stages


def test_engine_stages_compose_to_scheme2_gradient():
    """encode→erase→decode→epilogue through the engine == Scheme2.gradient."""
    prob = make_linear_problem(m=256, k=60, seed=0)
    code = make_regular_ldpc(60, l=3, r=6, seed=0)
    mom = second_moment(prob.X, prob.y)
    s2 = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8)
    eng = s2.engine
    theta = jax.random.normal(jax.random.PRNGKey(0), (60,))
    mask = jnp.zeros(code.N, bool).at[jnp.array([3, 17, 90])].set(True)

    # hand-composed stages
    z = eng.erase(s2.C @ theta, mask)
    dec = eng.decode(z, mask)
    c_hat, unresolved = eng.systematic(dec)
    g_manual = c_hat - jnp.where(unresolved, 0.0, s2.b)

    g, n_unres = s2.gradient(theta, mask)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_manual),
                               rtol=1e-6, atol=1e-6)
    assert int(n_unres) == int(unresolved.sum())


def test_engine_encode_is_systematic():
    code = make_regular_ldpc(40, l=3, r=6, seed=1)
    eng = CodedComputeEngine(code)
    payload = jnp.asarray(np.random.default_rng(0).standard_normal((40, 3)),
                          jnp.float32)
    symbols = eng.encode(payload)
    assert symbols.shape == (code.N, 3)
    np.testing.assert_allclose(np.asarray(symbols[:code.K]),
                               np.asarray(payload), rtol=1e-5, atol=1e-5)


def test_engine_gradient_batch_matches_loop():
    """Scheme2.gradient_batch == per-query Scheme2.gradient (one launch)."""
    prob = make_linear_problem(m=256, k=60, seed=1)
    code = make_regular_ldpc(60, l=3, r=6, seed=1)
    mom = second_moment(prob.X, prob.y)
    for backend in ("dense", "sparse", "pallas"):
        s2 = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                           decode_backend=backend)
        rng = np.random.default_rng(2)
        B = 5
        theta_B = jnp.asarray(rng.standard_normal((B, 60)), jnp.float32)
        mask_B = jnp.asarray(rng.random((B, code.N)) < 0.2)
        g_B, u_B = s2.gradient_batch(theta_B, mask_B)
        assert g_B.shape == (B, 60)
        for i in range(B):
            g, u = s2.gradient(theta_B[i], mask_B[i])
            assert int(u_B[i]) == int(u)
            np.testing.assert_allclose(np.asarray(g_B[i]), np.asarray(g),
                                       rtol=2e-3, atol=2e-3)


def test_engine_rejects_unknown_backend():
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    with pytest.raises(ValueError):
        CodedComputeEngine(code, backend="nope")


def test_engine_adaptive_decode_budget():
    """adaptive=True engines treat decode_iters as the round budget."""
    code = make_regular_ldpc(64, l=3, r=6, seed=5)
    rng = np.random.default_rng(5)
    cw = jnp.asarray(code.encode(rng.standard_normal(code.K)), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < 0.25)
    rx = jnp.where(erased, 0.0, cw)
    eng = CodedComputeEngine(code, decode_iters=1, adaptive=True)
    dec1 = eng.decode(rx, erased)
    assert int(dec1.rounds_used) <= 1
    eng_full = CodedComputeEngine(code, decode_iters=code.N, adaptive=True)
    dec = eng_full.decode(rx, erased)
    assert int(dec.erased.sum()) <= int(dec1.erased.sum())


# -------------------------------------------------- Scheme Protocol matrix


def _build_all_schemes():
    """One small instance of EVERY scheme in the registry."""
    from repro.core import Scheme1, Scheme2Blocked
    from repro.core.schemes import (GradientCodingFR, Karakus, MDSLee,
                                    Replication, Uncoded)

    prob = make_linear_problem(m=128, k=40, seed=3)
    mom = second_moment(prob.X, prob.y)
    code40 = make_regular_ldpc(40, l=3, r=6, seed=0)     # K == k
    code20 = make_regular_ldpc(20, l=3, r=6, seed=0)     # K | k (2 blocks)
    w = 8
    return {
        "scheme1": Scheme1.build(code20, mom, lr=prob.lr),
        "scheme2": Scheme2.build(code40, mom, lr=prob.lr, decode_iters=6),
        "scheme2-blocked": Scheme2Blocked.build(code20, mom, lr=prob.lr,
                                                decode_iters=6),
        "uncoded": Uncoded(prob.X, prob.y, w=w, lr=prob.lr),
        "replication": Replication(prob.X, prob.y, w=w, lr=prob.lr, r=2),
        "karakus": Karakus.build(prob.X, prob.y, w, lr=prob.lr, seed=0),
        "mds-lee": MDSLee.build(prob.X, prob.y, w, lr=prob.lr, K_code=4),
        "gradient-coding-fr": GradientCodingFR(prob.X, prob.y, w=w, s=1,
                                               lr=prob.lr),
    }


def test_every_registered_scheme_satisfies_protocol_under_run_pgd():
    """The Protocol replaces the old ad-hoc duck typing: every scheme in the
    registry is a runtime instance of Scheme AND actually runs under the
    shared run_pgd driver."""
    instances = _build_all_schemes()
    registry = scheme_registry()
    assert set(instances) == set(registry), "registry/test instance drift"
    for name, scheme in instances.items():
        assert isinstance(scheme, Scheme), f"{name} violates the Protocol"
        assert isinstance(scheme, registry[name])
        res = run_pgd(scheme, jnp.zeros(40), FixedCountStragglers(1),
                      steps=3, key=jax.random.PRNGKey(0))
        assert res.errors.shape == (3,)
        assert res.theta.shape == (40,)
        assert np.isfinite(np.asarray(res.theta)).all(), name


def test_protocol_rejects_non_schemes():
    @dataclasses.dataclass
    class NotAScheme:
        w: int = 4

    assert not isinstance(NotAScheme(), Scheme)
