"""Pallas kernel sweeps vs pure-jnp oracles (interpret=True on CPU).

Per the assignment contract: for each kernel, sweep shapes/dtypes and
assert_allclose against the ref.py oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoder import peel_decode
from repro.core.ldpc import make_regular_ldpc
from repro.kernels.block_matmul import block_matmul, coded_matvec, encode_gm
from repro.kernels.block_matmul.ref import block_matmul_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.ldpc_peel import peel_decode_pallas, peel_round_pallas
from repro.kernels.ldpc_peel.kernel import check_pass
from repro.kernels.ldpc_peel.ref import check_pass_ref


# ------------------------------------------------------------- ldpc_peel --


@pytest.mark.parametrize("p,N,V", [(8, 16, 1), (32, 64, 4), (128, 256, 128),
                                   (130, 260, 7), (64, 128, 200)])
def test_check_pass_matches_ref(p, N, V):
    rng = np.random.default_rng(p + N + V)
    H = rng.standard_normal((p, N)).astype(np.float32)
    H[rng.random((p, N)) < 0.8] = 0.0  # sparse
    vals = rng.standard_normal((N, V)).astype(np.float32)
    erased = (rng.random(N) < 0.3).astype(np.float32)[:, None]

    # pad to kernel-legal sizes the same way ops.py does
    def pad(x, m0, m1):
        return np.pad(x, ((0, (-x.shape[0]) % m0), (0, (-x.shape[1]) % m1)))

    bp = min(128, max(8, p))
    Hp = pad(H, bp, 128)
    vp = pad(vals, 128, min(128, max(8, V)))
    ep = pad(erased, 128, 1)
    sums, cnt, pos, coeff = check_pass(jnp.asarray(Hp), jnp.asarray(vp),
                                       jnp.asarray(ep), bp=bp,
                                       bv=min(128, vp.shape[1]))
    rs, rc, rp, rf = check_pass_ref(jnp.asarray(Hp), jnp.asarray(vp),
                                    jnp.asarray(ep))
    np.testing.assert_allclose(sums, rs, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(cnt, rc, rtol=1e-6)
    np.testing.assert_array_equal(pos, rp)
    np.testing.assert_allclose(coeff, rf, rtol=1e-6)


@pytest.mark.parametrize("K,V", [(20, 1), (40, 8), (100, 64)])
def test_peel_round_pallas_matches_decoder(K, V):
    code = make_regular_ldpc(K, l=3, r=6, seed=K)
    rng = np.random.default_rng(0)
    msg = rng.standard_normal((K, V)).astype(np.float32)
    cw = jnp.asarray(code.encode(msg), jnp.float32)
    if V == 1:
        cw = cw[:, 0]
    erased = jnp.asarray(rng.random(code.N) < 0.25)
    rx = jnp.where(erased if cw.ndim == 1 else erased[:, None], 0.0, cw)

    from repro.core.decoder import peel_round
    H = jnp.asarray(code.H, jnp.float32)
    ref_v, ref_e = peel_round(H, jnp.asarray(code.H_mask),
                              rx[:, None] if cw.ndim == 1 else rx, erased)
    got_v, got_e = peel_round_pallas(H, rx, erased)
    np.testing.assert_array_equal(got_e, ref_e)
    gv = got_v[:, None] if cw.ndim == 1 else got_v
    np.testing.assert_allclose(gv, ref_v, rtol=1e-4, atol=1e-4)


def test_peel_decode_pallas_full_agreement():
    code = make_regular_ldpc(60, l=3, r=6, seed=3)
    rng = np.random.default_rng(1)
    cw = jnp.asarray(code.encode(rng.standard_normal(60)), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < 0.3)
    rx = jnp.where(erased, 0.0, cw)
    ref = peel_decode(code, rx, erased, iters=10)
    got_v, got_e = peel_decode_pallas(jnp.asarray(code.H, jnp.float32),
                                      rx, erased, iters=10)
    np.testing.assert_array_equal(got_e, ref.erased)
    ok = ~np.asarray(got_e)
    np.testing.assert_allclose(np.asarray(got_v)[ok], np.asarray(ref.values)[ok],
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- block_matmul --


@pytest.mark.parametrize("M,K,N", [(8, 8, 8), (128, 128, 128), (100, 37, 65),
                                   (256, 512, 128), (40, 200, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmul_sweep(M, K, N, dtype):
    rng = np.random.default_rng(M * K + N)
    A = jnp.asarray(rng.standard_normal((M, K)), dtype)
    B = jnp.asarray(rng.standard_normal((K, N)), dtype)
    got = block_matmul(A, B)
    ref = block_matmul_ref(A, B)
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(got, ref, rtol=tol, atol=tol * K)


def test_coded_matvec_and_encode():
    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    rng = np.random.default_rng(2)
    M = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    theta = jnp.asarray(rng.standard_normal(64), jnp.float32)
    C = encode_gm(jnp.asarray(code.G, jnp.float32), M)
    np.testing.assert_allclose(C, code.G @ np.asarray(M), rtol=1e-4, atol=1e-4)
    z = coded_matvec(C, theta)
    np.testing.assert_allclose(z, np.asarray(C) @ np.asarray(theta),
                               rtol=1e-4, atol=1e-4)


# -------------------------------------------------------- flash_attention --


@pytest.mark.parametrize("B,S,H,KV,D", [
    (1, 8, 1, 1, 16), (2, 64, 4, 2, 32), (1, 128, 8, 8, 64),
    (2, 100, 4, 1, 32),  # non-tile-multiple seq + MQA
    (1, 256, 2, 2, 128),
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(B, S, H, KV, D, causal):
    rng = np.random.default_rng(B * S + H + D)
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), jnp.float32) * 0.5
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32) * 0.5
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, bq=32, bk=32)
    # oracle with expanded GQA heads
    G = H // KV
    ke = jnp.repeat(k, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ve = jnp.repeat(v, G, axis=2).transpose(0, 2, 1, 3).reshape(B * H, S, D)
    qe = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = attention_ref(qe, ke, ve, causal=causal)
    ref = ref.reshape(B, H, S, D).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(9)
    B, S, H, D = 1, 64, 2, 32
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype) * 0.5
    k = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype) * 0.5
    v = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    got = flash_attention(q, k, v, causal=True, bq=32, bk=32)
    qe = q.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ke = k.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ve = v.transpose(0, 2, 1, 3).reshape(B * H, S, D)
    ref = attention_ref(qe, ke, ve, causal=True).reshape(B, H, S, D
                                                         ).transpose(0, 2, 1, 3)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_matches_model_sdpa():
    """Flash kernel == models.attention.sdpa_chunked (the production path)."""
    from repro.models.attention import sdpa_chunked
    rng = np.random.default_rng(3)
    B, S, KV, G, D = 2, 64, 2, 2, 32
    H = KV * G
    q = jnp.asarray(rng.standard_normal((B, S, KV, G, D)), jnp.float32) * 0.3
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32) * 0.3
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)), jnp.float32)
    pos = jnp.arange(S)
    ref = sdpa_chunked(q, k, v, pos, pos, causal=True, chunk=16)
    # flash expects (B, S, H, D) with per-KV grouping order preserved
    qf = q.reshape(B, S, H, D)
    got = flash_attention(qf, k, v, causal=True, bq=32, bk=32)
    np.testing.assert_allclose(got, ref.reshape(B, S, H, D), rtol=2e-4, atol=2e-4)
