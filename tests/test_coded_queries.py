"""Serving-layer batcher for concurrent coded queries: lockstep waves and
continuous per-slot admission."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Scheme2, make_regular_ldpc, second_moment
from repro.data import make_linear_problem
from repro.serving import CodedQuery, CodedQueryBatcher

K = 60
PROB = make_linear_problem(m=256, k=K, seed=0)
CODE = make_regular_ldpc(K, l=3, r=6, seed=0)
MOM = second_moment(PROB.X, PROB.y)


def _scheme(backend="sparse", decode_iters=8):
    return Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=decode_iters,
                         decode_backend=backend)


def _queries(n, seed=0, q=0.2):
    rng = np.random.default_rng(seed)
    return [CodedQuery(i, rng.standard_normal(K).astype(np.float32),
                       rng.random(CODE.N) < q) for i in range(n)]


def _assert_matches_reference(q, scheme, rtol=2e-3):
    g_ref, u_ref = scheme.gradient(jnp.asarray(q.theta),
                                   jnp.asarray(q.straggler_mask))
    assert q.unresolved == int(u_ref)
    np.testing.assert_allclose(q.gradient, np.asarray(g_ref),
                               rtol=rtol, atol=rtol)


def test_waves_flush_through_one_launch_each():
    bat = CodedQueryBatcher(_scheme(), n_slots=4)
    for q in _queries(10):
        bat.submit(q)
    done = bat.run()
    assert len(done) == 10
    assert all(q.done for q in done)
    # 10 queries, 4 slots -> ceil(10/4) = 3 batched launches, not 10
    assert bat.launches == 3
    assert not bat.active


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_batched_results_match_single_query_path(backend):
    scheme = _scheme(backend)
    bat = CodedQueryBatcher(scheme, n_slots=4)
    queries = _queries(6, seed=1)
    for q in queries:
        bat.submit(q)
    bat.run()
    for q in queries:
        g_ref, u_ref = scheme.gradient(jnp.asarray(q.theta),
                                       jnp.asarray(q.straggler_mask))
        assert q.unresolved == int(u_ref)
        np.testing.assert_allclose(q.gradient, np.asarray(g_ref),
                                   rtol=2e-3, atol=2e-3)


def test_partial_wave_padding_is_inert():
    """A lone query in an 8-slot wave gets the same answer as unbatched."""
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=8)
    [q] = _queries(1, seed=2, q=0.3)
    bat.submit(q)
    bat.run()
    assert bat.launches == 1
    g_ref, u_ref = scheme.gradient(jnp.asarray(q.theta),
                                   jnp.asarray(q.straggler_mask))
    assert q.unresolved == int(u_ref)
    np.testing.assert_allclose(q.gradient, np.asarray(g_ref),
                               rtol=2e-3, atol=2e-3)


def test_submit_validates_shapes():
    bat = CodedQueryBatcher(_scheme(), n_slots=2)
    with pytest.raises(ValueError):
        bat.submit(CodedQuery(0, np.zeros(K + 1, np.float32),
                              np.zeros(CODE.N, bool)))
    with pytest.raises(ValueError):
        bat.submit(CodedQuery(0, np.zeros(K, np.float32),
                              np.zeros(CODE.N - 1, bool)))


def test_rejects_scheme_without_batch_api():
    class NoBatch:
        pass

    with pytest.raises(TypeError):
        CodedQueryBatcher(NoBatch())


def test_rejects_unknown_mode():
    with pytest.raises(ValueError):
        CodedQueryBatcher(_scheme(), mode="async")


# ------------------------------------------------------ continuous admission


def _heavy_light_queries(n, *, heavy_ids, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        q = 0.42 if i in heavy_ids else 0.08
        out.append(CodedQuery(i, rng.standard_normal(K).astype(np.float32),
                              rng.random(CODE.N) < q))
    return out


def test_lockstep_mode_flushes_in_waves():
    """The explicit lockstep baseline keeps the PR-2 wave contract."""
    bat = CodedQueryBatcher(_scheme(), n_slots=4, mode="lockstep")
    for q in _queries(10):
        bat.submit(q)
    done = bat.run()
    assert len(done) == 10 and bat.launches == 3
    # every wave pays the fixed budget; accounting says so
    assert all(q.rounds == 8 and q.launches == 1 for q in done)
    for q in done:
        _assert_matches_reference(q, _scheme())


def test_continuous_light_never_waits_on_heavy():
    """One heavy query pins a slot across launches; light queries stream
    through the remaining slot, one launch each."""
    scheme = _scheme(decode_iters=12)
    bat = CodedQueryBatcher(scheme, n_slots=2, rounds_per_launch=2)
    qs = _heavy_light_queries(5, heavy_ids={0}, seed=3)
    for q in qs:
        bat.submit(q)
    bat.run()
    heavy, lights = qs[0], qs[1:]
    assert heavy.launches > 1                       # spans several launches
    assert all(q.launches == 1 for q in lights)     # lights: in-and-out
    assert all(q.finished_launch <= heavy.finished_launch for q in lights)
    assert heavy.rounds > max(q.rounds for q in lights)
    for q in lights:
        _assert_matches_reference(q, scheme)


def test_continuous_results_match_single_query_path():
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=4, rounds_per_launch=3)
    queries = _queries(9, seed=1)
    for q in queries:
        bat.submit(q)
    done = bat.run()
    assert len(done) == 9
    for q in queries:
        _assert_matches_reference(q, scheme)


def test_continuous_fifo_admission_and_refill():
    """Slots refill from the FIFO head: admission order == submission
    order, and a retired slot is reused by the next queued query."""
    bat = CodedQueryBatcher(_scheme(), n_slots=2, rounds_per_launch=8)
    qs = _queries(7, seed=2)
    for q in qs:
        bat.submit(q)
    bat.run()
    admits = [q.admitted_launch for q in qs]
    assert admits == sorted(admits)                 # FIFO admission order
    assert admits[0] == admits[1] == 0              # first pair fills pool
    assert len({q.admitted_launch for q in qs}) >= 3  # refills happened
    assert not bat.active


def test_continuous_launch_accounting():
    """launches counts batched launches; per-query launches sum to the
    slot-launch occupancy (every occupied slot rides every launch once)."""
    bat = CodedQueryBatcher(_scheme(), n_slots=4, rounds_per_launch=8)
    qs = _queries(10, seed=0)
    for q in qs:
        bat.submit(q)
    bat.run()
    # light q=0.2 queries converge within one 8-round chunk -> wave-like
    assert bat.launches == 3
    assert sum(q.launches for q in qs) == 10
    assert all(q.finished_launch >= q.admitted_launch for q in qs)


def test_continuous_partial_pool_padding_compiles_once():
    """Inert padding slots keep every launch the same static shape: ONE
    trace of the launch fn serves full, partial, and refilled pools."""
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=8, rounds_per_launch=2)
    qs = _queries(11, seed=4, q=0.25)   # 11 queries, 8 slots: partial waves
    for q in qs:
        bat.submit(q)
    bat.run()
    assert len(bat.finished) == 11
    assert bat.traces == 1
    for q in qs:
        _assert_matches_reference(q, scheme)


def test_continuous_single_query_matches_unbatched():
    """A lone query in an 8-slot pool gets the same answer as unbatched."""
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=8)
    [q] = _queries(1, seed=2, q=0.3)
    bat.submit(q)
    bat.run()
    _assert_matches_reference(q, scheme)


def test_continuous_budget_exhaustion_matches_fixed_d():
    """A query that cannot fully decode within the budget retires with the
    same unresolved set and gradient as the fixed-budget reference."""
    scheme = _scheme(decode_iters=2)    # tiny budget: q=0.3 won't finish
    bat = CodedQueryBatcher(scheme, n_slots=2, rounds_per_launch=1)
    qs = _queries(4, seed=5, q=0.3)
    for q in qs:
        bat.submit(q)
    bat.run()
    assert any(q.unresolved > 0 for q in qs)
    for q in qs:
        assert q.rounds <= 2
        _assert_matches_reference(q, scheme)


def test_continuous_requires_engine_backed_scheme():
    class BatchOnly:
        def gradient_batch(self, th, m):
            return th, m

    with pytest.raises(TypeError):
        CodedQueryBatcher(BatchOnly(), mode="continuous")


# ------------------------------------------- priority-weighted round budgets


def test_priority_scales_per_launch_chunk():
    """A high-priority heavy query burns its budget in fewer launches than
    the same query at default priority — same total rounds, same answer."""
    qs = _heavy_light_queries(2, heavy_ids={0, 1}, seed=7)
    lo, hi = qs[0], qs[1]
    hi.theta, hi.straggler_mask = lo.theta.copy(), lo.straggler_mask.copy()
    hi.priority = 3.0
    results = {}
    for q in (lo, hi):
        scheme = _scheme(decode_iters=12)
        bat = CodedQueryBatcher(scheme, n_slots=2, rounds_per_launch=2)
        bat.submit(q)
        bat.run()
        results[q.qid] = q
    assert hi.done and lo.done
    assert hi.launches < lo.launches            # 6-round chunks vs 2-round
    assert hi.rounds == lo.rounds               # same decode trajectory
    np.testing.assert_allclose(hi.gradient, lo.gradient, rtol=1e-6)
    assert hi.unresolved == lo.unresolved


def test_priority_mixed_pool_urgent_finishes_first():
    """Two identical heavy queries in one pool: the urgent one retires in
    an earlier launch; both still match the unbatched reference."""
    scheme = _scheme(decode_iters=12)
    bat = CodedQueryBatcher(scheme, n_slots=2, rounds_per_launch=2)
    qs = _heavy_light_queries(2, heavy_ids={0, 1}, seed=8)
    qs[1].theta = qs[0].theta.copy()
    qs[1].straggler_mask = qs[0].straggler_mask.copy()
    qs[1].priority = 4.0
    for q in qs:
        bat.submit(q)
    bat.run()
    assert qs[1].finished_launch < qs[0].finished_launch
    for q in qs:
        _assert_matches_reference(q, scheme)


def test_priority_default_is_uniform_chunking():
    """priority=1.0 queries behave exactly as before the scheduler."""
    q = _queries(1, seed=9, q=0.25)[0]
    assert q.priority == 1.0
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=2, rounds_per_launch=3)
    bat.submit(q)
    bat.run()
    assert bat.pool.default_chunk == 3
    _assert_matches_reference(q, scheme)


# ------------------------------------------------------- slot pool lifecycle


def test_slot_pool_state_machine():
    from repro.serving import SlotPool

    pool = SlotPool(3, budget=8, rounds_per_launch=4)
    assert pool.free_slots() == [0, 1, 2] and not pool.active
    pool.admit(0, "a")
    pool.admit(1, "b", chunk=2)
    with pytest.raises(ValueError):
        pool.admit(0, "c")                      # occupied
    with pytest.raises(ValueError):
        pool.admit(2, None)                     # None marks free slots
    budgets = pool.launch_budgets()
    assert budgets.tolist() == [4, 2, 0]
    # "a" early-exits (3 < 4 granted), "b" uses its full 2-round chunk
    retired = pool.account(np.array([3, 2, 0]), np.array([0, 5, 0]))
    assert retired == [(0, "a")]
    assert pool.owner(1) == "b" and pool.rounds_spent(1) == 2
    # "b" keeps going: grants min(chunk, remaining budget)
    pool.admit(2, "c", chunk=100)               # clamped by remaining budget
    budgets = pool.launch_budgets()
    assert budgets.tolist() == [0, 2, 8]
    # "b" grinds through its total budget in 2-round chunks; "c" burns its
    # whole clamped grant and retires on budget exhaustion
    pool.account(np.array([0, 2, 8]), np.array([0, 4, 3]))
    budgets = pool.launch_budgets()
    assert budgets.tolist() == [0, 2, 0]        # "c" retired at budget 8
    pool.account(np.array([0, 2, 0]), np.array([0, 3, 0]))   # used: 6 of 8
    budgets = pool.launch_budgets()
    assert budgets.tolist() == [0, 2, 0]
    retired = pool.account(np.array([0, 2, 0]), np.array([0, 3, 0]))
    assert retired == [(1, "b")] and not pool.active


def test_slot_pool_validates():
    from repro.serving import SlotPool

    with pytest.raises(ValueError):
        SlotPool(0, budget=4)
    with pytest.raises(ValueError):
        SlotPool(2, budget=4, rounds_per_launch=0)
