"""Serving-layer lockstep batcher for concurrent coded queries."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Scheme2, make_regular_ldpc, second_moment
from repro.data import make_linear_problem
from repro.serving import CodedQuery, CodedQueryBatcher

K = 60
PROB = make_linear_problem(m=256, k=K, seed=0)
CODE = make_regular_ldpc(K, l=3, r=6, seed=0)
MOM = second_moment(PROB.X, PROB.y)


def _scheme(backend="sparse"):
    return Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=8,
                         decode_backend=backend)


def _queries(n, seed=0, q=0.2):
    rng = np.random.default_rng(seed)
    return [CodedQuery(i, rng.standard_normal(K).astype(np.float32),
                       rng.random(CODE.N) < q) for i in range(n)]


def test_waves_flush_through_one_launch_each():
    bat = CodedQueryBatcher(_scheme(), n_slots=4)
    for q in _queries(10):
        bat.submit(q)
    done = bat.run()
    assert len(done) == 10
    assert all(q.done for q in done)
    # 10 queries, 4 slots -> ceil(10/4) = 3 batched launches, not 10
    assert bat.launches == 3
    assert not bat.active


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_batched_results_match_single_query_path(backend):
    scheme = _scheme(backend)
    bat = CodedQueryBatcher(scheme, n_slots=4)
    queries = _queries(6, seed=1)
    for q in queries:
        bat.submit(q)
    bat.run()
    for q in queries:
        g_ref, u_ref = scheme.gradient(jnp.asarray(q.theta),
                                       jnp.asarray(q.straggler_mask))
        assert q.unresolved == int(u_ref)
        np.testing.assert_allclose(q.gradient, np.asarray(g_ref),
                                   rtol=2e-3, atol=2e-3)


def test_partial_wave_padding_is_inert():
    """A lone query in an 8-slot wave gets the same answer as unbatched."""
    scheme = _scheme()
    bat = CodedQueryBatcher(scheme, n_slots=8)
    [q] = _queries(1, seed=2, q=0.3)
    bat.submit(q)
    bat.run()
    assert bat.launches == 1
    g_ref, u_ref = scheme.gradient(jnp.asarray(q.theta),
                                   jnp.asarray(q.straggler_mask))
    assert q.unresolved == int(u_ref)
    np.testing.assert_allclose(q.gradient, np.asarray(g_ref),
                               rtol=2e-3, atol=2e-3)


def test_submit_validates_shapes():
    bat = CodedQueryBatcher(_scheme(), n_slots=2)
    with pytest.raises(ValueError):
        bat.submit(CodedQuery(0, np.zeros(K + 1, np.float32),
                              np.zeros(CODE.N, bool)))
    with pytest.raises(ValueError):
        bat.submit(CodedQuery(0, np.zeros(K, np.float32),
                              np.zeros(CODE.N - 1, bool)))


def test_rejects_scheme_without_batch_api():
    class NoBatch:
        pass

    with pytest.raises(TypeError):
        CodedQueryBatcher(NoBatch())
