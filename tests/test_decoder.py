"""Peeling decoder correctness: every coordinate the decoder marks as
recovered must equal the true codeword coordinate — for ANY erasure pattern.
Plus capability, adaptivity, batching, and monotonicity-in-D properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # dev-only dep: degrade to per-test skips when missing
    from tests._hypothesis_compat import given, settings, st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.decoder import erased_after, peel_decode, peel_decode_adaptive
from repro.core.ldpc import make_ldgm, make_regular_ldpc

CODE = make_regular_ldpc(40, l=3, r=6, seed=0)


def _codeword(code, seed=0, V=None):
    rng = np.random.default_rng(seed)
    msg = rng.standard_normal((code.K,) if V is None else (code.K, V))
    return jnp.asarray(code.encode(msg), jnp.float32)


def test_no_erasures_identity():
    cw = _codeword(CODE)
    res = peel_decode(CODE, cw, jnp.zeros(CODE.N, bool), iters=5)
    np.testing.assert_allclose(res.values, cw, rtol=1e-6)
    assert not bool(res.erased.any())


@pytest.mark.parametrize("n_erase", [1, 2, 3, 5, 8])
def test_small_erasures_fully_recovered(n_erase):
    cw = _codeword(CODE, seed=1)
    rng = np.random.default_rng(n_erase)
    recovered_any = False
    for trial in range(10):
        idx = rng.choice(CODE.N, size=n_erase, replace=False)
        erased = np.zeros(CODE.N, bool)
        erased[idx] = True
        rx = jnp.where(jnp.asarray(erased), 0.0, cw)
        res = peel_decode(CODE, rx, jnp.asarray(erased), iters=CODE.N)
        # Invariant: every coordinate NOT marked erased is correct.
        ok = ~np.asarray(res.erased)
        np.testing.assert_allclose(np.asarray(res.values)[ok], np.asarray(cw)[ok],
                                   rtol=1e-4, atol=1e-4)
        if not res.erased.any():
            recovered_any = True
    assert recovered_any, "peeling never fully recovered even once — decoder broken"


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_recovered_coords_always_correct(data):
    """Hypothesis: arbitrary erasure patterns, arbitrary payloads — anything
    the decoder declares resolved must match the true codeword."""
    seed = data.draw(st.integers(0, 10_000))
    n_erase = data.draw(st.integers(0, CODE.N))
    rng = np.random.default_rng(seed)
    idx = rng.choice(CODE.N, size=n_erase, replace=False)
    erased = np.zeros(CODE.N, bool)
    erased[idx] = True
    cw = _codeword(CODE, seed=seed)
    rx = jnp.where(jnp.asarray(erased), 0.0, cw)
    D = data.draw(st.integers(0, 12))
    res = peel_decode(CODE, rx, jnp.asarray(erased), iters=D)
    ok = ~np.asarray(res.erased)
    # fp32 + Gaussian edge weights: a long peeling chain divides by small
    # coefficients, so per-coordinate error can reach ~1e-2 relative (pure
    # conditioning — the ±1-weight variant below is tight)
    np.testing.assert_allclose(np.asarray(res.values)[ok], np.asarray(cw)[ok],
                               rtol=3e-2, atol=3e-2)
    # erasures never increase, and newly-resolved set only shrinks the mask
    assert np.all(~np.asarray(res.erased) | erased)


PM1_CODE = make_regular_ldpc(40, l=3, r=6, seed=5, values="pm1")


@settings(max_examples=40, deadline=None)
@given(data=st.data())
def test_recovered_coords_exact_pm1_weights(data):
    """Same invariant with ±1 edge weights: every peeling division is by ±1,
    so recovery is numerically tight regardless of chain length."""
    seed = data.draw(st.integers(0, 10_000))
    n_erase = data.draw(st.integers(0, PM1_CODE.N))
    rng = np.random.default_rng(seed)
    idx = rng.choice(PM1_CODE.N, size=n_erase, replace=False)
    erased = np.zeros(PM1_CODE.N, bool)
    erased[idx] = True
    cw = _codeword(PM1_CODE, seed=seed)
    rx = jnp.where(jnp.asarray(erased), 0.0, cw)
    res = peel_decode(PM1_CODE, rx, jnp.asarray(erased), iters=PM1_CODE.N)
    ok = ~np.asarray(res.erased)
    np.testing.assert_allclose(np.asarray(res.values)[ok], np.asarray(cw)[ok],
                               rtol=2e-4, atol=2e-4)


def test_batched_payload_matches_scalar():
    V = 7
    cw = _codeword(CODE, seed=3, V=V)
    erased = np.zeros(CODE.N, bool)
    erased[[0, 5, 17, 33]] = True
    rx = jnp.where(jnp.asarray(erased)[:, None], 0.0, cw)
    res = peel_decode(CODE, rx, jnp.asarray(erased), iters=10)
    for v in range(V):
        res_v = peel_decode(CODE, rx[:, v], jnp.asarray(erased), iters=10)
        np.testing.assert_allclose(res.values[:, v], res_v.values, rtol=1e-5)
        np.testing.assert_array_equal(res.erased, res_v.erased)


def test_monotone_in_iterations():
    """|unresolved| is non-increasing in D (Remark 3's finite-n analogue)."""
    rng = np.random.default_rng(7)
    erased = rng.random(CODE.N) < 0.3
    counts = [int(erased_after(CODE, erased, d).sum()) for d in range(0, 15)]
    assert all(a >= b for a, b in zip(counts, counts[1:]))
    assert counts[0] == int(erased.sum())


def test_adaptive_matches_fixed_at_fixpoint():
    rng = np.random.default_rng(11)
    cw = _codeword(CODE, seed=11)
    erased = rng.random(CODE.N) < 0.25
    rx = jnp.where(jnp.asarray(erased), 0.0, cw)
    fixed = peel_decode(CODE, rx, jnp.asarray(erased), iters=CODE.N)
    adapt = peel_decode_adaptive(CODE, rx, jnp.asarray(erased))
    np.testing.assert_array_equal(fixed.erased, adapt.erased)
    ok = ~np.asarray(adapt.erased)
    np.testing.assert_allclose(np.asarray(adapt.values)[ok], np.asarray(fixed.values)[ok],
                               rtol=1e-5)
    # early exit: with 25% erasures it should not need anywhere near N rounds
    assert int(adapt.rounds_used) <= 20


def test_adaptive_zero_erasures_zero_rounds_cheap():
    cw = _codeword(CODE)
    adapt = peel_decode_adaptive(CODE, cw, jnp.zeros(CODE.N, bool))
    assert int(adapt.rounds_used) <= 1


def test_ldgm_decoding():
    code = make_ldgm(32, 16, row_weight=4, seed=0)
    cw = _codeword(code, seed=5)
    erased = np.zeros(code.N, bool)
    erased[[3, 9, 21]] = True  # systematic erasures; parity symbols known
    rx = jnp.where(jnp.asarray(erased), 0.0, cw)
    res = peel_decode(code, rx, jnp.asarray(erased), iters=code.N)
    ok = ~np.asarray(res.erased)
    np.testing.assert_allclose(np.asarray(res.values)[ok], np.asarray(cw)[ok], rtol=1e-4)


def test_decode_is_jittable_and_cached():
    cw = _codeword(CODE)
    erased = jnp.zeros(CODE.N, bool).at[4].set(True)
    rx = jnp.where(erased, 0.0, cw)
    f = jax.jit(lambda v, e: peel_decode(CODE, v, e, iters=6).values)
    np.testing.assert_allclose(f(rx, erased), peel_decode(CODE, rx, erased, 6).values,
                               rtol=1e-6)
