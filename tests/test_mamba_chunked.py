"""Chunked associative-scan Mamba == sequential scan (fp tolerance), for
every chunk size, with and without state handoff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import init_mamba, mamba_decode, mamba_forward


@pytest.fixture(scope="module")
def setup():
    d, B, S = 32, 2, 64
    p = init_mamba(jax.random.PRNGKey(0), d, d_state=8, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d)) * 0.3
    return p, x


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_sequential(setup, chunk):
    p, x = setup
    y_seq = mamba_forward(p, x, d_state=8)
    y_chk = mamba_forward(p, x, d_state=8, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)


def test_chunked_state_handoff_matches(setup):
    p, x = setup
    y1, st1 = mamba_forward(p, x, d_state=8, return_state=True)
    y2, st2 = mamba_forward(p, x, d_state=8, return_state=True, chunk=16)
    np.testing.assert_allclose(np.asarray(st2["h"]), np.asarray(st1["h"]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st2["conv"]), np.asarray(st1["conv"]),
                               rtol=1e-5, atol=1e-6)
    # and decode continues identically from either state
    xt = jax.random.normal(jax.random.PRNGKey(2), (x.shape[0], 1, x.shape[2]))
    o1, _ = mamba_decode(p, xt, st1, d_state=8)
    o2, _ = mamba_decode(p, xt, st2, d_state=8)
    np.testing.assert_allclose(np.asarray(o2), np.asarray(o1),
                               rtol=2e-4, atol=2e-4)


def test_chunk_not_dividing_falls_back(setup):
    p, x = setup  # S=64; chunk=24 does not divide -> sequential path
    y = mamba_forward(p, x, d_state=8, chunk=24)
    y_seq = mamba_forward(p, x, d_state=8)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_seq), rtol=1e-6)


def test_gradients_flow_through_chunked(setup):
    p, x = setup

    def loss(p_):
        return jnp.sum(mamba_forward(p_, x, d_state=8, chunk=16) ** 2)

    g = jax.grad(loss)(p)
    assert all(np.isfinite(np.asarray(l, np.float32)).all()
               for l in jax.tree.leaves(g))
