"""Decoder-backend parity: dense vs sparse vs fused-Pallas.

The contract (see core/decoder.py's backend matrix): every backend makes
bit-identical *decoding-trajectory* decisions — which checks are solvable,
which coordinate each solvable check resolves, and therefore the exact
erasure mask after every round — because solvability is an exact count of
erased neighbours and all backends resolve the first-erased-column
neighbour.  Decoded *values* agree up to f32 summation order (each backend
accumulates a check's row sum in a different association), so values are
compared with tight tolerances and, independently, against the true
codeword on recovered coordinates.

Shapes deliberately include non-multiples of 128 (the Pallas wrapper must
pad once and unpad exactly), scalar ``(N,)`` payloads, wide ``(N, V)``
payloads, and the all-erased / none-erased edge cases.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoder import (
    peel_decode,
    peel_decode_adaptive,
    peel_round,
    peel_round_sparse,
    resolve_backend,
)
from repro.core.ldpc import make_ldgm, make_regular_ldpc

BACKENDS = ("dense", "sparse", "pallas")


def _random_instance(code, *, V, q, seed):
    rng = np.random.default_rng(seed)
    msg = rng.standard_normal((code.K,) if V is None else (code.K, V))
    cw = jnp.asarray(code.encode(msg), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < q)
    rx = jnp.where(erased if cw.ndim == 1 else erased[:, None], 0.0, cw)
    return cw, rx, erased


def _assert_backend_parity(code, cw, rx, erased, iters):
    results = {
        b: peel_decode(code, rx, erased, iters, backend=b) for b in BACKENDS
    }
    ref = results["dense"]
    truth = np.asarray(cw)
    # The decode itself has f32 cancellation error vs the true codeword
    # (resolving values through chains of near-cancelling row sums); anchor
    # the truth tolerance to the dense reference's own deviation so this
    # stays a parity test, not a conditioning test.
    ok_ref = ~np.asarray(ref.erased)
    ref_dev = float(np.max(np.abs(np.asarray(ref.values)[ok_ref]
                                  - truth[ok_ref]), initial=0.0))
    truth_atol = max(5e-2, 3.0 * ref_dev)
    for name, res in results.items():
        # bit-for-bit: identical erasure trajectory endpoint & round count
        np.testing.assert_array_equal(
            np.asarray(res.erased), np.asarray(ref.erased),
            err_msg=f"backend={name}: erasure mask diverged")
        assert int(res.rounds_used) == iters
        assert res.values.shape == cw.shape
        # values: f32-summation-order agreement with the dense reference.
        # Anchored to the same conditioning measure as the truth check: on
        # an ill-conditioned instance the resolution chain amplifies each
        # backend's (different) per-round rounding by the same factor it
        # amplifies dense's deviation from the codeword.
        np.testing.assert_allclose(
            np.asarray(res.values), np.asarray(ref.values),
            rtol=truth_atol, atol=truth_atol,
            err_msg=f"backend={name}: values diverged from dense")
        # and every recovered coordinate matches the true codeword
        ok = ~np.asarray(res.erased)
        got = np.asarray(res.values)
        np.testing.assert_allclose(
            got[ok], truth[ok], rtol=truth_atol, atol=truth_atol,
            err_msg=f"backend={name}: recovered values != codeword")
    return ref


@pytest.mark.parametrize("K,V,q,seed", [
    (20, None, 0.25, 0),     # the paper's (40, 20) code, scalar payload
    (20, 3, 0.25, 1),        # tiny non-128 payload
    (40, None, 0.35, 2),
    (60, 8, 0.30, 3),        # N = 120: not a multiple of 128
    (100, 7, 0.40, 4),       # odd everything
    (128, 130, 0.30, 5),     # payload wider than one 128 tile
    (256, 1, 0.20, 6),       # explicit V=1 (not squeezed)
])
def test_backends_agree_on_regular_codes(K, V, q, seed):
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    cw, rx, erased = _random_instance(code, V=V, q=q, seed=seed)
    _assert_backend_parity(code, cw, rx, erased, iters=10)


@pytest.mark.parametrize("l,r,K", [(3, 6, 48), (4, 8, 64), (3, 9, 90)])
def test_backends_agree_across_degree_profiles(l, r, K):
    code = make_regular_ldpc(K, l=l, r=r, seed=11)
    cw, rx, erased = _random_instance(code, V=5, q=0.3, seed=13)
    _assert_backend_parity(code, cw, rx, erased, iters=8)


@pytest.mark.parametrize("seed", range(4))
def test_backends_agree_on_ldgm(seed):
    code = make_ldgm(32, 16, row_weight=4, seed=seed)
    cw, rx, erased = _random_instance(code, V=4, q=0.3, seed=seed + 50)
    _assert_backend_parity(code, cw, rx, erased, iters=6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_none_erased_is_identity(backend):
    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    cw, rx, _ = _random_instance(code, V=None, q=0.0, seed=0)
    res = peel_decode(code, rx, jnp.zeros(code.N, bool), 5, backend=backend)
    assert not bool(res.erased.any())
    np.testing.assert_array_equal(np.asarray(res.values), np.asarray(cw))


@pytest.mark.parametrize("backend", BACKENDS)
def test_all_erased_stays_erased(backend):
    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    erased = jnp.ones(code.N, bool)
    rx = jnp.zeros((code.N,), jnp.float32)
    res = peel_decode(code, rx, erased, 5, backend=backend)
    # no check ever has exactly one erased neighbour (r >= 2): nothing moves
    assert bool(res.erased.all())
    np.testing.assert_array_equal(np.asarray(res.values), np.asarray(rx))


def test_single_round_sparse_matches_dense_exactly_on_mask():
    """Round-level check, not just the D-round endpoint."""
    code = make_regular_ldpc(64, l=3, r=6, seed=7)
    rng = np.random.default_rng(7)
    cw = jnp.asarray(code.encode(rng.standard_normal((64, 4))), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < 0.3)
    rx = jnp.where(erased[:, None], 0.0, cw)
    H = jnp.asarray(code.H, jnp.float32)
    v_d, e_d = rx, erased
    v_s, e_s = rx, erased
    idx = jnp.asarray(code.check_idx)
    coeff = jnp.asarray(code.check_coeff)
    for _ in range(6):
        v_d, e_d = peel_round(H, jnp.asarray(code.H_mask), v_d, e_d)
        v_s, e_s = peel_round_sparse(idx, coeff, v_s, e_s)
        np.testing.assert_array_equal(np.asarray(e_d), np.asarray(e_s))
        # Values: the two rounds associate each check's row sum
        # differently (dense matvec vs the sparse compensated chain), so a
        # near-cancelling sum bounds the ABSOLUTE error of the resolved
        # value, not its relative error.
        np.testing.assert_allclose(np.asarray(v_d), np.asarray(v_s),
                                   rtol=1e-3, atol=1e-3)


def test_adaptive_sparse_matches_dense_rounds():
    code = make_regular_ldpc(100, l=3, r=6, seed=9)
    cw, rx, erased = _random_instance(code, V=None, q=0.25, seed=9)
    d = peel_decode_adaptive(code, rx, erased, backend="dense")
    s = peel_decode_adaptive(code, rx, erased, backend="sparse")
    assert int(d.rounds_used) == int(s.rounds_used)
    np.testing.assert_array_equal(np.asarray(d.erased), np.asarray(s.erased))
    np.testing.assert_allclose(np.asarray(d.values), np.asarray(s.values),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("K,q,seed", [(20, 0.25, 0), (64, 0.30, 7),
                                      (100, 0.05, 9), (60, 0.0, 1)])
def test_adaptive_pallas_matches_dense_stopping_rule(K, q, seed):
    """The fused adaptive kernel's in-kernel while_loop must reproduce the
    dense/sparse while_loops exactly: same round count (the early-exit
    'decoding effort tracks stragglers' knob) and same erasure endpoint."""
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    cw, rx, erased = _random_instance(code, V=3, q=q, seed=seed)
    d = peel_decode_adaptive(code, rx, erased, backend="dense")
    p = peel_decode_adaptive(code, rx, erased, backend="pallas")
    assert int(d.rounds_used) == int(p.rounds_used)
    np.testing.assert_array_equal(np.asarray(d.erased), np.asarray(p.erased))
    truth = np.asarray(cw)
    ok = ~np.asarray(d.erased)
    dev = float(np.max(np.abs(np.asarray(d.values)[ok] - truth[ok]),
                       initial=0.0))
    tol = max(5e-4, 3.0 * dev)
    np.testing.assert_allclose(np.asarray(p.values), np.asarray(d.values),
                               rtol=tol, atol=tol)


def test_adaptive_pallas_budget_respected():
    code = make_regular_ldpc(64, l=3, r=6, seed=3)
    cw, rx, erased = _random_instance(code, V=None, q=0.3, seed=3)
    res = peel_decode_adaptive(code, rx, erased, 1, backend="pallas")
    ref = peel_decode(code, rx, erased, 1, backend="dense")
    assert int(res.rounds_used) <= 1
    np.testing.assert_array_equal(np.asarray(res.erased),
                                  np.asarray(ref.erased))


def test_fused_decode_is_one_kernel_launch():
    """The whole fixed-D pallas decode must be a SINGLE pallas_call — the
    per-round relaunch (D launches, D re-pads) is exactly what PR 1
    removed."""
    from repro.kernels.ldpc_peel.ops import _peel_decode_impl

    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    H = jnp.asarray(code.H, jnp.float32)
    v = jnp.zeros((code.N, 4), jnp.float32)
    e = jnp.zeros((code.N,), bool)
    fn = _peel_decode_impl.__wrapped__  # un-jitted impl
    jaxpr = jax.make_jaxpr(
        lambda H, v, e: fn(H, v, e, iters=10, interpret=True))(H, v, e)
    assert str(jaxpr).count("pallas_call") == 1


def test_batched_and_adaptive_fused_decodes_are_one_kernel_launch():
    """The engine-era kernels keep the one-launch property: B patterns per
    launch (grid over the batch) and the adaptive early-exit decode
    (in-kernel while_loop) each lower to a single pallas_call."""
    from repro.kernels.ldpc_peel.ops import (_peel_decode_adaptive_impl,
                                             _peel_decode_batch_impl)

    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    H = jnp.asarray(code.H, jnp.float32)
    vB = jnp.zeros((6, code.N, 4), jnp.float32)
    eB = jnp.zeros((6, code.N), bool)
    fn = _peel_decode_batch_impl.__wrapped__
    jaxpr = jax.make_jaxpr(
        lambda H, v, e: fn(H, v, e, iters=10, interpret=True))(H, vB, eB)
    assert str(jaxpr).count("pallas_call") == 1

    v = jnp.zeros((code.N, 4), jnp.float32)
    e = jnp.zeros((code.N,), bool)
    fn = _peel_decode_adaptive_impl.__wrapped__
    jaxpr = jax.make_jaxpr(
        lambda H, v, e: fn(H, v, e, max_iters=40, interpret=True))(H, v, e)
    assert str(jaxpr).count("pallas_call") == 1


def test_neighbor_table_invariants():
    for code in (make_regular_ldpc(64, l=3, r=6, seed=1),
                 make_ldgm(32, 16, row_weight=4, seed=1)):
        idx, coeff = code.check_idx, code.check_coeff
        p = code.p
        assert idx.shape == coeff.shape and idx.shape[0] == p
        assert idx.dtype == np.int32 and coeff.dtype == np.float32
        mask = code.H != 0.0
        r_max = idx.shape[1]
        assert r_max == int(mask.sum(axis=1).max())
        for i in range(p):
            cols = np.flatnonzero(mask[i])
            assert (idx[i, : cols.size] == cols).all()          # ascending
            assert (idx[i, cols.size:] == code.N).all()         # sentinel pad
            np.testing.assert_array_equal(coeff[i, : cols.size],
                                          code.H[i, cols].astype(np.float32))
            assert (coeff[i, cols.size:] == 0.0).all()
        # column-side table (the scatter-free batched round's gather table)
        vidx = code.var_idx
        assert vidx.shape[0] == code.N and vidx.dtype == np.int32
        assert vidx.shape[1] == int(mask.sum(axis=0).max())
        for j in range(code.N):
            rows = np.flatnonzero(mask[:, j])
            assert (vidx[j, : rows.size] == rows).all()
            assert (vidx[j, rows.size:] == p).all()


def test_resolve_backend_matrix():
    code = make_regular_ldpc(20, l=3, r=6, seed=0)       # N = 40 (small)
    big = make_regular_ldpc(256, l=3, r=6, seed=0)       # N = 512
    on_cpu = jax.default_backend() != "tpu"
    if on_cpu:
        assert resolve_backend("auto", code) == "dense"
        assert resolve_backend("auto", big) == "sparse"
    for b in ("dense", "sparse", "pallas", "pallas_tiled"):
        assert resolve_backend(b, code) == b
    # since the fused adaptive kernel landed, adaptive keeps pallas
    assert resolve_backend("pallas", code, adaptive=True) == "pallas"
    # raw (H, Hb) tuples: dense only
    tup = (jnp.asarray(code.H, jnp.float32), jnp.asarray(code.H_mask))
    assert resolve_backend("auto", tup) == "dense"
    with pytest.raises(ValueError):
        resolve_backend("sparse", tup)
    with pytest.raises(ValueError):
        resolve_backend("nope", code)
    # the VMEM estimate the TPU "auto" dispatch uses: the old N<=512
    # resident cutoff falls out of the default 8 MiB budget at rate 1/2
    from repro.core.decoder import (_DEFAULT_VMEM_BUDGET_BYTES,
                                    vmem_bytes_estimate)
    assert vmem_bytes_estimate(big) <= _DEFAULT_VMEM_BUDGET_BYTES
    assert vmem_bytes_estimate((1024, 2048)) > _DEFAULT_VMEM_BUDGET_BYTES


def test_tuple_code_still_decodes_dense():
    """Back-compat: callers passing raw (H, Hb) keep working via dense."""
    code = make_regular_ldpc(40, l=3, r=6, seed=2)
    cw, rx, erased = _random_instance(code, V=None, q=0.25, seed=2)
    ref = peel_decode(code, rx, erased, 8, backend="dense")
    tup = (jnp.asarray(code.H, jnp.float32), jnp.asarray(code.H_mask))
    got = peel_decode(tup, rx, erased, 8)
    np.testing.assert_array_equal(np.asarray(got.erased), np.asarray(ref.erased))
    np.testing.assert_array_equal(np.asarray(got.values), np.asarray(ref.values))
