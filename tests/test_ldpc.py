"""LDPC/LDGM construction invariants."""
import numpy as np
import pytest
try:  # dev-only dep: degrade to per-test skips when missing
    from tests._hypothesis_compat import given, settings, st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core.ldpc import make_ldgm, make_regular_ldpc


@pytest.mark.parametrize("K,l,r", [(20, 3, 6), (100, 3, 6), (64, 4, 8), (90, 3, 9), (200, 3, 6)])
def test_regular_ldpc_structure(K, l, r):
    code = make_regular_ldpc(K, l=l, r=r, seed=1)
    p = K * l // (r - l)
    assert code.N == K + p and code.K == K and code.p == p
    # exact (l, r)-regularity
    mask = code.H_mask
    assert (mask.sum(axis=0) == l).all()
    assert (mask.sum(axis=1) == r).all()
    # simple graph: no duplicate edges by construction (boolean adjacency)
    # systematic generator
    assert np.allclose(code.G[:K], np.eye(K))
    # H G = 0 (valid code)
    assert np.allclose(code.H @ code.G, 0.0, atol=1e-8 * K)


def test_regular_ldpc_rate_half_matches_paper():
    # the paper's (40, 20) rate-1/2 code
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    assert (code.N, code.K) == (40, 20)
    assert code.rate == 0.5


def test_encode_systematic():
    code = make_regular_ldpc(32, l=3, r=6, seed=2)
    rng = np.random.default_rng(0)
    msg = rng.standard_normal((32, 5))
    cw = code.encode(msg)
    assert cw.shape == (code.N, 5)
    assert np.allclose(cw[:32], msg)
    assert np.allclose(code.H @ cw, 0.0, atol=1e-6)


@pytest.mark.parametrize("values", ["gaussian", "pm1"])
def test_edge_values(values):
    code = make_regular_ldpc(20, l=3, r=6, seed=3, values=values)
    nz = code.H[code.H_mask]
    if values == "pm1":
        assert np.all(np.isin(nz, [-1.0, 1.0]))
    else:
        assert np.std(nz) > 0.1


@settings(max_examples=20, deadline=None)
@given(K=st.sampled_from([12, 24, 36, 48, 60]), seed=st.integers(0, 1000))
def test_regular_ldpc_property(K, seed):
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    assert np.allclose(code.H @ code.G, 0.0, atol=1e-7 * K)
    assert (code.H_mask.sum(axis=0) == 3).all()
    assert (code.H_mask.sum(axis=1) == 6).all()


@pytest.mark.parametrize("K,p,rw", [(16, 8, 4), (64, 32, 4), (100, 50, 5), (8, 4, 3)])
def test_ldgm_structure(K, p, rw):
    code = make_ldgm(K, p, row_weight=rw, seed=0)
    assert code.N == K + p
    P = code.G[K:]
    assert ((P != 0).sum(axis=1) == rw).all()  # sparse parity rows
    # balanced column degrees (differ by at most 1)
    cd = (P != 0).sum(axis=0)
    assert cd.max() - cd.min() <= 1
    assert np.allclose(code.H @ code.G, 0.0, atol=1e-8)
    # parity-check structure [P, -I]
    assert np.allclose(code.H[:, K:], -np.eye(p))


def test_bad_params_raise():
    with pytest.raises(ValueError):
        make_regular_ldpc(20, l=6, r=6)
    with pytest.raises(ValueError):
        make_regular_ldpc(21, l=3, r=7)  # 21*3 % 4 != 0
    with pytest.raises(ValueError):
        make_ldgm(4, 2, row_weight=9)
