"""Integration: the dry-run machinery end-to-end on a tiny (2,2) placeholder
mesh in a SUBPROCESS (the 4-device XLA flag must be set before jax init, so
it cannot run in this process).  One arch per step-kind plus the sharding
spec unit checks that don't need devices.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import pytest

REPO = Path(__file__).resolve().parents[1]


def _run_dryrun(arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--reduced"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"dryrun failed:\n{res.stdout}\n{res.stderr}"
    return res.stdout


@pytest.mark.parametrize("arch,shape", [
    ("qwen3-1.7b", "train_4k"),          # train step
    ("deepseek-v2-236b", "decode_32k"),  # MLA decode w/ cache shardings
    ("jamba-1.5-large-398b", "prefill_32k"),  # hybrid prefill
])
def test_dryrun_reduced_mesh(arch, shape):
    out = _run_dryrun(arch, shape)
    assert "dry-run OK" in out
    assert "cost_analysis" in out


def test_param_shardings_divisibility():
    """Every generated spec must divide its dimension (the rule that makes
    all 40 x 2 combinations lower)."""
    from repro.configs import get_config
    from repro.launch.mesh import make_abstract_mesh
    from repro.models import Model
    from repro.sharding import make_param_shardings

    # device-free abstract mesh (signature-compat across JAX versions)
    mesh = make_abstract_mesh((16, 16), ("data", "model"))

    for name in ("qwen3-1.7b", "qwen2-1.5b", "kimi-k2-1t-a32b", "rwkv6-3b",
                 "whisper-medium"):
        cfg = get_config(name)
        model = Model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        shardings = make_param_shardings(cfg, shapes, mesh)

        def check(path, leaf, sh):
            spec = sh.spec
            for dim, part in enumerate(spec):
                if part is None:
                    continue
                axes = part if isinstance(part, tuple) else (part,)
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                assert leaf.shape[dim] % size == 0, \
                    f"{name} {path}: dim {dim} ({leaf.shape[dim]}) % {size}"

        jax.tree_util.tree_map_with_path(
            lambda p, l, s: check(p, l, s), shapes, shardings)


def test_paper_dryrun_pallas_variant():
    """The fused-kernel decode variant must lower+compile in the AOT
    roofline comparison alongside dense/dense-fused/sparse (H replicated,
    interpret-mode lowering off-TPU)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.paper_dryrun", "--k", "1024",
         "--K", "512", "--decode-iters", "4", "--decode", "pallas"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"paper_dryrun failed:\n{res.stdout}\n{res.stderr}"
    assert "scheme2-k1024-D4-f32-pallas" in res.stdout
    assert "roofline:" in res.stdout
    out = json.loads((REPO / "artifacts" / "dryrun" /
                      "paper-coded-gd__scheme2-k1024-D4-f32-pallas__16_16.json"
                      ).read_text())
    assert out["ok"] and out["shape"].endswith("-pallas")


def test_paper_dryrun_seeded_gather_variant():
    """``--seeded --seeded-mode gather`` lowers+compiles the seeded decode
    with edge-proportional gather rounds and tags the roofline artifact
    with the ``-gather`` suffix."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.paper_dryrun", "--k", "1024",
         "--K", "512", "--decode-iters", "4", "--decode", "pallas",
         "--seeded", "--seeded-mode", "gather"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"paper_dryrun failed:\n{res.stdout}\n{res.stderr}"
    assert "scheme2-k1024-D4-f32-pallas-seeded-gather" in res.stdout
    out = json.loads((REPO / "artifacts" / "dryrun" /
                      "paper-coded-gd__scheme2-k1024-D4-f32-pallas-seeded"
                      "-gather__16_16.json").read_text())
    assert out["ok"] and out["shape"].endswith("-gather")


def test_paper_dryrun_pipeline_fold_step():
    """``--pipeline`` lowers+analyzes the late-fold program alongside the
    main step, on the distributed (workers, data) mesh layout."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env.pop("XLA_FLAGS", None)
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.paper_dryrun", "--k", "1024",
         "--K", "512", "--decode-iters", "4", "--decode", "sparse",
         "--distributed", "--pipeline"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"paper_dryrun failed:\n{res.stdout}\n{res.stderr}"
    assert "scheme2-k1024-D4-f32-sparse-dist-fold" in res.stdout
    out = json.loads((REPO / "artifacts" / "dryrun" /
                      "paper-coded-gd__scheme2-k1024-D4-f32-sparse-dist-fold"
                      "__16w_16d.json").read_text())
    assert out["ok"] and out["shape"].endswith("-fold")


def test_input_specs_all_shapes():
    from repro.configs import get_config
    from repro.launch.specs import SHAPES, input_specs
    from repro.models import Model

    for arch in ("qwen3-1.7b", "whisper-medium", "internvl2-2b", "rwkv6-3b",
                 "deepseek-v2-236b"):
        cfg = get_config(arch)
        model = Model(cfg)
        for shape in SHAPES:
            kind, specs = input_specs(cfg, shape, model)
            leaves = jax.tree.leaves(specs)
            assert leaves and all(isinstance(l, jax.ShapeDtypeStruct)
                                  for l in leaves)
            if kind == "train":
                assert specs["batch"]["tokens"].shape[0] == SHAPES[shape].batch


def test_long500k_window_policy():
    from repro.configs import get_config
    from repro.launch.specs import SHAPES, decode_window

    # plain attention archs -> sliding window; MLA -> full latent; SSM irrelevant
    assert decode_window(get_config("qwen3-1.7b"), SHAPES["long_500k"]) == 8192
    assert decode_window(get_config("deepseek-v2-236b"), SHAPES["long_500k"]) is None
    assert decode_window(get_config("qwen3-1.7b"), SHAPES["decode_32k"]) is None
