"""Edge-proportional seeded decode rounds (``seeded_mode="gather"``) and
the fused seeded encode kernel.

The gather round generates only the r (column, weight) pairs per check row
from the seed — per-round FLOPs O(p·r) instead of the dense regenerated
tile's O(p·N) — and merges resolutions with the same first-tile-wins rule.
The trajectory (erasure masks + round counts) depends only on
integer-exact quantities, so it is bit-identical to the dense-tile round
and to every materialized backend; VALUES agree up to f32 summation order
(repo convention), with originally-known coordinates untouched bit for bit.

The fused encode kernel (``encode_seeded_fused_pallas`` /
``repro.core.encoding.encode_seeded``) regenerates generator gather
indices in-register and runs the per-row gather-sum in table order — bit
identical to the JIT-COMPILED sequential :func:`gather_encode` (XLA
contracts mul+add to FMA under jit on every backend, so the eager NumPy
sum is NOT the reference; the kernel and the jitted sequential gather
lower to the same FMA chain).

These tests pin:

* all four decode entry points at N = 8192 (interpret mode): gather
  trajectories bit-identical to dense-tile AND to the sparse backend;
* gather values allclose to dense-tile, known coordinates bit-equal;
* ragged/padded tiles (bp not dividing p, bp > p, padded columns);
* the one-``pallas_call`` property of every gather-mode decode and of the
  fused encode;
* the fused encode against the jitted table gather — full codeword,
  row windows, 1-D payloads, and the moment encode — plus
  ``Scheme2.build_seeded(encode_fused=True)``;
* the hwcaps crossover behind ``seeded_mode="auto"`` (gather on CPU,
  dense-tile where the modeled advantage is below ``mxu_advantage``) and
  the modeled ≥8× per-round FLOPs ratio at N = 16384 that CI gates on;
* the batched sparse decode's payload-lane layout: a (B, N, V) decode is
  the per-lane (B, N, 1) decode bit for bit (check-side structure work is
  per-pattern, broadcast over V).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Scheme2, second_moment
from repro.core.decoder import (
    SEEDED_MODES,
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
)
from repro.core.encoding import (
    encode_moment_seeded,
    encode_seeded,
    gather_encode,
    generator_gather_tables,
    generator_structure_of,
)
from repro.core.engine import CodedComputeEngine
from repro.core.hwcaps import (
    HardwareCaps,
    detect_caps,
    pick_seeded_mode,
    seeded_dense_round_flops,
    seeded_gather_round_flops,
)
from repro.core.ldpc import (
    make_seeded_ldgm,
    make_seeded_ldpc,
    seeded_generator_rows,
    seeded_structure,
)
from repro.data import make_linear_problem

D = 5


@functools.lru_cache(maxsize=None)
def _seeded_code(K):
    return make_seeded_ldpc(K, l=4, r=8, seed=0)


def _instance(code, *, q=0.25, seed=0, V=None):
    rng = np.random.default_rng(seed)
    shape = (code.N,) if V is None else (code.N, V)
    vals = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < q)
    rx = jnp.where(erased if V is None else erased[:, None], 0.0, vals)
    return rx, erased


def _assert_same_trajectory(got, ref):
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))


# ------------------------------------------------------- decode parity --


def test_gather_all_four_variants_at_8192():
    """The acceptance config: fixed, adaptive, batch, and batch-adaptive
    gather-mode decodes at N = 8192 (interpret mode), erasure trajectories
    bit-identical to the dense-tile seeded kernel AND to sparse."""
    code = _seeded_code(4096)
    kw = dict(backend="pallas_seeded", bp=512, bv=8)

    # fixed
    rx, erased = _instance(code, seed=2)
    sparse = peel_decode(code, rx, erased, D, backend="sparse")
    dense = peel_decode(code, rx, erased, D, seeded_mode="dense_tile", **kw)
    gath = peel_decode(code, rx, erased, D, seeded_mode="gather", **kw)
    _assert_same_trajectory(gath, sparse)
    _assert_same_trajectory(gath, dense)
    still = ~np.asarray(erased)  # originally-known coords: untouched bits
    np.testing.assert_array_equal(np.asarray(gath.values)[still],
                                  np.asarray(dense.values)[still])

    # adaptive: same fixpoint, same real round count
    sparse_a = peel_decode_adaptive(code, rx, erased, 16, backend="sparse")
    dense_a = peel_decode_adaptive(code, rx, erased, 16,
                                   seeded_mode="dense_tile", **kw)
    gath_a = peel_decode_adaptive(code, rx, erased, 16,
                                  seeded_mode="gather", **kw)
    _assert_same_trajectory(gath_a, sparse_a)
    _assert_same_trajectory(gath_a, dense_a)
    assert (int(gath_a.rounds_used) == int(sparse_a.rounds_used)
            == int(dense_a.rounds_used))

    # batch of independent patterns
    B = 3
    rng = np.random.default_rng(5)
    vals = jnp.asarray(rng.standard_normal((B, code.N)), jnp.float32)
    er_B = jnp.asarray(rng.random((B, code.N)) < 0.25)
    rx_B = jnp.where(er_B, 0.0, vals)
    sparse_b = peel_decode_batch(code, rx_B, er_B, D, backend="sparse")
    dense_b = peel_decode_batch(code, rx_B, er_B, D,
                                seeded_mode="dense_tile", **kw)
    gath_b = peel_decode_batch(code, rx_B, er_B, D,
                               seeded_mode="gather", **kw)
    _assert_same_trajectory(gath_b, sparse_b)
    _assert_same_trajectory(gath_b, dense_b)

    # batch-adaptive with traced per-slot budgets
    budgets = jnp.asarray([1, 3, 16], jnp.int32)
    sparse_ba = peel_decode_batch_adaptive(code, rx_B, er_B, 16,
                                           backend="sparse", budgets=budgets)
    dense_ba = peel_decode_batch_adaptive(code, rx_B, er_B, 16,
                                          budgets=budgets,
                                          seeded_mode="dense_tile", **kw)
    gath_ba = peel_decode_batch_adaptive(code, rx_B, er_B, 16,
                                         budgets=budgets,
                                         seeded_mode="gather", **kw)
    _assert_same_trajectory(gath_ba, sparse_ba)
    _assert_same_trajectory(gath_ba, dense_ba)
    np.testing.assert_array_equal(np.asarray(gath_ba.rounds_used),
                                  np.asarray(sparse_ba.rounds_used))
    np.testing.assert_array_equal(np.asarray(gath_ba.rounds_used),
                                  np.asarray(dense_ba.rounds_used))


def test_gather_values_close_known_exact():
    """Resolved VALUES agree with dense-tile up to f32 summation order
    (the gather sums edges per row; the tile contracts over N) — allclose,
    while the trajectory and the never-erased coordinates stay exact."""
    code = _seeded_code(1024)
    rx, erased = _instance(code, seed=1, V=4)
    dense = peel_decode(code, rx, erased, D, backend="pallas_seeded",
                        bv=8, seeded_mode="dense_tile")
    gath = peel_decode(code, rx, erased, D, backend="pallas_seeded",
                       bv=8, seeded_mode="gather")
    _assert_same_trajectory(gath, dense)
    np.testing.assert_allclose(np.asarray(gath.values),
                               np.asarray(dense.values),
                               rtol=1e-5, atol=1e-5)
    still = ~np.asarray(erased)
    np.testing.assert_array_equal(np.asarray(gath.values)[still],
                                  np.asarray(dense.values)[still])


@pytest.mark.parametrize("bp", [88, 128, 4096])
def test_gather_ragged_and_oversized_tiles(bp):
    """Tile heights that do not divide p (ragged last tile) and tiles
    larger than p (single clamped tile) keep the exact trajectory —
    padded check rows generate zero edges by construction."""
    code = _seeded_code(512)  # p = 512, N = 1024
    rx, erased = _instance(code, seed=7)
    ref = peel_decode(code, rx, erased, D, backend="sparse")
    got = peel_decode(code, rx, erased, D, backend="pallas_seeded",
                      bp=bp, bv=8, seeded_mode="gather")
    _assert_same_trajectory(got, ref)


def test_gather_decodes_are_one_kernel_launch():
    """Every gather-mode decode keeps the one-``pallas_call`` property —
    edge generation and the segment-sum merge happen INSIDE the kernel."""
    from repro.kernels.ldpc_peel.ops import (
        _peel_decode_adaptive_seeded_impl,
        _peel_decode_batch_adaptive_seeded_impl,
        _peel_decode_batch_seeded_impl,
        _peel_decode_seeded_impl,
    )

    spec = seeded_structure(64, 128, 8, 0)
    v = jnp.zeros((128, 8), jnp.float32)
    e = jnp.zeros((128,), bool)
    vB = jnp.zeros((3, 128, 8), jnp.float32)
    eB = jnp.zeros((3, 128), bool)
    bud = jnp.full((3,), 5, jnp.int32)
    kw = dict(spec=spec, interpret=True, bp=32, bv=8, mode="gather")
    cases = [
        (_peel_decode_seeded_impl,
         lambda fn: fn(v, e, iters=D, **kw)),
        (_peel_decode_batch_seeded_impl,
         lambda fn: fn(vB, eB, iters=D, **kw)),
        (_peel_decode_adaptive_seeded_impl,
         lambda fn: fn(v, e, max_iters=16, **kw)),
        (_peel_decode_batch_adaptive_seeded_impl,
         lambda fn: fn(vB, eB, bud, **kw)),
    ]
    for impl, call in cases:
        jaxpr = jax.make_jaxpr(lambda fn=impl.__wrapped__, c=call: c(fn))()
        assert str(jaxpr).count("pallas_call") == 1, impl


def test_unknown_seeded_mode_rejected():
    code = _seeded_code(512)
    rx, erased = _instance(code, seed=0)
    with pytest.raises(ValueError):
        peel_decode(code, rx, erased, D, backend="pallas_seeded",
                    seeded_mode="bogus")
    with pytest.raises(ValueError):
        CodedComputeEngine(code, backend="pallas_seeded",
                           seeded_mode="bogus")


def test_engine_threads_seeded_mode():
    """The engine's seeded_mode knob reaches the decode: auto resolves to
    gather on CPU (mxu_advantage = 1), and the batched decode's trajectory
    matches the sparse engine's bit for bit."""
    code = _seeded_code(512)
    eng = CodedComputeEngine(code, decode_iters=D, backend="pallas_seeded",
                             bp=128, bv=8, seeded_mode="auto")
    assert eng.debug_info()["seeded_mode"] == "auto"
    ref = CodedComputeEngine(code, decode_iters=D, backend="sparse")
    rx, erased = _instance(code, seed=3)
    got = eng.decode(rx, erased)
    want = ref.decode(rx, erased)
    _assert_same_trajectory(got, want)


# ------------------------------------------------------- auto crossover --


def test_auto_crossover_follows_mxu_advantage():
    """CPU caps (advantage 1.0) always pick gather for real codes; a TPU-
    like advantage larger than the modeled ratio flips back to dense."""
    spec = seeded_structure(4096, 8192, 8, 0)
    assert pick_seeded_mode(
        spec, 8, caps=HardwareCaps("cpu", 1.0)) == "gather"
    # tiny code: dense/gather ratio ~2x < the 8x TPU placeholder advantage
    tiny = seeded_structure(8, 16, 8, 0)
    ratio = (seeded_dense_round_flops(tiny, 1)
             / seeded_gather_round_flops(tiny, 1))
    assert ratio < 8.0
    assert pick_seeded_mode(
        tiny, 1, caps=HardwareCaps("tpu", 8.0)) == "dense_tile"
    assert "auto" in SEEDED_MODES


def test_mxu_advantage_env_override(monkeypatch):
    """REPRO_MXU_ADVANTAGE replaces the TPU placeholder (read per call, so
    the monkeypatched env is seen immediately); CPU caps ignore it, and the
    default path still reports the placeholder when the var is unset."""
    from repro.core import hwcaps

    monkeypatch.delenv(hwcaps.MXU_ADVANTAGE_ENV, raising=False)
    assert detect_caps("tpu").mxu_advantage \
        == hwcaps.DEFAULT_TPU_MXU_ADVANTAGE
    assert detect_caps("cpu").mxu_advantage == 1.0

    monkeypatch.setenv(hwcaps.MXU_ADVANTAGE_ENV, "3.5")
    assert detect_caps("tpu").mxu_advantage == 3.5
    assert detect_caps("cpu").mxu_advantage == 1.0  # CPU stays scalar

    # a low measured advantage flips the tiny-code crossover back to gather
    tiny = seeded_structure(8, 16, 8, 0)
    ratio = (seeded_dense_round_flops(tiny, 1)
             / seeded_gather_round_flops(tiny, 1))
    monkeypatch.setenv(hwcaps.MXU_ADVANTAGE_ENV, str(ratio / 2))
    assert pick_seeded_mode(tiny, 1, caps=detect_caps("tpu")) == "gather"


@pytest.mark.parametrize("bad", ["fast", "", "0", "-2.0", "nan", "inf"])
def test_mxu_advantage_env_rejects_bad_values(monkeypatch, bad):
    from repro.core import hwcaps

    monkeypatch.setenv(hwcaps.MXU_ADVANTAGE_ENV, bad)
    with pytest.raises(ValueError, match=hwcaps.MXU_ADVANTAGE_ENV):
        detect_caps("tpu")
    # CPU detection never consults the override, so it cannot be broken
    assert detect_caps("cpu").mxu_advantage == 1.0


def test_modeled_flops_ratio_at_16384():
    """The CI-gated claim: at N = 16384 (p = 8192, V = 8, bp = 128) the
    dense-tile round models ≥ 8x the gather round's FLOPs."""
    spec = seeded_structure(8192, 16384, 8, 0)
    dense = seeded_dense_round_flops(spec, 8, bp=128)
    gather = seeded_gather_round_flops(spec, 8, bp=128)
    assert dense / gather >= 8.0


# --------------------------------------------------------- fused encode --


def test_fused_encode_matches_jitted_gather():
    """Full-codeword fused encode, bit-identical to the jit-compiled
    sequential table gather — 2-D payloads and 1-D vectors."""
    code = make_seeded_ldgm(128, 64, row_weight=8, seed=0)
    idx, coeff = generator_gather_tables(code)
    ref_fn = jax.jit(gather_encode)
    rng = np.random.default_rng(0)
    Y = jnp.asarray(rng.standard_normal((128, 5)), jnp.float32)
    np.testing.assert_array_equal(np.asarray(encode_seeded(code, Y)),
                                  np.asarray(ref_fn(idx, coeff, Y)))
    y = jnp.asarray(rng.standard_normal(128), jnp.float32)
    np.testing.assert_array_equal(np.asarray(encode_seeded(code, y)),
                                  np.asarray(ref_fn(idx, coeff, y)))
    # systematic prefix is an exact copy
    np.testing.assert_array_equal(np.asarray(encode_seeded(code, y))[:128],
                                  np.asarray(y))


def test_fused_encode_row_windows():
    """A worker's row window [row0, row0 + n_out) — including windows not
    aligned to any tile size — matches the jitted gather over the same
    regenerated table rows bit for bit."""
    code = make_seeded_ldgm(128, 64, row_weight=8, seed=3)
    rng = np.random.default_rng(4)
    y = jnp.asarray(rng.standard_normal((128, 3)), jnp.float32)
    ref_fn = jax.jit(gather_encode)
    for row0, n_out in [(0, 24), (84, 12), (128, 64), (160, 32)]:
        idx, coeff = seeded_generator_rows(code, row0, row0 + n_out)
        ref = ref_fn(jnp.asarray(idx), jnp.asarray(coeff), y)
        got = encode_seeded(code, y, row0, n_out=n_out)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fused_encode_matches_encode_moment_seeded():
    """The in-process acceptance claim: the fused kernel reproduces the
    jitted ``encode_moment_seeded`` (table gather) bit for bit."""
    code = make_seeded_ldgm(64, 32, row_weight=8, seed=0)
    rng = np.random.default_rng(1)
    M = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    ref = jax.jit(lambda m: encode_moment_seeded(code, m))(M)
    got = encode_seeded(code, M)
    assert got.shape == ref.shape == (code.N, 64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_fused_encode_is_one_kernel_launch():
    from repro.kernels.ldpc_peel.ops import _encode_seeded_fused_impl

    code = make_seeded_ldgm(128, 64, row_weight=8, seed=0)
    st = generator_structure_of(code)
    y = jnp.zeros((128, 3), jnp.float32)
    r0 = jnp.zeros((1, 1), jnp.int32)
    fn = _encode_seeded_fused_impl.__wrapped__
    jaxpr = jax.make_jaxpr(
        lambda y, r0: fn(y, r0, st=st, n_out=code.N, interpret=True))(y, r0)
    assert str(jaxpr).count("pallas_call") == 1


def test_generator_structure_requires_seeded_ldgm():
    with pytest.raises(ValueError):
        generator_structure_of(_seeded_code(512))  # parity code, not LDGM


def test_scheme2_encode_fused_matches_tables():
    """``Scheme2.build_seeded(encode_fused=True)``: the per-step codeword
    is bit-identical to the table-gather scheme's under jit, and the
    gradients track to f32 summation order with identical unresolved
    sets."""
    K = 64
    code = make_seeded_ldgm(K, 32, row_weight=8, seed=0)
    prob = make_linear_problem(m=4 * K, k=K, seed=0)
    mom = second_moment(prob.X, prob.y)
    tab = Scheme2.build_seeded(code, mom, lr=prob.lr, decode_iters=8,
                               decode_backend="sparse")
    fus = Scheme2.build_seeded(code, mom, lr=prob.lr, decode_iters=8,
                               decode_backend="sparse", encode_fused=True)
    assert fus.seeded_encode and fus.encode_fused
    rng = np.random.default_rng(2)
    theta = jnp.asarray(rng.standard_normal(K), jnp.float32)
    y = jnp.asarray(rng.standard_normal(K), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(jax.jit(fus._encode)(y)),
        np.asarray(jax.jit(tab._encode)(y)))
    mask = jnp.asarray(rng.random(code.N) < 0.25)
    g_t, u_t = tab.gradient(theta, mask)
    g_f, u_f = fus.gradient(theta, mask)
    np.testing.assert_allclose(np.asarray(g_f), np.asarray(g_t),
                               rtol=2e-4, atol=2e-4)
    assert int(u_f) == int(u_t)


# -------------------------------------------- sparse-batch payload lanes --


def test_sparse_batch_payload_lanes_bit_identical():
    """The batched sparse decode computes check-side structure work once
    per pattern and broadcasts it over V — a (B, N, V) decode must equal
    the per-lane (B, N, 1) decodes bit for bit (masks, values, rounds)."""
    code = _seeded_code(512)
    B, V = 3, 4
    rng = np.random.default_rng(11)
    vals = jnp.asarray(rng.standard_normal((B, code.N, V)), jnp.float32)
    er_B = jnp.asarray(rng.random((B, code.N)) < 0.3)
    rx_B = jnp.where(er_B[:, :, None], 0.0, vals)

    dec = peel_decode_batch(code, rx_B, er_B, D, backend="sparse")
    for v in range(V):
        lane = peel_decode_batch(code, rx_B[:, :, v:v + 1], er_B, D,
                                 backend="sparse")
        np.testing.assert_array_equal(np.asarray(dec.erased),
                                      np.asarray(lane.erased))
        np.testing.assert_array_equal(np.asarray(dec.values)[:, :, v],
                                      np.asarray(lane.values)[:, :, 0])

    budgets = jnp.asarray([1, 4, 16], jnp.int32)
    dec_a = peel_decode_batch_adaptive(code, rx_B, er_B, 16,
                                       backend="sparse", budgets=budgets)
    for v in range(V):
        lane = peel_decode_batch_adaptive(code, rx_B[:, :, v:v + 1], er_B,
                                          16, backend="sparse",
                                          budgets=budgets)
        np.testing.assert_array_equal(np.asarray(dec_a.erased),
                                      np.asarray(lane.erased))
        np.testing.assert_array_equal(np.asarray(dec_a.values)[:, :, v],
                                      np.asarray(lane.values)[:, :, 0])
        np.testing.assert_array_equal(np.asarray(dec_a.rounds_used),
                                      np.asarray(lane.rounds_used))
