"""check_regression CLI surface: the section registry, --list-sections,
and the unknown-section guard (a typo'd --sections in CI must fail loudly
instead of silently gating nothing)."""
import json
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import check_regression as cr  # noqa: E402


def _write_bench(path, data=None):
    path.write_text(json.dumps(data if data is not None else {
        "schema_version": 10, "results": []}))
    return str(path)


def test_list_sections_prints_registry(capsys):
    assert cr.main(["--list-sections"]) == 0
    out = capsys.readouterr().out
    for name, (desc, _) in cr.SECTIONS.items():
        assert name in out and desc in out


def test_list_sections_needs_no_files(capsys):
    # --list-sections must work without --baseline/--new (discoverability
    # from a clean checkout); plain invocation without them still errors
    assert cr.main(["--list-sections"]) == 0
    capsys.readouterr()
    with pytest.raises(SystemExit):
        cr.main([])


def test_unknown_section_fails(tmp_path, capsys):
    b = _write_bench(tmp_path / "b.json")
    assert cr.main(["--baseline", b, "--new", b,
                    "--sections", "batched,serving_typo"]) == 1
    out = capsys.readouterr().out
    assert "unknown sections" in out and "serving_typo" in out
    # ... and the known-sections hint lists the registry
    assert "batched" in out


def test_registry_covers_every_runner():
    # every section has a one-line description and a callable runner
    assert set(cr.SECTIONS) >= {"batched", "serving", "large_n", "seeded",
                                "seeded_gather", "replay", "distributed",
                                "pipeline", "obs"}
    for name, (desc, runner) in cr.SECTIONS.items():
        assert isinstance(desc, str) and desc
        assert callable(runner)


def test_empty_overlap_exits_one(tmp_path):
    # two benches with no comparable records -> None results -> exit 1
    b = _write_bench(tmp_path / "b.json")
    assert cr.main(["--baseline", b, "--new", b,
                    "--sections", "batched"]) == 1


def test_replay_self_comparison_passes():
    # the repo's checked-in bench vs itself: ratios are exactly 1.0 and
    # the hard replay floors hold -> exit 0
    bench = Path(__file__).resolve().parents[1] / "BENCH_decoder_scaling.json"
    if not bench.exists():
        pytest.skip("no checked-in benchmark json")
    data = json.loads(bench.read_text())
    if not data.get("replay"):
        pytest.skip("benchmark json has no replay section yet")
    assert cr.main(["--baseline", str(bench), "--new", str(bench),
                    "--sections", "replay"]) == 0
