"""Baseline schemes (uncoded / replication / Karakus / MDS-Lee / gradient
coding) — correctness and convergence under stragglers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FixedCountStragglers, run_pgd, second_moment
from repro.core.schemes import (
    GradientCodingFR,
    Karakus,
    MDSLee,
    Replication,
    Uncoded,
    hadamard_matrix,
)
from repro.data import make_linear_problem

W = 40
PROB = make_linear_problem(m=2048, k=100, seed=0)
MOM = second_moment(PROB.X, PROB.y)
NORM = float(jnp.linalg.norm(PROB.theta_star))


def exact_grad(theta):
    return MOM.M @ theta - MOM.b


def test_uncoded_no_stragglers_is_exact():
    sch = Uncoded(PROB.X, PROB.y, w=W, lr=PROB.lr)
    theta = jax.random.normal(jax.random.PRNGKey(0), (100,))
    g, _ = sch.gradient(theta, jnp.zeros(W, bool))
    np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-3, atol=1e-3)


def test_uncoded_converges_with_stragglers():
    sch = Uncoded(PROB.X, PROB.y, w=W, lr=PROB.lr)
    res = run_pgd(sch, jnp.zeros(100), FixedCountStragglers(10), steps=600,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(1))
    assert float(res.errors[-1]) < 0.05 * NORM


def test_replication_covers_single_straggler_per_pair():
    sch = Replication(PROB.X, PROB.y, w=W, lr=PROB.lr, r=2)
    theta = jax.random.normal(jax.random.PRNGKey(2), (100,))
    # stragglers all in the first replica set -> every partition still covered
    mask = jnp.zeros(W, bool).at[jnp.arange(0, W // 2)].set(True)
    g, lost = sch.gradient(theta, mask)
    assert int(lost) == 0
    np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-3, atol=1e-3)
    # both replicas of partition 0 straggle -> partition lost
    mask2 = jnp.zeros(W, bool).at[jnp.array([0, W // 2])].set(True)
    _, lost2 = sch.gradient(theta, mask2)
    assert int(lost2) == 1


def test_replication_converges():
    sch = Replication(PROB.X, PROB.y, w=W, lr=PROB.lr, r=2)
    res = run_pgd(sch, jnp.zeros(100), FixedCountStragglers(10), steps=600,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(3))
    assert float(res.errors[-1]) < 0.05 * NORM


def test_hadamard_matrix():
    H = hadamard_matrix(8)
    np.testing.assert_allclose(H @ H.T, 8 * np.eye(8))


@pytest.mark.parametrize("kind", ["hadamard", "gaussian"])
def test_karakus_converges(kind):
    sch = Karakus.build(PROB.X, PROB.y, W, lr=PROB.lr * 0.8, kind=kind, seed=0)
    res = run_pgd(sch, jnp.zeros(100), FixedCountStragglers(10), steps=800,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(4))
    assert float(res.errors[-1]) < 0.1 * NORM


def test_karakus_no_straggler_unbiased_direction():
    sch = Karakus.build(PROB.X, PROB.y, W, lr=PROB.lr, kind="gaussian", seed=1)
    theta = jax.random.normal(jax.random.PRNGKey(5), (100,))
    g, _ = sch.gradient(theta, jnp.zeros(W, bool))
    gt = exact_grad(theta)
    cos = float(g @ gt / (jnp.linalg.norm(g) * jnp.linalg.norm(gt)))
    assert cos > 0.95  # S^T S ≈ I/m-scaled: encoded gradient tracks the true one


def test_mds_lee_exact_below_capability():
    # K_code kept small: real-Vandermonde conditioning degrades exponentially
    # in the code dimension — exactly the noise-stability issue the paper
    # raises against MDS-coded schemes (Section 1). At K_code=8 fp32 still
    # recovers; test_mds_lee_conditioning_degrades shows the blow-up.
    sch = MDSLee.build(PROB.X, PROB.y, W, lr=PROB.lr, K_code=8)
    theta = jax.random.normal(jax.random.PRNGKey(6), (100,))
    mask = jnp.zeros(W, bool).at[jnp.array([2, 15, 31])].set(True)
    g, _ = sch.gradient(theta, mask)
    gt = exact_grad(theta)
    cos = float(g @ gt / (jnp.linalg.norm(g) * jnp.linalg.norm(gt)))
    assert cos > 0.99


def test_mds_lee_conditioning_degrades():
    """The paper's criticism of Vandermonde-based MDS schemes, demonstrated:
    recovery error grows with code dimension at fixed precision."""
    theta = jax.random.normal(jax.random.PRNGKey(10), (100,))
    mask = jnp.zeros(W, bool)
    gt = exact_grad(theta)

    def err(Kc):
        sch = MDSLee.build(PROB.X, PROB.y, W, lr=PROB.lr, K_code=Kc)
        g, _ = sch.gradient(theta, mask)
        return float(jnp.linalg.norm(g - gt) / jnp.linalg.norm(gt))

    assert err(24) > err(6)


def test_gradient_coding_fr_exact_any_s_stragglers():
    s = 3
    sch = GradientCodingFR(PROB.X, PROB.y, w=W, s=s, lr=PROB.lr)
    theta = jax.random.normal(jax.random.PRNGKey(7), (100,))
    mask = jnp.zeros(W, bool).at[jnp.array([0, 11, 25])].set(True)  # any 3
    g, lost = sch.gradient(theta, mask)
    assert int(lost) == 0
    np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-3, atol=1e-3)


def test_gradient_coding_converges():
    sch = GradientCodingFR(PROB.X, PROB.y, w=W, s=3, lr=PROB.lr)
    res = run_pgd(sch, jnp.zeros(100), FixedCountStragglers(3), steps=400,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(8))
    assert float(res.errors[-1]) < 0.05 * NORM
