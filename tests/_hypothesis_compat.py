"""Graceful degradation when ``hypothesis`` is not installed.

The property-based tests (test_decoder / test_extensions / test_ldpc /
test_optim) use hypothesis, which is a dev-only dependency
(requirements-dev.txt).  A bare ``from hypothesis import ...`` makes those
whole modules UNCOLLECTABLE when it is missing — taking every plain pytest
test in them down too.

Import from this module instead::

    from tests._hypothesis_compat import given, settings, st, hnp

When hypothesis is installed these are the real objects.  When it is not,
``given``/``settings`` decorate the test to call
``pytest.importorskip("hypothesis")`` at run time (so only the property
tests skip, with a clear reason), and ``st``/``hnp`` are inert stand-ins
whose attribute/call chains (``st.floats(...)``, ``hnp.arrays(...)``)
resolve to placeholders so module-level strategy definitions still import.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which env runs the suite
    from hypothesis import given, settings
    from hypothesis import strategies as st
    try:
        from hypothesis.extra import numpy as hnp
    except ImportError:  # hypothesis without the numpy extra
        hnp = None
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    class _Stub:
        """Inert strategy namespace: any attribute/call returns a stub."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _Stub()
    hnp = _Stub()

    def _skipping_decorator(*dargs, **dkwargs):
        def deco(fn):
            # NOTE: deliberately no functools.wraps — the replacement must
            # have an EMPTY signature, or pytest treats the property-test
            # arguments as missing fixtures instead of skipping.
            def wrapper():
                import pytest

                pytest.importorskip(
                    "hypothesis",
                    reason="property test needs hypothesis "
                           "(pip install -r requirements-dev.txt)",
                )

            wrapper.__name__ = getattr(fn, "__name__", "property_test")
            wrapper.__doc__ = getattr(fn, "__doc__", None)
            return wrapper

        return deco

    given = _skipping_decorator
    settings = _skipping_decorator

__all__ = ["given", "settings", "st", "hnp", "HAVE_HYPOTHESIS"]
