"""Scheme 1 / Scheme 2 behaviour: exactness, unbiasedness (Lemma 1),
convergence (Theorem 1-style), and the sparse-recovery (IHT) path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdversarialStragglers,
    BernoulliStragglers,
    FixedCountStragglers,
    Scheme1,
    Scheme2,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.core.density_evolution import q_final
from repro.data import make_linear_problem, make_sparse_problem
from repro.optim import projections

CODE = make_regular_ldpc(200, l=3, r=6, seed=0)  # (400, 200): w=400 workers
PROB = make_linear_problem(m=512, k=200, seed=1)
MOM = second_moment(PROB.X, PROB.y)


def exact_grad(theta):
    return MOM.M @ theta - MOM.b


def test_scheme2_no_stragglers_equals_gd():
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=5)
    theta = jnp.zeros(200)
    mask = jnp.zeros(CODE.N, bool)
    g, unresolved = s2.gradient(theta, mask)
    np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-3, atol=1e-4)
    assert int(unresolved) == 0


def test_scheme2_few_stragglers_exact_after_decode():
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=CODE.N)
    theta = jax.random.normal(jax.random.PRNGKey(0), (200,))
    mask = jnp.zeros(CODE.N, bool).at[jnp.array([1, 50, 399])].set(True)
    g, unresolved = s2.gradient(theta, mask)
    if int(unresolved) == 0:  # peeling recovered everything
        np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-3, atol=1e-3)
    else:  # decoded-or-zero coordinates only
        gt = np.asarray(exact_grad(theta))
        got = np.asarray(g)
        nz = got != 0.0
        np.testing.assert_allclose(got[nz], gt[nz], rtol=1e-3, atol=1e-3)


def test_lemma1_unbiasedness():
    """E[ĝ] = (1 - q_D) ∇L(θ) under Bernoulli stragglers (Monte Carlo)."""
    q0, D = 0.1, 4
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=D)
    theta = jax.random.normal(jax.random.PRNGKey(1), (200,)) * 0.3
    model = BernoulliStragglers(q0)

    @jax.jit
    def one(key):
        g, u = s2.gradient(theta, model.sample(key, CODE.N))
        return g

    keys = jax.random.split(jax.random.PRNGKey(2), 600)
    gs = jax.vmap(one)(keys)
    mean_g = np.asarray(gs.mean(axis=0))
    gt = np.asarray(exact_grad(theta))
    # Per-coordinate scale: should match (1 - q_emp) for the FINITE code,
    # which density evolution approximates. Fit the scale and check both.
    scale = float(mean_g @ gt / (gt @ gt))
    qD = q_final(q0, CODE.l, CODE.r, D)
    assert 0 < scale <= 1.001
    assert abs(scale - (1 - qD)) < 0.08, (scale, 1 - qD)
    # direction match
    cos = mean_g @ gt / (np.linalg.norm(mean_g) * np.linalg.norm(gt))
    assert cos > 0.99


@pytest.mark.parametrize("q0", [0.0, 0.1, 0.2])
def test_scheme2_converges_bernoulli(q0):
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=8)
    res = run_pgd(s2, jnp.zeros(200), BernoulliStragglers(q0), steps=400,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(3))
    assert float(res.errors[-1]) < 0.05 * float(jnp.linalg.norm(PROB.theta_star))
    # averaged iterate also converges (Theorem 1 is stated for it)
    assert float(jnp.linalg.norm(res.theta_bar - PROB.theta_star)) < \
        0.2 * float(jnp.linalg.norm(PROB.theta_star))


def test_scheme2_fixed_count_converges():
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=8)
    res = run_pgd(s2, jnp.zeros(200), FixedCountStragglers(40), steps=400,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(4))
    assert float(res.errors[-1]) < 0.05 * float(jnp.linalg.norm(PROB.theta_star))


def test_scheme2_more_decode_iters_not_worse():
    """More decoding iterations -> fewer unresolved coords on average."""
    model = BernoulliStragglers(0.2)
    means = []
    for D in [1, 3, 8]:
        s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=D)
        res = run_pgd(s2, jnp.zeros(200), model, steps=60,
                      theta_star=PROB.theta_star, key=jax.random.PRNGKey(5))
        means.append(float(res.unresolved.mean()))
    assert means[0] >= means[1] >= means[2]


def test_scheme1_exact_small_straggler_count():
    code = make_regular_ldpc(50, l=3, r=6, seed=1)  # (100, 50), k=200 -> 4 blocks
    s1 = Scheme1.build(code, MOM, lr=PROB.lr)
    theta = jax.random.normal(jax.random.PRNGKey(6), (200,))
    mask = jnp.zeros(code.N, bool).at[jnp.array([0, 7])].set(True)
    g, _ = s1.gradient(theta, mask)
    np.testing.assert_allclose(g, exact_grad(theta), rtol=1e-2, atol=5e-3)


def test_scheme1_converges_under_stragglers():
    code = make_regular_ldpc(50, l=3, r=6, seed=1)
    s1 = Scheme1.build(code, MOM, lr=PROB.lr)
    res = run_pgd(s1, jnp.zeros(200), FixedCountStragglers(5), steps=200,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(7))
    assert float(res.errors[-1]) < 0.05 * float(jnp.linalg.norm(PROB.theta_star))


def test_sparse_recovery_iht_with_scheme2():
    """Paper Fig. 2-style: IHT with LDPC moment-encoded gradients."""
    u = 20
    prob = make_sparse_problem(m=512, k=200, u=u, seed=2)
    mom = second_moment(prob.X, prob.y)
    s2 = Scheme2.build(CODE, mom, lr=prob.lr, decode_iters=8,
                       projection=projections.hard_threshold(u))
    res = run_pgd(s2, jnp.zeros(200), FixedCountStragglers(40), steps=500,
                  theta_star=prob.theta_star, key=jax.random.PRNGKey(8))
    assert float(res.errors[-1]) < 0.05 * float(jnp.linalg.norm(prob.theta_star))


def test_adversarial_stragglers_still_converge():
    """Fixed stragglers each step: unrecovered coords are always the same, so
    plain Scheme 2 stalls on those coordinates — unless decode recovers them.
    With only 3 adversarial stragglers the peeler recovers everything."""
    s2 = Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=CODE.N)
    res = run_pgd(s2, jnp.zeros(200), AdversarialStragglers((3, 77, 250)), steps=300,
                  theta_star=PROB.theta_star, key=jax.random.PRNGKey(9))
    assert float(res.errors[-1]) < 0.05 * float(jnp.linalg.norm(PROB.theta_star))
