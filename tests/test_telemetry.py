"""Telemetry layer: EMA straggler-rate estimation, density-evolution-derived
decode budgets and wait-for thresholds, and the topology's per-worker →
per-symbol erasure lift (a partition)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from tests._hypothesis_compat import given, settings, st
except ImportError:  # pragma: no cover - run from tests/ directly
    from _hypothesis_compat import given, settings, st

from repro.core import BernoulliStragglers
from repro.core.density_evolution import q_final, threshold
from repro.distributed.telemetry import (
    ArrivalLagEstimator,
    StragglerRateEstimator,
    cached_threshold,
    decode_budget,
    pick_wait_and_staleness,
    pick_wait_for,
    pick_wait_for_cached,
    rounds_to_clear,
)
from repro.distributed.topology import WorkerTopology


# ------------------------------------------------------------ EMA estimator


def test_ema_converges_to_bernoulli_rate():
    """Under i.i.d. Bernoulli(q0) straggling the estimate converges to q0
    (within the EMA's effective-sample-size noise floor)."""
    q0, W = 0.2, 64
    est = StragglerRateEstimator(decay=0.95)
    model = BernoulliStragglers(q0)
    keys = jax.random.split(jax.random.PRNGKey(0), 400)
    for k in keys:
        est.observe(float(model.sample(k, W).mean()))
    # effective sample size ~ (1+decay)/(1-decay) ≈ 39 masks of W workers
    assert abs(est.rate - q0) < 0.03, est.rate


def test_ema_prior_and_bias_correction():
    est = StragglerRateEstimator(decay=0.9, prior=0.3)
    assert est.rate == 0.3            # no observations yet: the prior
    est.observe(0.5)
    # bias-corrected: ONE observation estimates exactly that observation,
    # not decay·0 + (1-decay)·0.5 = 0.05
    assert est.rate == pytest.approx(0.5)
    est.observe(0.1)
    assert 0.1 < est.rate < 0.5       # between the two observations
    assert est.steps == 2


def test_ema_tracks_regime_change():
    """After a calm→storm shift the estimate crosses over within a few
    decay time constants."""
    est = StragglerRateEstimator(decay=0.8)
    for _ in range(30):
        est.observe(0.05)
    assert est.rate == pytest.approx(0.05, abs=1e-6)
    for _ in range(15):
        est.observe(0.4)
    assert est.rate > 0.3


def test_ema_validates_inputs():
    with pytest.raises(ValueError):
        StragglerRateEstimator(decay=1.0)
    est = StragglerRateEstimator()
    with pytest.raises(ValueError):
        est.observe(1.5)


# ------------------------------------------- density-evolution round budgets


def test_rounds_to_clear_matches_density_evolution():
    """The returned D really is the first round with q_D ≤ tol."""
    l, r, tol = 3, 6, 1e-3
    for q0 in (0.05, 0.15, 0.3, 0.4):
        D = rounds_to_clear(q0, l, r, max_rounds=64, tol=tol)
        assert q_final(q0, l, r, D) <= tol
        if D > 1:
            assert q_final(q0, l, r, D - 1) > tol


def test_rounds_to_clear_monotone_and_saturating():
    l, r = 3, 6
    Ds = [rounds_to_clear(q, l, r, max_rounds=64) for q in
          (0.0, 0.05, 0.15, 0.3, 0.4)]
    assert Ds == sorted(Ds)
    # above the ensemble threshold the recursion never collapses
    qstar = cached_threshold(l, r)
    assert rounds_to_clear(qstar + 0.05, l, r, max_rounds=64) == 64


def test_decode_budget_clamped_and_padded():
    l, r = 3, 6
    b_light = decode_budget(0.02, l, r, max_rounds=32)
    b_heavy = decode_budget(0.9, l, r, max_rounds=32)
    assert 1 <= b_light < b_heavy <= 32
    assert b_heavy == 32              # undecodable rate → worst-case budget
    # slack rounds are actually added on top of the DE answer
    D = rounds_to_clear(0.02 * 1.25, l, r, max_rounds=32)
    assert b_light == D + 2


# ---------------------------------------------------- wait-for threshold


def test_wait_for_respects_threshold_margin():
    """The cut implied by wait_for never exceeds margin·q*(l, r)."""
    l, r, margin = 3, 6, 0.9
    qstar = threshold(l, r)
    for w in (8, 40, 256):
        for q_hat in (0.0, 0.05, 0.2, 0.5, 1.0):
            wait = pick_wait_for(q_hat, w, l, r, margin=margin)
            assert 1 <= wait <= w
            cut_frac = (w - wait) / w
            assert cut_frac <= margin * qstar + 1e-9, (w, q_hat, cut_frac)


def test_wait_for_tracks_observed_rate():
    """Calm telemetry → wait for (nearly) everyone; heavy telemetry →
    cut up to the threshold-capped maximum."""
    l, r, w = 3, 6, 40
    assert pick_wait_for(0.0, w, l, r) == w
    calm = pick_wait_for(0.02, w, l, r)
    stormy = pick_wait_for(0.35, w, l, r)
    assert calm > stormy
    # stormy saturates at the threshold cap, not at the observed rate
    qstar = cached_threshold(l, r)
    assert stormy == w - int(0.9 * qstar * w)


def test_cached_threshold_matches_direct():
    assert cached_threshold(3, 6) == pytest.approx(threshold(3, 6))


def test_pick_wait_for_cached_matches_uncached():
    """On bucket-aligned rates the memo is exact; off-grid the 1/1024
    quantization can shift the cut by at most one worker, and only when
    ``headroom·q̂·w`` lands exactly on an integer boundary."""
    for w in (4, 8, 40, 256):
        for b in range(0, 1025, 8):
            q = b / 1024
            assert (pick_wait_for_cached(q, w, 3, 6)
                    == pick_wait_for(q, w, 3, 6))
        for q in np.linspace(0.0, 1.0, 101):
            assert abs(pick_wait_for_cached(float(q), w, 3, 6)
                       - pick_wait_for(float(q), w, 3, 6)) <= 1


# --------------------------------------------------- arrival-lag estimation


def test_lag_estimator_prior_then_tracks_observations():
    est = ArrivalLagEstimator(decay=0.5, max_lag=4)
    # before any observation: uniform-late prior, half the mass on-time
    assert est.pmf[0] == pytest.approx(0.5)
    assert est.pmf[1:].sum() == pytest.approx(0.5)
    # steady stream: 6 of 8 on time, 2 at lag 1 → pmf converges there
    for _ in range(30):
        est.observe([0, 0, 0, 0, 0, 0, 1, 1])
    assert est.pmf[0] == pytest.approx(0.75)
    assert est.pmf[1] == pytest.approx(0.25)
    assert est.coverage(1) == pytest.approx(1.0)
    assert est.coverage(0) == pytest.approx(0.0)


def test_lag_estimator_clips_and_covers():
    est = ArrivalLagEstimator(decay=0.0, max_lag=3)
    est.observe([0, 1, 2, 99])      # 99 clips into the never bin
    assert est.pmf[-1] == pytest.approx(0.25)
    # of the late mass (3 workers), a window of 2 covers 2
    assert est.coverage(2) == pytest.approx(2 / 3)
    # no late mass at all → any window trivially covers
    est2 = ArrivalLagEstimator()
    est2.observe([0, 0, 0])
    assert est2.coverage(0) == 1.0


def test_lag_estimator_validates():
    with pytest.raises(ValueError):
        ArrivalLagEstimator(decay=1.0)
    with pytest.raises(ValueError):
        ArrivalLagEstimator(max_lag=0)


def test_pick_wait_and_staleness_window_tracks_lags():
    w, l, r = 8, 3, 6
    est = ArrivalLagEstimator(decay=0.0, max_lag=8)
    est.observe([0] * 6 + [1, 1])              # all late mass at lag 1
    wait, s = pick_wait_and_staleness(0.25, est, w, l, r)
    assert wait == pick_wait_for_cached(0.25, w, l, r)
    assert s == 1
    est.observe([0] * 6 + [8, 8])              # hopeless stragglers only:
    _, s = pick_wait_and_staleness(0.25, est, w, l, r, max_window=4)
    assert s == 4                              # cap returned, not exceeded


# ----------------------------------------- worker→symbol lift is a partition


@settings(deadline=None, max_examples=25)
@given(W=st.sampled_from([1, 2, 4, 8, 16]), rpw=st.integers(1, 8),
       seed=st.integers(0, 10_000))
def test_worker_lift_is_partition(W, rpw, seed):
    """Every encoded symbol is covered by EXACTLY one worker: lifting a
    one-hot worker mask yields disjoint symbol sets whose union is all N
    symbols, and lifting any mask then pooling back per worker recovers
    the mask exactly."""
    N = W * rpw
    topo = WorkerTopology(W, N)
    # one-hot masks: disjoint covers
    cover = np.zeros(N, int)
    for j in range(W):
        onehot = np.zeros(W, bool)
        onehot[j] = True
        sym = np.asarray(topo.to_symbol_erasure(jnp.asarray(onehot)))
        assert sym.sum() == rpw
        cover += sym
    assert (cover == 1).all()         # partition: each symbol exactly once
    # arbitrary mask round-trips through the assignment
    rng = np.random.default_rng(seed)
    mask = rng.random(W) < 0.4
    sym = np.asarray(topo.to_symbol_erasure(jnp.asarray(mask)))
    pooled = sym.reshape(W, rpw)
    assert (pooled.all(axis=1) == mask).all()
    assert (pooled.any(axis=1) == mask).all()
    # and agrees with the worker_of_row table
    assert (sym == mask[topo.worker_of_row]).all()


def test_topology_validation():
    with pytest.raises(ValueError):
        WorkerTopology(3, 8)          # 8 rows don't split over 3 workers
    with pytest.raises(ValueError):
        WorkerTopology(0, 8)
    topo = WorkerTopology(4, 8)
    assert topo.rows_per_worker == 2
    assert topo.worker_rows(1) == slice(2, 4)
    with pytest.raises(IndexError):
        topo.worker_rows(4)
    assert float(topo.observed_fraction(jnp.array([True, False, True, False]))
                 ) == pytest.approx(0.5)


# ----------------------------------------------------- estimator snapshots


def test_straggler_estimator_snapshot_json_round_trips():
    import json

    est = StragglerRateEstimator(decay=0.9, prior=0.3)
    snap = est.snapshot()
    assert snap["kind"] == "straggler_rate"
    assert not snap["bias_corrected"]          # prior only, no observations
    assert snap["rate"] == pytest.approx(0.3)
    est.observe(0.5)
    est.observe(0.25)
    snap = est.snapshot()
    assert snap["bias_corrected"] and snap["steps"] == 2
    assert snap["rate"] == pytest.approx(est.rate)
    assert json.loads(json.dumps(snap)) == snap


def test_lag_estimator_snapshot_pmf_sums_to_one():
    import json

    lag = ArrivalLagEstimator(decay=0.5, max_lag=4)
    snap = lag.snapshot()                      # prior pmf is a distribution
    assert snap["kind"] == "arrival_lag"
    assert sum(snap["pmf"]) == pytest.approx(1.0)
    lag.observe([0, 0, 1, 99])                 # 99 clips into the never bin
    lag.observe([0, 2, 2, 0])
    snap = lag.snapshot()
    assert sum(snap["pmf"]) == pytest.approx(1.0)
    assert len(snap["pmf"]) == lag.max_lag + 2
    assert snap["coverage"] == pytest.approx(
        [lag.coverage(s) for s in range(lag.max_lag + 1)])
    assert json.loads(json.dumps(snap)) == snap
