"""Coverage extensions: Scheme2Blocked/Scheme2 equivalence, grouped-MoE
invariance, adaptive decode-budget behaviour, sharding variants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:  # dev-only dep: degrade to per-test skips when missing
    from tests._hypothesis_compat import given, settings, st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.core import (
    BernoulliStragglers,
    Scheme2,
    Scheme2Blocked,
    make_regular_ldpc,
    peel_decode_adaptive,
    second_moment,
)
from repro.data import make_linear_problem
from repro.models import moe as MOE


def test_scheme2_blocked_equals_scheme2_when_k_equals_K():
    """nb = 1 block: the blocked scheme must reduce exactly to Scheme 2."""
    prob = make_linear_problem(m=256, k=40, seed=0)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    s2 = Scheme2.build(code, mom, lr=prob.lr, decode_iters=6)
    s2b = Scheme2Blocked.build(code, mom, lr=prob.lr, decode_iters=6)
    theta = jax.random.normal(jax.random.PRNGKey(0), (40,))
    mask = jnp.zeros(code.N, bool).at[jnp.array([3, 17])].set(True)
    g1, u1 = s2.gradient(theta, mask)
    g2, u2 = s2b.gradient(theta, mask)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)
    assert int(u1) == int(u2)


def test_scheme2_blocked_block_order():
    """Blocked flat gradient must align coordinates with M's row partition."""
    prob = make_linear_problem(m=256, k=60, seed=1)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=1)  # 3 blocks
    s2b = Scheme2Blocked.build(code, mom, lr=prob.lr, decode_iters=40)
    theta = jax.random.normal(jax.random.PRNGKey(1), (60,))
    g, u = s2b.gradient(theta, jnp.zeros(code.N, bool))
    assert int(u) == 0
    np.testing.assert_allclose(g, mom.M @ theta - mom.b, rtol=2e-3, atol=2e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), groups=st.sampled_from([1, 2, 4]))
def test_moe_grouped_matches_ungrouped_high_capacity(seed, groups):
    """With capacity high enough that nothing drops, grouped routing is
    token-order invariant and must equal the global routing exactly."""
    key = jax.random.PRNGKey(seed)
    p = MOE.init_moe(key, 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 4, 16))
    y1, _ = MOE.moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=16.0)
    y2, _ = MOE.moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=16.0,
                            groups=groups)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_dropping_monotone():
    """Lower capacity factor -> more dropped tokens -> output moves toward
    the shared/zero path; outputs must stay finite either way."""
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, 16, 32, 4, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 32, 16))
    y_lo, _ = MOE.moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=0.25)
    y_hi, _ = MOE.moe_forward(p, x, n_experts=4, top_k=2, capacity_factor=8.0)
    assert np.isfinite(np.asarray(y_lo)).all()
    # dropped tokens contribute 0 -> lower-capacity output has smaller norm
    assert float(jnp.linalg.norm(y_lo)) <= float(jnp.linalg.norm(y_hi)) + 1e-3


def test_adaptive_decode_rounds_track_stragglers():
    """The paper's 'decoding effort adapts to realized stragglers':
    rounds_used must be (weakly) increasing in the erasure count."""
    code = make_regular_ldpc(128, l=3, r=6, seed=0)
    rng = np.random.default_rng(0)
    cw = jnp.asarray(code.encode(rng.standard_normal(128)), jnp.float32)
    rounds = []
    for s in (1, 10, 40):
        erased = np.zeros(code.N, bool)
        erased[rng.choice(code.N, s, replace=False)] = True
        rx = jnp.where(jnp.asarray(erased), 0.0, cw)
        res = peel_decode_adaptive(code, rx, jnp.asarray(erased))
        rounds.append(int(res.rounds_used))
    assert rounds[0] <= rounds[1] <= rounds[2] + 1


def test_seq_shard_kv_spec_generation():
    """H1 knob: KV-head-indivisible caches get sequence-sharded specs."""
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.mesh import make_abstract_mesh
    from repro.models import Model
    from repro.sharding import cache_sharding

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    cfg = get_config("qwen3-1.7b")  # kv=8 does not divide 16
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(128, 32768))
    base = cache_sharding(cfg, mesh, cache)
    opt = cache_sharding(cfg, mesh, cache, seq_shard_kv=True)
    k_base = base["blocks"]["sub0"]["k"].spec
    k_opt = opt["blocks"]["sub0"]["k"].spec
    assert k_base == P(None, "data", None, None, None)   # replicated over model
    assert k_opt == P(None, "data", "model", None, None)  # seq dim sharded


def test_reduced_configs_contract():
    """Assignment contract: every reduced config is <=2 layers, d_model<=512,
    <=4 experts."""
    from repro.configs import get_config, list_configs
    for name in list_configs():
        cfg = get_config(name)
        r = cfg.reduced()
        assert r.n_layers <= 2 and r.d_model <= 512
        if r.moe:
            assert r.moe.n_experts <= 4
        # same family/technique knobs preserved
        assert r.family == cfg.family
        assert (r.moe is None) == (cfg.moe is None)
        assert (r.mla is None) == (cfg.mla is None)
        assert (r.rwkv is None) == (cfg.rwkv is None)
