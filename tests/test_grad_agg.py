"""LDPC-coded gradient aggregation (beyond-paper core/grad_agg.py)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import BernoulliStragglers, CodedAggregator
from repro.core.grad_agg import flatten_grads


def test_zero_stragglers_exact_sum():
    agg = CodedAggregator.build(16, redundancy=0.5, row_weight=4, seed=0)
    rng = np.random.default_rng(0)
    partials = jnp.asarray(rng.standard_normal((16, 33)), jnp.float32)
    total, unresolved = agg.aggregate(partials, jnp.zeros(agg.n_workers, bool))
    np.testing.assert_allclose(total, partials.sum(axis=0), rtol=1e-4, atol=1e-4)
    assert int(unresolved) == 0


def test_parity_recovers_single_systematic_erasure():
    agg = CodedAggregator.build(16, redundancy=0.5, row_weight=4, seed=0,
                                decode_iters=20)
    rng = np.random.default_rng(1)
    partials = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    mask = jnp.zeros(agg.n_workers, bool).at[5].set(True)  # shard 5 straggles
    total, unresolved = agg.aggregate(partials, mask)
    assert int(unresolved) == 0
    np.testing.assert_allclose(total, partials.sum(axis=0), rtol=1e-4, atol=1e-4)


def test_unrecovered_shards_zero_filled():
    # erase more than the code can peel: totals = sum over recovered only
    agg = CodedAggregator.build(8, redundancy=0.25, row_weight=3, seed=0,
                                decode_iters=10)
    rng = np.random.default_rng(2)
    partials = jnp.asarray(rng.standard_normal((8, 4)), jnp.float32)
    mask = jnp.zeros(agg.n_workers, bool).at[jnp.arange(6)].set(True)
    total, unresolved = agg.aggregate(partials, mask)
    assert int(unresolved) > 0
    # sanity: the result equals the sum over exactly the recovered systematic set
    G = jnp.asarray(agg.code.G, jnp.float32)
    sym = G @ partials
    from repro.core.decoder import peel_decode
    dec = peel_decode(agg.code, jnp.where(mask[:, None], 0.0, sym), mask, 10)
    rec = ~np.asarray(dec.erased[:8])
    expect = np.asarray(partials)[rec].sum(axis=0)
    np.testing.assert_allclose(total, expect, rtol=1e-4, atol=1e-4)


def test_bernoulli_unbiased_scaled():
    agg = CodedAggregator.build(32, redundancy=0.5, row_weight=4, seed=3,
                                decode_iters=8)
    rng = np.random.default_rng(3)
    partials = jnp.asarray(rng.standard_normal((32, 5)), jnp.float32)
    model = BernoulliStragglers(0.1)

    @jax.jit
    def one(key):
        total, _ = agg.aggregate(partials, model.sample(key, agg.n_workers))
        return total

    keys = jax.random.split(jax.random.PRNGKey(0), 800)
    totals = jax.vmap(one)(keys)
    mean = np.asarray(totals.mean(axis=0))
    gt = np.asarray(partials.sum(axis=0))
    scale = float(mean @ gt / (gt @ gt))
    assert 0.85 < scale <= 1.001  # (1 - q_D) close to 1 for q0=0.1 w/ parity


def test_flatten_roundtrip():
    tree = {"a": jnp.ones((2, 3)), "b": {"c": jnp.arange(4.0)}}
    flat, unflat = flatten_grads(tree)
    assert flat.shape == (10,)
    rt = unflat(flat)
    np.testing.assert_allclose(rt["a"], tree["a"])
    np.testing.assert_allclose(rt["b"]["c"], tree["b"]["c"])


def test_end_to_end_coded_training_linear_model():
    """Coded aggregation drives data-parallel GD to convergence on a linear
    model with Bernoulli stragglers — the 'technique applied to any loss'."""
    rng = np.random.default_rng(4)
    k, m, shards = 30, 640, 16
    X = jnp.asarray(rng.standard_normal((m, k)) / np.sqrt(m), jnp.float32)
    theta_star = jnp.asarray(rng.standard_normal(k), jnp.float32)
    y = X @ theta_star
    agg = CodedAggregator.build(shards, redundancy=0.5, row_weight=4, seed=5)
    Xs = X.reshape(shards, m // shards, k)
    ys = y.reshape(shards, m // shards)
    lr = 1.0 / float(jnp.linalg.norm(X, 2)) ** 2
    model = BernoulliStragglers(0.15)

    @jax.jit
    def step(theta, key):
        partials = jax.vmap(lambda Xb, yb: Xb.T @ (Xb @ theta - yb))(Xs, ys)
        g, _ = agg.aggregate(partials, model.sample(key, agg.n_workers))
        return theta - lr * g

    theta = jnp.zeros(k)
    key = jax.random.PRNGKey(6)
    for t in range(500):
        key, k1 = jax.random.split(key)
        theta = step(theta, k1)
    err = float(jnp.linalg.norm(theta - theta_star) / jnp.linalg.norm(theta_star))
    assert err < 0.05, err
