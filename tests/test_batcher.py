"""Wave batcher: batched greedy decode must equal per-request sequential
greedy decode (exactness of the lockstep scheduling)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serving.batcher import Request, WaveBatcher


def _sequential_greedy(model, params, prompt, max_new, max_len):
    cache = model.init_cache(1, max_len)
    tok = jnp.asarray([[prompt[0]]], jnp.int32)
    pos = 0
    out = []
    step = jax.jit(model.decode_step)
    pending = list(prompt[1:])
    while len(out) < max_new and pos < max_len - 1:
        logits, cache = step(params, tok, jnp.int32(pos), cache)
        pos += 1
        if pending:
            tok = jnp.asarray([[pending.pop(0)]], jnp.int32)
        else:
            nxt = int(jnp.argmax(logits[0, 0]))
            out.append(nxt)
            tok = jnp.asarray([[nxt]], jnp.int32)
    return out


def test_wave_batcher_matches_sequential():
    cfg = get_config("qwen3-1.7b").reduced()
    model = Model(cfg, remat=False, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, size=L).tolist()
               for L in (3, 5, 4, 6, 2)]  # 5 requests, 4 slots -> 2 waves
    batcher = WaveBatcher(model, params, n_slots=4, max_len=32)
    for i, pr in enumerate(prompts):
        batcher.submit(Request(rid=i, prompt=pr, max_new=6))
    done = batcher.run()
    assert len(done) == 5 and all(r.done for r in done)

    for r in sorted(done, key=lambda r: r.rid):
        ref = _sequential_greedy(model, params, prompts[r.rid], 6, 32)
        assert r.out == ref, f"rid={r.rid}: {r.out} != {ref}"


def test_wave_batcher_eos_and_caps():
    cfg = get_config("qwen2-1.5b").reduced()
    model = Model(cfg, remat=False, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(1))
    batcher = WaveBatcher(model, params, n_slots=2, max_len=16)
    batcher.submit(Request(rid=0, prompt=[1, 2], max_new=4))
    batcher.submit(Request(rid=1, prompt=[3], max_new=50))  # capped by max_len
    done = batcher.run()
    assert len(done) == 2
    r0 = next(r for r in done if r.rid == 0)
    r1 = next(r for r in done if r.rid == 1)
    assert len(r0.out) == 4
    assert 0 < len(r1.out) <= 50
