"""Trainer, checkpointing, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import make_linear_problem, token_batches
from repro.data.batches import make_batch
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init
from repro.train import Trainer, TrainerConfig, load_checkpoint, save_checkpoint


@pytest.fixture(scope="module")
def small_model():
    cfg = get_config("qwen3-1.7b").reduced()
    model = Model(cfg, remat=False, attn_chunk=8)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _batches(cfg, batch=4, seq=16):
    key = jax.random.PRNGKey(0)
    while True:
        key, k = jax.random.split(key)
        yield make_batch(cfg, batch, seq, key=k)


def test_trainer_plain(small_model):
    cfg, model, params = small_model
    tr = Trainer(model, TrainerConfig(steps=5, log_every=0,
                                      opt=AdamWConfig(lr=1e-3)))
    p2, _, hist = tr.fit(jax.tree.map(jnp.copy, params), _batches(cfg))
    assert len(hist) == 5
    assert np.isfinite(hist).all()


def test_trainer_coded_agg_matches_plain_no_stragglers(small_model):
    """With q0 = 0 every shard is recovered, so the coded-aggregate gradient
    equals the plain gradient (up to fp error) and training trajectories
    coincide step-for-step."""
    cfg, model, params = small_model
    batch_iter1 = _batches(cfg)
    batch_iter2 = _batches(cfg)
    plain = Trainer(model, TrainerConfig(steps=3, log_every=0,
                                         opt=AdamWConfig(lr=1e-3)))
    coded = Trainer(model, TrainerConfig(steps=3, log_every=0,
                                         opt=AdamWConfig(lr=1e-3),
                                         coded_agg=True, n_shards=4,
                                         straggler_q0=0.0, decode_iters=10))
    p1, _, h1 = plain.fit(jax.tree.map(jnp.copy, params), batch_iter1)
    p2, _, h2 = coded.fit(jax.tree.map(jnp.copy, params), batch_iter2)
    np.testing.assert_allclose(h1, h2, rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=5e-3, atol=5e-4)


def test_trainer_coded_agg_with_stragglers_trains(small_model):
    cfg, model, params = small_model
    tr = Trainer(model, TrainerConfig(steps=6, log_every=0,
                                      opt=AdamWConfig(lr=2e-3),
                                      coded_agg=True, n_shards=4,
                                      straggler_q0=0.2))
    _, _, hist = tr.fit(jax.tree.map(jnp.copy, params), _batches(cfg))
    assert np.isfinite(hist).all()


def test_checkpoint_roundtrip(tmp_path, small_model):
    cfg, model, params = small_model
    opt = adamw_init(params)
    save_checkpoint(tmp_path, 7, params, opt, {"note": "test"})
    step, p2, o2 = load_checkpoint(tmp_path, params, opt)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(o2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path, small_model):
    cfg, model, params = small_model
    save_checkpoint(tmp_path, 1, params)
    bad = jax.tree.map(lambda a: jnp.zeros(a.shape + (1,), a.dtype), params)
    with pytest.raises(ValueError):
        load_checkpoint(tmp_path, bad)


def test_token_batches_deterministic():
    a = list(token_batches(1000, 2, 8, seed=3, n_batches=2))
    b = list(token_batches(1000, 2, 8, seed=3, n_batches=2))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
        assert x["tokens"].shape == (2, 8)
        assert int(x["tokens"].max()) < 1000
    # labels are next-token shifted
    full_a = np.concatenate([np.asarray(a[0]["tokens"]),
                             np.asarray(a[0]["labels"][:, -1:])], axis=1)
    np.testing.assert_array_equal(full_a[:, 1:], np.asarray(a[0]["labels"]))


def test_linear_problem_properties():
    prob = make_linear_problem(128, 16, seed=0)
    assert prob.X.shape == (128, 16)
    np.testing.assert_allclose(prob.X @ prob.theta_star, prob.y, rtol=1e-5,
                               atol=1e-5)
    # lr = 1/λmax guarantee: exact GD strictly decreases the loss
    theta = jnp.zeros(16)
    M = prob.X.T @ prob.X
    b = prob.X.T @ prob.y
    losses = []
    for _ in range(10):
        theta = theta - prob.lr * (M @ theta - b)
        losses.append(float(0.5 * jnp.sum((prob.y - prob.X @ theta) ** 2)))
    assert all(x > y for x, y in zip(losses, losses[1:]))
