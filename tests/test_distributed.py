"""The sharded coded-worker runtime: master/worker parity with the
single-device Scheme2 (bit-for-bit, every decode backend), worker-granular
straggling, telemetry-driven budgets, and the distributed AOT step.

The in-process tests run on whatever mesh this process has (1 CPU device in
the tier-1 job; 8 fake devices in the CI distributed job) — logical workers
are decoupled from devices, so the full code path including ``shard_map``
runs either way.  The subprocess test forces the fake 8-device mesh
explicitly (the acceptance configuration).
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BernoulliStragglers,
    DelayModel,
    FixedCountStragglers,
    Scheme2,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.data import make_linear_problem
from repro.distributed import (
    DistributedCodedGD,
    StragglerRateEstimator,
    WorkerStragglers,
    WorkerTopology,
    make_worker_mesh,
)
from repro.distributed.selfcheck import check_parity

REPO = Path(__file__).resolve().parents[1]

K = 64
CODE = make_regular_ldpc(K, l=3, r=6, seed=0)
PROB = make_linear_problem(m=4 * K, k=K, seed=0)
MOM = second_moment(PROB.X, PROB.y)


def _scheme(backend="sparse", decode_iters=8, **kw):
    return Scheme2.build(CODE, MOM, lr=PROB.lr, decode_iters=decode_iters,
                         decode_backend=backend, **kw)


# ------------------------------------------------------------------- parity


@pytest.mark.parametrize("backend", ["dense", "sparse"])
def test_bit_parity_with_single_device_scheme2(backend):
    """Same key, same per-worker erasures → bit-identical iterates."""
    assert check_parity(K=K, n_workers=8, steps=5, q0=0.25,
                        backend=backend) == 5


def test_bit_parity_pallas_backend():
    """The fused-kernel decode under the distributed master (interpret
    mode off-TPU — slow, so fewer steps)."""
    assert check_parity(K=K, n_workers=8, steps=2, q0=0.25,
                        backend="pallas") == 2


def test_parity_on_fake_8_device_mesh_subprocess():
    """The acceptance configuration: a REAL 8-device mesh (fake CPU
    devices), all three decode backends, bit-identical trajectories."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selfcheck",
         "--workers", "8", "--steps", "4"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"selfcheck failed:\n{res.stdout}\n{res.stderr}"
    assert res.stdout.count("parity OK") == 3      # dense, sparse, pallas
    assert "devices=8" in res.stdout


# ------------------------------------------------------ sharded master decode


def test_sharded_master_decode_bit_parity():
    """master_decode="sharded": the decode itself runs over the mesh (check
    tiles partitioned, one all-gather merge per round) and the trajectory
    stays bit-identical to the single-device sparse decode — the overwrite
    merge crosses shards as a select, never an f32 sum."""
    assert check_parity(K=K, n_workers=8, steps=5, q0=0.25,
                        backend="sparse", master_decode="sharded") == 5


def test_sharded_decode_matches_sparse_rounds():
    """The shard_map-ped decode function itself (ragged check padding over
    the mesh) against the single-device fixed-D sparse loop, bit for bit."""
    from repro.core.decoder import peel_fixed_sparse
    from repro.distributed.sharded_decode import (build_sharded_decode,
                                                  shard_check_tables)

    code = make_regular_ldpc(100, l=3, r=6, seed=1)   # p = 100: ragged
    mesh = make_worker_mesh()
    idx_sh, coeff_sh = shard_check_tables(code, mesh)
    rng = np.random.default_rng(0)
    cw = jnp.asarray(code.encode(rng.standard_normal((100, 2))), jnp.float32)
    dec = jax.jit(build_sharded_decode(mesh, iters=8))
    for seed in range(3):
        er = jnp.asarray(np.random.default_rng(seed).random(code.N) < 0.35)
        rx = jnp.where(er[:, None], 0.0, cw)
        ref_v, ref_e = peel_fixed_sparse(jnp.asarray(code.check_idx),
                                         jnp.asarray(code.check_coeff),
                                         rx, er, 8)
        v, e, r = dec(idx_sh, coeff_sh, rx, er, jnp.asarray([8], jnp.int32))
        np.testing.assert_array_equal(np.asarray(v), np.asarray(ref_v))
        np.testing.assert_array_equal(np.asarray(e), np.asarray(ref_e))
        assert int(r) == 8


def test_sharded_parity_on_fake_8_device_mesh_subprocess():
    """Sharded master decode ≡ single-device decode on the fake 8-device
    mesh (the acceptance claim for the sharded decode)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selfcheck",
         "--workers", "8", "--steps", "4", "--master-decode", "sharded"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"selfcheck failed:\n{res.stdout}\n{res.stderr}"
    assert "parity OK" in res.stdout
    assert "master_decode=sharded" in res.stdout
    assert "devices=8" in res.stdout


def test_sharded_telemetry_budget_traced_and_respected():
    """Telemetry budgets flow into the sharded master program as the same
    traced (1,) operand: varying budgets reuse ONE compiled program, and
    rounds spent never exceed the granted budget."""
    scheme = _scheme(decode_iters=32)
    topo = WorkerTopology(8, CODE.N)
    dist = DistributedCodedGD(scheme, topo, budget_mode="telemetry",
                              master_decode="sharded", max_rounds=32)
    theta = jnp.zeros(K)
    budgets_seen = set()
    for t in range(6):
        mask = BernoulliStragglers(0.05 if t < 3 else 0.4).sample(
            jax.random.PRNGKey(t), 8)
        theta, _, rounds, budget = dist.step(theta, mask)
        budgets_seen.add(budget)
        assert rounds <= budget
    assert len(budgets_seen) > 1
    assert dist._master_program._cache_size() == 1


def test_sharded_master_decode_validation():
    with pytest.raises(ValueError):
        DistributedCodedGD(_scheme(), WorkerTopology(8, CODE.N),
                           master_decode="hologram")


def test_run_matches_run_pgd_trajectory():
    """The master driver's python loop reproduces run_pgd's scanned
    trajectory under the same lifted straggler stream (same key schedule);
    unresolved counts match exactly, errors to float tolerance."""
    scheme = _scheme()
    topo = WorkerTopology(8, CODE.N)
    stragglers = WorkerStragglers(BernoulliStragglers(0.2), topo)
    key = jax.random.PRNGKey(3)
    theta0 = jnp.zeros(K)
    ref = run_pgd(scheme, theta0, stragglers, 10, key=key,
                  theta_star=PROB.theta_star)
    dist = DistributedCodedGD(scheme, topo)
    got = dist.run(theta0, BernoulliStragglers(0.2), 10, key=key,
                   theta_star=PROB.theta_star)
    np.testing.assert_array_equal(got.unresolved, np.asarray(ref.unresolved))
    # run_pgd fuses the whole trajectory into one scanned program; the
    # master loop launches per-step programs — same math, different XLA
    # fusion, so float equality is approximate here (the bit-exact claim
    # against a per-step reference is test_bit_parity_* above); the
    # per-step rounding difference compounds over the 10 GD steps, so the
    # band is wider than a single decode's.
    np.testing.assert_allclose(got.errors, np.asarray(ref.errors),
                               rtol=5e-3, atol=1e-5)
    # per-coordinate drift accumulates over the 10 steps; the error norm
    # above pins the trajectory, coordinates get an absolute band
    np.testing.assert_allclose(np.asarray(got.theta), np.asarray(ref.theta),
                               rtol=5e-3, atol=5e-3)


# ------------------------------------------------- worker-granular straggling


def test_worker_straggler_lift_erases_whole_shards():
    topo = WorkerTopology(8, CODE.N)
    model = WorkerStragglers(FixedCountStragglers(3), topo)
    mask = model.sample(jax.random.PRNGKey(0), CODE.N)
    m = np.asarray(mask).reshape(8, topo.rows_per_worker)
    per_worker = m.any(axis=1)
    assert per_worker.sum() == 3                  # exactly s workers
    assert (m.all(axis=1) == per_worker).all()    # whole shards, never rows
    with pytest.raises(ValueError):
        model.sample(jax.random.PRNGKey(0), CODE.N + 1)


def test_distributed_validates_construction():
    scheme = _scheme()
    with pytest.raises(ValueError):               # N mismatch
        DistributedCodedGD(scheme, WorkerTopology(4, 2 * CODE.N))
    with pytest.raises(ValueError):               # unknown budget mode
        DistributedCodedGD(scheme, WorkerTopology(8, CODE.N),
                           budget_mode="psychic")
    dist = DistributedCodedGD(scheme, WorkerTopology(8, CODE.N))
    with pytest.raises(ValueError):               # wrong mask width
        dist.step(jnp.zeros(K), jnp.zeros(CODE.N, bool))


# ----------------------------------------------------------- telemetry loop


def test_telemetry_budgets_track_climate_and_save_rounds():
    """Online telemetry: budgets rise with the straggler climate, mean
    decode rounds land far under the fixed worst-case budget, and the
    adaptive decode still resolves what the fixed decode resolves."""
    max_rounds = 32
    scheme = _scheme(decode_iters=max_rounds)
    topo = WorkerTopology(8, CODE.N)
    dist = DistributedCodedGD(scheme, topo, budget_mode="telemetry",
                              estimator=StragglerRateEstimator(decay=0.7),
                              max_rounds=max_rounds)
    calm = dist.run(jnp.zeros(K), BernoulliStragglers(0.05), 12,
                    key=jax.random.PRNGKey(0))
    stormy_est = StragglerRateEstimator(decay=0.7)
    dist2 = DistributedCodedGD(scheme, topo, budget_mode="telemetry",
                               estimator=stormy_est, max_rounds=max_rounds)
    stormy = dist2.run(jnp.zeros(K), BernoulliStragglers(0.35), 12,
                       key=jax.random.PRNGKey(0))
    # budgets track the observed climate (tail steps, past the prior)
    assert calm.budgets[-5:].mean() < stormy.budgets[-5:].mean()
    assert calm.rates[-1] < stormy.rates[-1]
    # decode effort stays far under the worst-case fixed budget
    assert calm.rounds.mean() < max_rounds / 4
    assert (calm.rounds <= calm.budgets).all()
    assert (stormy.rounds <= stormy.budgets).all()


def test_telemetry_step_budget_is_traced_not_recompiled():
    """Varying per-step budgets must reuse ONE compiled master program."""
    scheme = _scheme(decode_iters=32)
    topo = WorkerTopology(8, CODE.N)
    dist = DistributedCodedGD(scheme, topo, budget_mode="telemetry",
                              max_rounds=32)
    theta = jnp.zeros(K)
    budgets_seen = set()
    for t in range(8):
        mask = BernoulliStragglers(0.05 if t < 4 else 0.4).sample(
            jax.random.PRNGKey(t), 8)
        theta, _, _, budget = dist.step(theta, mask)
        budgets_seen.add(budget)
    assert len(budgets_seen) > 1                  # budgets actually varied
    assert dist._master_program._cache_size() == 1


def test_delay_model_wait_for_semantics():
    """With a DelayModel the master waits for the telemetry-chosen fastest
    wait_for workers; the implied mask and simulated step time are
    consistent with the order statistics."""
    scheme = _scheme(decode_iters=16)
    topo = WorkerTopology(8, CODE.N)
    dist = DistributedCodedGD(scheme, topo, budget_mode="telemetry",
                              max_rounds=16)
    res = dist.run(jnp.zeros(K), None, 10, key=jax.random.PRNGKey(1),
                   delay_model=DelayModel(tau=1.0, mu=1.0))
    assert ((1 <= res.wait_for) & (res.wait_for <= 8)).all()
    assert (res.step_times >= 1.0).all()          # tau floor
    # waiting for fewer workers can only shorten the simulated step
    assert res.errors.shape == (10,)


# ------------------------------------------------------------- AOT step


def test_build_distributed_gd_step_lowers():
    """The production-scale master/worker step lowers + compiles on a
    reduced (devices, 1) workers x data mesh, both decode variants."""
    from repro.distributed.master import build_distributed_gd_step
    from repro.launch.mesh import make_mesh

    n_dev = jax.device_count()
    mesh = make_mesh((n_dev, 1), ("workers", "data"))
    for decode in ("dense", "sparse"):
        jitted, specs = build_distributed_gd_step(
            256, 128, 4, jnp.float32, mesh, decode=decode)
        compiled = jitted.lower(*specs).compile()
        assert compiled is not None
    with pytest.raises(ValueError):
        build_distributed_gd_step(256, 128, 4, jnp.float32, mesh,
                                  decode="pallas")


# ------------------------------------------------ seeded workers & grad-agg


def test_seeded_worker_encode_bit_parity():
    """worker_encode="seeded": workers hold only generator gather tables,
    fuse encode into the matvec — bit-identical to the single-device
    Scheme2.build_seeded trajectory under the lifted masks."""
    assert check_parity(K=K, n_workers=8, steps=5, q0=0.25,
                        backend="sparse", worker_encode="seeded") == 5


def test_seeded_worker_encode_validates_scheme():
    """A materialized scheme cannot drive seeded workers (there are no
    gather tables to shard; C is the encoded operator, not M)."""
    topo = WorkerTopology(8, CODE.N)
    with pytest.raises(ValueError, match="build_seeded"):
        DistributedCodedGD(_scheme(), topo, make_worker_mesh(),
                           worker_encode="seeded")


def test_distributed_grad_agg_bit_parity():
    """DistributedCodedAggregator (2-D payload worker launch) vs the
    single-device CodedAggregator, bit for bit, several masks."""
    from repro.distributed.selfcheck import check_grad_agg_parity
    assert check_grad_agg_parity(n_shards=64, dim=17, n_workers=8,
                                 steps=4, q0=0.25) == 4


def test_seeded_and_grad_agg_parity_subprocess():
    """The two new selfcheck modes on the REAL fake-8-device mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env.setdefault("JAX_PLATFORMS", "cpu")
    res = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selfcheck",
         "--workers", "8", "--steps", "4", "--backends", "sparse",
         "--worker-encode", "seeded"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"selfcheck failed:\n{res.stdout}\n{res.stderr}"
    assert "parity OK" in res.stdout
    assert "worker_encode=seeded" in res.stdout
    assert "devices=8" in res.stdout
    res = subprocess.run(
        [sys.executable, "-m", "repro.distributed.selfcheck",
         "--workers", "8", "--steps", "4", "--backends", "sparse",
         "--grad-agg"],
        capture_output=True, text=True, timeout=900, env=env, cwd=REPO)
    assert res.returncode == 0, f"selfcheck failed:\n{res.stdout}\n{res.stderr}"
    assert "parity OK: grad-agg" in res.stdout
    assert "devices=8" in res.stdout


def test_selfcheck_json_mode_in_process(capsys, tmp_path):
    """--json puts one machine-readable object on stdout (the obs status
    line goes to stderr, keeping it parseable) and exports --obs-out."""
    import json

    from repro.distributed.selfcheck import main

    obs = tmp_path / "sc.jsonl"
    rc = main(["--K", "32", "--workers", "8", "--steps", "2",
               "--backends", "dense", "--json", "--obs-out", str(obs)])
    cap = capsys.readouterr()
    assert rc == 0
    doc = json.loads(cap.out)                  # stdout is pure JSON
    assert doc["ok"] is True and doc["workers"] == 8
    assert doc["checks"] == [{
        "kind": "gd-step", "backend": "dense", "master_decode": "single",
        "worker_encode": "materialized", "ok": True, "steps": 2}]
    assert "[obs]" in cap.err
    assert obs.exists() and obs.with_suffix(".trace.json").exists()
