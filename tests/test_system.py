"""End-to-end behaviour tests for the paper's system: the full Scheme-2
pipeline (data -> moments -> LDPC encode -> straggler erasures -> peeling
decode -> PGD) reproduces the paper's claims on one box."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BernoulliStragglers,
    FixedCountStragglers,
    Scheme2Blocked,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.core.schemes import Karakus, Replication, Uncoded
from repro.data import make_linear_problem


def _iters_to(scheme, prob, model, tol=2e-2, steps=600, key=0):
    res = run_pgd(scheme, jnp.zeros_like(prob.theta_star), model, steps,
                  theta_star=prob.theta_star, key=jax.random.PRNGKey(key))
    errs = np.asarray(res.errors) / float(jnp.linalg.norm(prob.theta_star))
    hit = np.nonzero(errs < tol)[0]
    return int(hit[0]) + 1 if hit.size else steps


def test_paper_headline_ldpc_beats_baselines():
    """Paper Section 4: with s = 10 stragglers out of w = 40, moment encoding
    converges in fewer steps than uncoded and Karakus data encoding."""
    prob = make_linear_problem(m=2048, k=200, seed=0)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=0)
    model = FixedCountStragglers(10)

    it_ldpc = _iters_to(Scheme2Blocked.build(code, mom, lr=prob.lr,
                                             decode_iters=12), prob, model)
    it_unc = _iters_to(Uncoded(prob.X, prob.y, w=40, lr=prob.lr), prob, model)
    it_kar = _iters_to(Karakus.build(prob.X, prob.y, 40, lr=prob.lr * 0.8,
                                     kind="gaussian"), prob, model)
    assert it_ldpc <= it_unc, (it_ldpc, it_unc)
    assert it_ldpc < it_kar, (it_ldpc, it_kar)


def test_higher_straggler_rate_degrades_gracefully():
    """More stragglers -> slower but still-converging optimization (the
    (1-q_D) scale enters the rate, Theorem 1)."""
    prob = make_linear_problem(m=1024, k=100, seed=1)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=1)
    iters = []
    for q0 in (0.0, 0.15, 0.3):
        sch = Scheme2Blocked.build(code, mom, lr=prob.lr, decode_iters=10)
        iters.append(_iters_to(sch, prob, BernoulliStragglers(q0), key=int(q0 * 10)))
    assert iters[0] <= iters[1] <= iters[2] * 1.5  # monotone-ish, all finite
    assert iters[2] < 600  # still converges at q0 = 0.3


def test_decode_budget_quality_tradeoff():
    """Fewer decode rounds D -> more zero-filled coordinates -> more steps;
    the D knob trades master compute for convergence (Section 3)."""
    prob = make_linear_problem(m=1024, k=100, seed=2)
    mom = second_moment(prob.X, prob.y)
    code = make_regular_ldpc(20, l=3, r=6, seed=2)
    model = BernoulliStragglers(0.25)
    it_small_D = _iters_to(Scheme2Blocked.build(code, mom, lr=prob.lr,
                                                decode_iters=1), prob, model)
    it_big_D = _iters_to(Scheme2Blocked.build(code, mom, lr=prob.lr,
                                              decode_iters=12), prob, model)
    assert it_big_D <= it_small_D
