# NOTE: deliberately does NOT set XLA_FLAGS / host device count — smoke tests
# and benchmarks must see the single real CPU device.  Only launch/dryrun.py
# (run as its own process) forces 512 placeholder devices.
import os

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
