"""Check-axis-tiled fused decode: parity past the whole-H-in-VMEM regime.

The tiled kernels' contract (kernels/ldpc_peel/kernel.py): every tile's
resolution proposal is computed against the ROUND-START state and merged
first-tile-wins, so the tiled schedule is still flooding with the global
lowest-index-check tie-break — bit-identical erasure trajectories to the
dense/sparse/resident-pallas backends, values equal up to f32 summation
order (same per-row math, same merge winner).  These tests prove
it at the sizes the resident kernel cannot serve (N ∈ {2048, 4096, 8192},
interpret mode on CPU — codes built parity-only, the trajectory never
needs a generator), on ragged tile edges (p not divisible by bp), across
all four fused variants (fixed / adaptive / batch / batch-adaptive), and
through the decoder/engine dispatch (``backend="pallas_tiled"``, VMEM
estimate, tile knobs).
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.decoder import (
    peel_decode,
    peel_decode_adaptive,
    peel_decode_batch,
    peel_decode_batch_adaptive,
    pick_tile_bp,
    resolve_backend,
    vmem_bytes_estimate,
)
from repro.core.engine import CodedComputeEngine
from repro.core.ldpc import make_parity_only_ldpc, make_regular_ldpc

LARGE_NS = (2048, 4096, 8192)
D = 5


@functools.lru_cache(maxsize=None)
def _parity_code(K):
    return make_parity_only_ldpc(K, l=3, r=6, seed=0)


def _instance(code, *, q=0.25, seed=0, V=None):
    """Random payload + erasure pattern.  The decode trajectory depends
    only on H and the mask, so a non-codeword payload tests it fully
    (parity-only codes have no generator to encode with)."""
    rng = np.random.default_rng(seed)
    shape = (code.N,) if V is None else (code.N, V)
    vals = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < q)
    rx = jnp.where(erased if V is None else erased[:, None], 0.0, vals)
    return rx, erased


# ------------------------------------------------------- large-N parity --


@pytest.mark.parametrize("N", LARGE_NS)
def test_tiled_matches_dense_and_sparse_at_large_n(N):
    """Fixed-D parity at sizes the resident kernel cannot hold: the tiled
    erasure trajectory is bit-identical to dense AND sparse, and the
    decoded values agree (f32 summation order is the only slack)."""
    code = _parity_code(N // 2)
    rx, erased = _instance(code, seed=N)
    ref = peel_decode(code, rx, erased, D, backend="dense")
    sp = peel_decode(code, rx, erased, D, backend="sparse")
    tiled = peel_decode(code, rx, erased, D, backend="pallas_tiled",
                        bp=512, bv=8)
    np.testing.assert_array_equal(np.asarray(tiled.erased),
                                  np.asarray(ref.erased))
    np.testing.assert_array_equal(np.asarray(sp.erased),
                                  np.asarray(ref.erased))
    assert int(tiled.rounds_used) == D
    # Values: random (non-codeword) payloads make resolved values pure
    # cancellation noise, so cross-backend value tolerance is meaningless
    # here — the exact value claim is tiled == resident bit-for-bit
    # (test_tiled_bit_identical_values_to_resident); this test pins the
    # trajectory, plus the UNTOUCHED coordinates staying bit-identical.
    still = ~np.asarray(erased)
    np.testing.assert_array_equal(np.asarray(tiled.values)[still],
                                  np.asarray(ref.values)[still])


def test_tiled_values_match_resident():
    """On the fixed path the tiled round is the same per-row math as the
    resident round with an equivalent merge winner, so values agree to f32
    summation order (XLA may block the row-sum reduction differently per
    tile shape — observed ~1e-4); trajectories are bit-identical always."""
    code = _parity_code(1024)          # N=2048: resident still traceable
    rx, erased = _instance(code, seed=1)
    res = peel_decode(code, rx, erased, D, backend="pallas")
    for bp in (128, 512):
        tiled = peel_decode(code, rx, erased, D, backend="pallas_tiled",
                            bp=bp, bv=8)
        np.testing.assert_array_equal(np.asarray(tiled.erased),
                                      np.asarray(res.erased))
        np.testing.assert_allclose(np.asarray(tiled.values),
                                   np.asarray(res.values),
                                   rtol=1e-3, atol=1e-3)
    # single-tile stream (bp = p): still one launch, same trajectory
    one_tile = peel_decode(code, rx, erased, D, backend="pallas_tiled",
                           bp=code.p, bv=8)
    np.testing.assert_array_equal(np.asarray(one_tile.erased),
                                  np.asarray(res.erased))


def test_all_four_tiled_variants_at_8192():
    """The acceptance config: fixed, adaptive, batch, and batch-adaptive
    fused decodes all run at N = 8192 f32 in interpret mode via the tiled
    path, bit-identical trajectories (and round counts) to the dense
    reference."""
    code = _parity_code(4096)
    kw = dict(backend="pallas_tiled", bp=512, bv=8)

    # fixed
    rx, erased = _instance(code, seed=2)
    ref = peel_decode(code, rx, erased, D, backend="dense")
    got = peel_decode(code, rx, erased, D, **kw)
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))

    # adaptive: same early-exit round count
    refa = peel_decode_adaptive(code, rx, erased, 24, backend="dense")
    gota = peel_decode_adaptive(code, rx, erased, 24, **kw)
    assert int(gota.rounds_used) == int(refa.rounds_used)
    np.testing.assert_array_equal(np.asarray(gota.erased),
                                  np.asarray(refa.erased))

    # batch of independent patterns == per-slot single decodes
    B = 2
    rng = np.random.default_rng(3)
    valsB = jnp.asarray(rng.standard_normal((B, code.N)), jnp.float32)
    erasedB = jnp.asarray(rng.random((B, code.N)) < 0.25)
    rxB = jnp.where(erasedB, 0.0, valsB)
    gotB = peel_decode_batch(code, rxB, erasedB, D, **kw)
    for i in range(B):
        ri = peel_decode(code, rxB[i], erasedB[i], D, backend="dense")
        np.testing.assert_array_equal(np.asarray(gotB.erased[i]),
                                      np.asarray(ri.erased))

    # batch-adaptive: per-slot budgets, per-slot round counts
    budgets = jnp.asarray([2, 24], jnp.int32)
    gotBA = peel_decode_batch_adaptive(code, rxB, erasedB, 24,
                                       budgets=budgets, **kw)
    for i in range(B):
        ri = peel_decode_adaptive(code, rxB[i], erasedB[i],
                                  int(budgets[i]), backend="dense")
        assert int(gotBA.rounds_used[i]) == int(ri.rounds_used)
        np.testing.assert_array_equal(np.asarray(gotBA.erased[i]),
                                      np.asarray(ri.erased))


# ------------------------------------------------------ ragged tile edges --


@pytest.mark.parametrize("bp", [48, 64, 128])
def test_ragged_tile_edges(bp):
    """p = 100 is not divisible by any of these bp: the wrapper pads the
    check axis with all-zero rows (never solvable) and the trajectory must
    not move — mask bit-equal to dense, values f32-close to the resident
    kernel and exact against the true codeword tolerance."""
    code = make_regular_ldpc(100, l=3, r=6, seed=7)   # p = 100, N = 200
    rng = np.random.default_rng(7)
    cw = jnp.asarray(code.encode(rng.standard_normal((100, 3))), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < 0.3)
    rx = jnp.where(erased[:, None], 0.0, cw)
    ref = peel_decode(code, rx, erased, 10, backend="dense")
    res = peel_decode(code, rx, erased, 10, backend="pallas")
    tiled = peel_decode(code, rx, erased, 10, backend="pallas_tiled", bp=bp)
    np.testing.assert_array_equal(np.asarray(tiled.erased),
                                  np.asarray(ref.erased))
    np.testing.assert_allclose(np.asarray(tiled.values),
                               np.asarray(res.values), rtol=1e-3, atol=1e-3)
    ok = ~np.asarray(tiled.erased)
    np.testing.assert_allclose(np.asarray(tiled.values)[ok],
                               np.asarray(cw)[ok], rtol=5e-2, atol=5e-2)


def test_tiled_with_payload_axis_and_batch():
    """(N, V) payloads and (B, N, V) batches through the tiled wrappers
    (padding + unpadding on every axis at once)."""
    code = make_regular_ldpc(60, l=3, r=6, seed=3)    # N = 120: ragged N too
    rng = np.random.default_rng(3)
    cw = jnp.asarray(code.encode(rng.standard_normal((60, 5))), jnp.float32)
    erased = jnp.asarray(rng.random(code.N) < 0.3)
    rx = jnp.where(erased[:, None], 0.0, cw)
    ref = peel_decode(code, rx, erased, 8, backend="dense")
    got = peel_decode(code, rx, erased, 8, backend="pallas_tiled", bp=32)
    np.testing.assert_array_equal(np.asarray(got.erased),
                                  np.asarray(ref.erased))
    assert got.values.shape == cw.shape

    B = 3
    erB = jnp.asarray(rng.random((B, code.N)) < 0.3)
    rxB = jnp.where(erB[:, :, None], 0.0, jnp.stack([cw] * B))
    gotB = peel_decode_batch(code, rxB, erB, 8, backend="pallas_tiled", bp=32)
    for i in range(B):
        ri = peel_decode(code, rxB[i], erB[i], 8, backend="dense")
        np.testing.assert_array_equal(np.asarray(gotB.erased[i]),
                                      np.asarray(ri.erased))


def test_tiled_budget_zero_and_none_erased():
    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    rng = np.random.default_rng(0)
    cw = jnp.asarray(code.encode(rng.standard_normal(64)), jnp.float32)
    # nothing erased: identity
    res = peel_decode(code, cw, jnp.zeros(code.N, bool), 5,
                      backend="pallas_tiled", bp=32)
    assert not bool(res.erased.any())
    np.testing.assert_array_equal(np.asarray(res.values), np.asarray(cw))
    # per-slot budget 0: slot returned untouched with 0 rounds
    erased = jnp.asarray(rng.random(code.N) < 0.3)
    rx = jnp.where(erased, 0.0, cw)
    out = peel_decode_batch_adaptive(
        code, rx[None], erased[None], 10,
        budgets=jnp.asarray([0], jnp.int32), backend="pallas_tiled", bp=32)
    assert int(out.rounds_used[0]) == 0
    np.testing.assert_array_equal(np.asarray(out.erased[0]),
                                  np.asarray(erased))


# ------------------------------------------------- one-launch + dispatch --


def test_tiled_decodes_are_one_kernel_launch():
    """Every tiled variant keeps the one-``pallas_call`` property — the
    streaming happens INSIDE the kernel, not as a launch-per-tile."""
    from repro.kernels.ldpc_peel.ops import (
        _peel_decode_adaptive_tiled_impl,
        _peel_decode_batch_adaptive_tiled_impl,
        _peel_decode_batch_tiled_impl,
        _peel_decode_tiled_impl,
    )

    code = make_regular_ldpc(40, l=3, r=6, seed=0)
    H = jnp.asarray(code.H, jnp.float32)
    v = jnp.zeros((code.N, 4), jnp.float32)
    e = jnp.zeros((code.N,), bool)
    vB = jnp.zeros((6, code.N, 4), jnp.float32)
    eB = jnp.zeros((6, code.N), bool)
    bud = jnp.zeros((6,), jnp.int32)

    cases = [
        (_peel_decode_tiled_impl,
         lambda fn: fn(H, v, e, iters=10, bp=16, interpret=True)),
        (_peel_decode_batch_tiled_impl,
         lambda fn: fn(H, vB, eB, iters=10, bp=16, interpret=True)),
        (_peel_decode_adaptive_tiled_impl,
         lambda fn: fn(H, v, e, max_iters=40, bp=16, interpret=True)),
        (_peel_decode_batch_adaptive_tiled_impl,
         lambda fn: fn(H, vB, eB, bud, bp=16, interpret=True)),
    ]
    for impl, call in cases:
        jaxpr = jax.make_jaxpr(lambda *a, fn=impl.__wrapped__, c=call: c(fn))()
        assert str(jaxpr).count("pallas_call") == 1, impl


def test_tiled_kernel_rejects_unpadded_operands():
    """The tile loops floor-divide, so unpadded operands would silently
    drop trailing check rows — the kernel entry points must refuse them
    (the ops.py wrappers pad before calling)."""
    from repro.kernels.ldpc_peel import decode_fused_tiled

    H = jnp.zeros((100, 256), jnp.float32)        # p=100 not % bp=48
    v = jnp.zeros((256, 8), jnp.float32)
    e = jnp.zeros((256, 1), jnp.float32)
    with pytest.raises(ValueError, match="pre-padded"):
        decode_fused_tiled(H, v, e, iters=2, bp=48, bv=8, interpret=True)


def test_vmem_estimate_and_tile_knobs():
    small = make_regular_ldpc(64, l=3, r=6, seed=0)
    est_small = vmem_bytes_estimate(small)
    est_big = vmem_bytes_estimate((4096, 8192))          # raw (p, N) shape
    assert est_small < 1 * 2**20 < est_big               # monotone in size
    assert est_big > 512 * 2**20                         # resident can't fit
    with pytest.raises(ValueError):
        vmem_bytes_estimate(small, batch=0)
    # pick_tile_bp: 8-aligned, within [8, p], shrinking with the budget
    bp = pick_tile_bp((4096, 8192))
    assert bp % 8 == 0 and 8 <= bp <= 4096
    assert pick_tile_bp((4096, 8192), vmem_budget_bytes=2**20) < bp
    # explicit backend name resolves; tuples are rejected like pallas
    assert resolve_backend("pallas_tiled", small) == "pallas_tiled"
    tup = (jnp.asarray(small.H, jnp.float32), jnp.asarray(small.H_mask))
    with pytest.raises(ValueError):
        resolve_backend("pallas_tiled", tup)


def test_engine_tiled_dispatch_and_debug_info():
    """The engine threads tile knobs through decode/decode_batch and
    reports the resolved dispatch (chosen backend + VMEM numbers)."""
    code = make_regular_ldpc(64, l=3, r=6, seed=0)
    eng = CodedComputeEngine(code, decode_iters=8, backend="pallas_tiled",
                             bp=16, bv=8)
    info = eng.debug_info()
    assert info["resolved_backend"] == "pallas_tiled"
    assert info["bp"] == 16 and info["vmem_bytes_estimate"] > 0
    ref = CodedComputeEngine(code, decode_iters=8, backend="dense")
    rng = np.random.default_rng(0)
    cw = jnp.asarray(code.encode(rng.standard_normal(64)), jnp.float32)
    sym = jnp.stack([cw] * 2)
    mask = jnp.asarray(rng.random((2, code.N)) < 0.25)
    got_v, got_u = eng.recover_batch(sym, mask)
    ref_v, ref_u = ref.recover_batch(sym, mask)
    np.testing.assert_array_equal(np.asarray(got_u), np.asarray(ref_u))
    np.testing.assert_allclose(np.asarray(got_v), np.asarray(ref_v),
                               rtol=5e-2, atol=5e-2)
    # default-budget auto stays off the tiled path off-TPU (sparse/dense)
    assert resolve_backend("auto", code) in ("dense", "sparse")
