"""Density evolution (Proposition 2) and its empirical agreement."""
import numpy as np
import pytest

from repro.core.decoder import erased_after
from repro.core.density_evolution import q_final, qd_sequence, threshold
from repro.core.ldpc import make_regular_ldpc


def test_recursion_values():
    qs = qd_sequence(0.1, 3, 6, 3)
    # hand-check one step: q1 = q0 * (1 - (1-q0)^5)^2
    q1 = 0.1 * (1.0 - 0.9 ** 5) ** 2
    assert np.isclose(qs[1], q1)
    assert qs.shape == (4,)


def test_monotone_below_threshold():
    qs = qd_sequence(0.35, 3, 6, 50)
    assert np.all(np.diff(qs) <= 1e-12)
    assert qs[-1] < 1e-6


def test_not_vanishing_above_threshold():
    qs = qd_sequence(0.48, 3, 6, 500)
    assert qs[-1] > 0.1


def test_threshold_3_6():
    # Richardson-Urbanke: q*(3,6) ≈ 0.4294
    q = threshold(3, 6)
    assert abs(q - 0.4294) < 2e-3


def test_threshold_4_8_smaller_than_3_6():
    assert threshold(4, 8) < threshold(3, 6)


@pytest.mark.parametrize("q0", [0.05, 0.15, 0.25])
def test_density_evolution_matches_empirical(q0):
    """On a long code, the fraction of unresolved coordinates after D rounds
    should track q_D (Proposition 2 is an asymptotic statement)."""
    code = make_regular_ldpc(600, l=3, r=6, seed=4)
    rng = np.random.default_rng(0)
    D = 6
    fracs = []
    for t in range(20):
        erased = rng.random(code.N) < q0
        rem = erased_after(code, erased, D)
        fracs.append(rem.sum() / code.N)
    emp = float(np.mean(fracs))
    qd = q_final(q0, 3, 6, D)
    # empirical should be in the ballpark of density evolution (finite-n gap)
    assert abs(emp - qd) < max(0.05, 3.0 * qd)
