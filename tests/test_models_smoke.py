"""Per-architecture smoke tests (assignment requirement): instantiate the
REDUCED variant of each family (2 layers, d_model<=512, <=4 experts), run one
forward AND one train step on CPU, assert output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.data.batches import make_batch
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update

ARCHS = [
    "qwen3-1.7b", "codeqwen1.5-7b", "jamba-1.5-large-398b", "whisper-medium",
    "minitron-8b", "deepseek-v2-236b", "kimi-k2-1t-a32b", "qwen2-1.5b",
    "internvl2-2b", "rwkv6-3b",
]

B, SEQ = 2, 32


def test_all_assigned_archs_registered():
    known = list_configs()
    for a in ARCHS:
        assert a in known, f"{a} missing from registry"
    assert len(ARCHS) == 10


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).reduced()
            model = Model(cfg, remat=False, attn_chunk=16)
            params = model.init(jax.random.PRNGKey(0))
            cache[name] = (cfg, model, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_and_finite(name, built):
    cfg, model, params = built(name)
    batch = make_batch(cfg, B, SEQ)
    logits, aux = model.forward(params, batch)
    S_total = SEQ if cfg.family != "vlm" else SEQ  # vlm: patches+text == SEQ
    assert logits.shape == (B, S_total, cfg.vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_no_nans(name, built):
    cfg, model, params = built(name)
    batch = make_batch(cfg, B, SEQ)

    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), f"{name}: loss={loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves, "no gradients"
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all(), f"{name}: NaN grad"
    # one optimizer step actually changes the params
    state = adamw_init(params)
    new_params, _ = adamw_update(params, grads, state, AdamWConfig(lr=1e-3))
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32) -
                                                    b.astype(jnp.float32)).max()),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0.0


@pytest.mark.parametrize("name", ARCHS)
def test_loss_decreases_few_steps(name, built):
    """3 steps of AdamW on a fixed batch must reduce the loss (sanity that
    gradients point the right way for every family)."""
    cfg, model, params = built(name)
    batch = make_batch(cfg, B, SEQ)
    params = jax.tree.map(jnp.copy, params)
    state = adamw_init(params)
    cfgo = AdamWConfig(lr=5e-3, weight_decay=0.0)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        params, state = adamw_update(params, grads, state, cfgo)
        return params, state, loss

    losses = []
    for _ in range(4):
        params, state, loss = step(params, state)
        losses.append(float(loss))
    assert losses[-1] < losses[0], f"{name}: {losses}"


@pytest.mark.parametrize("name", ARCHS)
def test_param_counts_positive(name, built):
    cfg, model, params = built(name)
    total = model.param_count(params)
    active = model.active_param_count(params)
    assert total > 0 and 0 < active <= total
    if cfg.moe:
        assert active < total  # MoE must have inactive experts


def test_stack_plans():
    assert get_config("qwen3-1.7b").stack_plan() == (0, 1)
    assert get_config("deepseek-v2-236b").stack_plan() == (1, 1)
    assert get_config("kimi-k2-1t-a32b").stack_plan() == (1, 1)
    assert get_config("jamba-1.5-large-398b").stack_plan() == (0, 8)
    assert get_config("rwkv6-3b").stack_plan() == (0, 1)
    # jamba: exactly one attention layer per 8, MoE every 2nd
    specs = get_config("jamba-1.5-large-398b").layer_specs()
    assert sum(1 for m, _ in specs if m == "attn") == 72 // 8
    assert sum(1 for _, f in specs if f == "moe") == 72 // 2


def test_full_config_dims_match_assignment():
    c = get_config("qwen3-1.7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (28, 2048, 16, 8, 6144, 151936) and c.qk_norm
    c = get_config("codeqwen1.5-7b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (32, 4096, 32, 32, 13440, 92416) and c.qkv_bias
    c = get_config("jamba-1.5-large-398b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (72, 8192, 64, 8, 65536)
    assert (c.moe.n_experts, c.moe.top_k) == (16, 2)
    c = get_config("whisper-medium")
    assert (c.n_layers, c.d_model, c.n_heads, c.d_ff, c.vocab) == \
        (24, 1024, 16, 4096, 51865) and c.enc_layers == 24
    c = get_config("minitron-8b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (32, 4096, 32, 8, 16384, 256000)
    c = get_config("deepseek-v2-236b")
    assert (c.n_layers, c.d_model, c.n_heads, c.vocab) == (60, 5120, 128, 102400)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.n_shared) == (160, 6, 2)
    assert c.mla.kv_lora == 512
    c = get_config("kimi-k2-1t-a32b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (61, 7168, 64, 8, 163840)
    assert (c.moe.n_experts, c.moe.top_k, c.moe.d_ff_expert) == (384, 8, 2048)
    c = get_config("qwen2-1.5b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (28, 1536, 12, 2, 8960, 151936) and c.qkv_bias
    c = get_config("internvl2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff, c.vocab) == \
        (24, 2048, 16, 8, 8192, 92553) and c.n_patches == 256
    c = get_config("rwkv6-3b")
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (32, 2560, 8960, 65536)
    assert c.attn_every == 0 and c.rwkv is not None
