"""Paper Figure 2: sparse recovery in an OVERDETERMINED system (m = 2048,
k ∈ {800, 1000}, sparsity fraction f ∈ {0.1..0.5}), IHT with coded gradients.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import build_schemes, iterations_to_converge, print_table
from repro.data import make_sparse_problem
from repro.optim import projections


def run(*, ks=(800, 1000), fracs=(0.1, 0.3, 0.5), stragglers=(5, 10),
        trials=2, steps=1200, tol=2e-2) -> list[dict]:
    results = []
    for k in ks:
        for f in fracs:
            u = int(k * f)
            for s in stragglers:
                per: dict[str, list] = {}
                for trial in range(trials):
                    prob = make_sparse_problem(m=2048, k=k, u=u, seed=trial)
                    schemes = build_schemes(
                        prob, projection=projections.hard_threshold(u),
                        seed=trial)
                    for name, sch in schemes.items():
                        iters, final = iterations_to_converge(
                            sch, prob, s, steps=steps, tol=tol,
                            key=jax.random.PRNGKey(trial))
                        per.setdefault(name, []).append(
                            (iters if iters is not None else steps, final))
                for name, runs in per.items():
                    results.append({
                        "k": k, "f": f, "s": s, "scheme": name,
                        "iters": float(np.mean([r[0] for r in runs])),
                        "final_err": float(np.mean([r[1] for r in runs])),
                    })
    return results


def main(quick: bool = False):
    kw = dict(ks=(800,), fracs=(0.1, 0.3), trials=1, steps=800) if quick else {}
    results = run(**kw)
    rows = [[r["k"], r["f"], r["s"], r["scheme"], f"{r['iters']:.0f}",
             f"{r['final_err']:.3f}"] for r in results]
    print_table("Fig 2 — sparse recovery, overdetermined (m=2048, IHT)",
                ["k", "f", "s", "scheme", "iters", "final_rel_err"], rows)
    return results


if __name__ == "__main__":
    main()
