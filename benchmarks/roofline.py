"""§Roofline report: aggregates artifacts/dryrun/*.json into the per
(arch x shape x mesh) table — compute / memory / collective terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPS useful ratio — and nominates hillclimb
candidates (worst roofline fraction; most collective-bound; most
paper-representative).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import print_table

ARTIFACTS = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_reports() -> list[dict]:
    if not ARTIFACTS.exists():
        return []
    out = []
    for f in sorted(ARTIFACTS.glob("*.json")):
        try:
            out.append(json.loads(f.read_text()))
        except Exception:
            pass
    return [r for r in out if r.get("ok")]


def _write_markdown(reports):
    """Emit artifacts/roofline.md (EXPERIMENTS.md §Dry-run table source)."""
    lines = ["| arch | shape | mesh | compute_ms | memory_ms | collective_ms "
             "| bound | useful | extrap |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(reports, key=lambda r: (r["arch"], r["shape"],
                                            r.get("variant", ""), r["mesh"])):
        shape = r["shape"] + (f"+{r['variant']}" if r.get("variant") else "")
        lines.append(
            f"| {r['arch']} | {shape} | {r['mesh']} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | {'y' if r.get('extrapolated') else ''} |")
    (ARTIFACTS.parent / "roofline.md").write_text("\n".join(lines) + "\n")


def main(quick: bool = False):
    reports = [r for r in load_reports() if r["mesh"] != "2x2"]
    if not reports:
        print("\n### Roofline: no dry-run artifacts yet "
              "(run python -m repro.launch.dryrun --all first)")
        return []
    rows = []
    for r in sorted(reports, key=lambda r: (r["arch"], r["shape"],
                                            r.get("variant", ""), r["mesh"])):
        shape = r["shape"] + (f"+{r['variant']}" if r.get("variant") else "")
        rows.append([
            r["arch"], shape, r["mesh"],
            f"{r['compute_s']*1e3:.2f}", f"{r['memory_s']*1e3:.2f}",
            f"{r['collective_s']*1e3:.2f}", r["dominant"],
            f"{r['useful_ratio']:.2f}",
        ])
    print_table("Roofline terms per (arch x shape x mesh) — ms/step, per chip",
                ["arch", "shape", "mesh", "compute_ms", "memory_ms",
                 "collective_ms", "bound", "useful"], rows)
    _write_markdown(reports)

    # hillclimb candidate nomination
    single = [r for r in reports if r["mesh"] == "16x16"]
    if single:
        def frac(r):
            tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
            return r["compute_s"] / tot if tot else 0.0
        worst = min(single, key=frac)
        coll = max(single, key=lambda r: r["collective_s"] /
                   max(r["compute_s"] + r["memory_s"] + r["collective_s"], 1e-12))
        print(f"\nhillclimb candidates: worst-compute-fraction = "
              f"{worst['arch']} x {worst['shape']}; most-collective-bound = "
              f"{coll['arch']} x {coll['shape']}")
    return rows


if __name__ == "__main__":
    main()
