"""Paper Figure 1: least-squares estimation, m = 2048,
k ∈ {200, 400, 800, 1000}, s ∈ {5, 10} stragglers out of w = 40.

Reports iterations-to-converge and simulated wall time per scheme
(LDPC moment encoding vs uncoded / 2-replication / KSDY17 data encoding).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    build_schemes,
    iterations_to_converge,
    master_step_seconds,
    print_table,
    simulated_wall_time,
)
from repro.data import make_linear_problem


def run(*, ks=(200, 400, 800, 1000), stragglers=(5, 10), trials=3,
        steps=1200, tol=2e-2) -> list[dict]:
    results = []
    for k in ks:
        for s in stragglers:
            per_scheme: dict[str, list] = {}
            for trial in range(trials):
                prob = make_linear_problem(m=2048, k=k, seed=trial)
                schemes = build_schemes(prob, seed=trial)
                for name, sch in schemes.items():
                    iters, final = iterations_to_converge(
                        sch, prob, s, steps=steps, tol=tol,
                        key=jax.random.PRNGKey(100 + trial))
                    per_scheme.setdefault(name, []).append(
                        (iters if iters is not None else steps, final,
                         sch, prob))
            for name, runs in per_scheme.items():
                iters_m = float(np.mean([r[0] for r in runs]))
                master_s = master_step_seconds(runs[0][2], runs[0][3], s, reps=5)
                wall = simulated_wall_time(int(iters_m), master_s, s)
                results.append({
                    "k": k, "s": s, "scheme": name,
                    "iters": iters_m,
                    "final_err": float(np.mean([r[1] for r in runs])),
                    "master_ms": master_s * 1e3,
                    "sim_wall_s": wall,
                })
    return results


def main(quick: bool = False):
    kw = dict(ks=(200, 400), trials=2, steps=800) if quick else {}
    results = run(**kw)
    rows = [[r["k"], r["s"], r["scheme"], f"{r['iters']:.0f}",
             f"{r['final_err']:.3f}", f"{r['master_ms']:.2f}",
             f"{r['sim_wall_s']:.2f}"] for r in results]
    print_table("Fig 1 — least squares (m=2048, w=40)",
                ["k", "s", "scheme", "iters", "final_rel_err",
                 "master_ms/step", "sim_wall_s"], rows)
    return results


if __name__ == "__main__":
    main()
