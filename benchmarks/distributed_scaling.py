"""Distributed coded-GD scaling: worker counts, straggler climates, and the
telemetry-vs-fixed decode-budget comparison.

Run under a fake CPU worker mesh (or a real accelerator slice):

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -c \\
      "from benchmarks.distributed_scaling import main; main(quick=True)"

Sections:

  1. distributed overhead — per-step latency of the master/worker
     :class:`repro.distributed.DistributedCodedGD` step (sharded worker
     matvec + gather + master decode, two launches + host control) vs the
     jitted single-device ``Scheme2`` step, over worker counts.
     ``single_vs_distributed`` is a SAME-RUN ratio (both sides timed in one
     run on one machine), which is what ``check_regression.py`` gates — a
     code change that bloats the distributed control path moves it
     directly, a slower runner moves both sides and cancels.
  2. telemetry budget sweep — one run through a MIXED straggler climate
     (calm → storm → calm phases) with the online EMA estimator choosing
     per-step decode budgets, vs the fixed worst-case budget the paper's
     fixed-D decode would burn every step.  ``round_savings`` (fixed /
     telemetry mean decode rounds) is deterministic for a fixed seed (the
     masks and decode trajectories are PRNG-derived), so the gate is
     noise-free.  Decode quality (mean unresolved) is recorded for both
     so the savings cannot silently come from giving up on recovery.
  3. master decode-stream serving — the per-step survivor patterns of
     several concurrent distributed runs served through the SHARED
     continuous-admission slot lifecycle
     (``benchmarks.decoder_scaling.serve_continuous`` driving
     ``serving.slot_lifecycle.SlotPool``) — the multi-tenant master story.
  4. pipeline (schema v7) — the depth-k pipelined runtime
     (:class:`repro.distributed.pipeline.AsyncDistributedCodedGD`) vs the
     synchronous barrier driver, BOTH under one deterministic injected
     delay schedule in a decode-heavy regime (fixed-D master decode
     calibrated to the wait-for order statistic).  Two same-run ratios:
     ``sim_steps_per_sec_ratio`` on the simulated clock the runtime has
     always recorded (``step_times`` = the injected wait at the cutoff,
     here extended with decode service time and the pipeline-overlap
     recurrence of :func:`repro.distributed.pipeline.pipeline_timeline`)
     — deterministic, carries the ≥1.5× HARD floor — and
     ``host_steps_per_sec_ratio``, the measured wall-clock of the two
     driver loops (machine-dependent: a single-core host serializes the
     overlapped device programs and only keeps the control-plane savings;
     multi-core runners see the real overlap).  Convergence quality (mean
     unresolved AFTER late folds, final error) is recorded for BOTH modes
     and gated, so pipeline speed cannot hide quality loss.

Results are APPENDED to ``BENCH_decoder_scaling.json`` under
``"distributed_scaling"``; the rest of the file is left untouched.

Forcing ``--backend pallas`` past the VMEM limit no longer crashes the
sweep: the master decode backend is resolved through
``benchmarks.common.resolve_bench_backend`` with a printed failover.
"""
from __future__ import annotations

import contextlib
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, resolve_bench_backend
from benchmarks.decoder_scaling import serve_continuous
from repro.core import (
    BernoulliStragglers,
    ScheduledDelays,
    Scheme2,
    make_regular_ldpc,
    second_moment,
)
from repro.data import make_linear_problem
from repro.distributed import (
    AsyncDistributedCodedGD,
    DistributedCodedGD,
    StragglerRateEstimator,
    WorkerStragglers,
    WorkerTopology,
    make_worker_mesh,
    pipeline_timeline,
)

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_decoder_scaling.json"


def _build(K, *, decode_iters, backend="sparse", budget_mode="fixed",
           n_workers=8, seed=0, max_rounds=None, decay=0.8):
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    # A forced backend the master cannot actually decode with at this N
    # (e.g. --backend pallas past the VMEM limit) fails over with a clear
    # message instead of crashing the sweep.
    backend, msg = resolve_bench_backend(code, backend)
    if msg:
        print(f"[distributed K={K}] {msg}")
    prob = make_linear_problem(m=2 * K, k=K, seed=seed)
    scheme = Scheme2.build(code, second_moment(prob.X, prob.y), lr=prob.lr,
                           decode_iters=decode_iters, decode_backend=backend)
    topo = WorkerTopology(n_workers, code.N)
    # place on the largest device count that divides W (8 workers on an
    # 8-device mesh; 4 workers on 4 of them; odd fits fall back smaller)
    n_dev = jax.device_count()
    mesh_dev = max(d for d in range(1, min(n_workers, n_dev) + 1)
                   if n_workers % d == 0)
    dist = DistributedCodedGD(
        scheme, topo, make_worker_mesh(mesh_dev),
        budget_mode=budget_mode, max_rounds=max_rounds,
        estimator=StragglerRateEstimator(decay=decay))
    return code, scheme, topo, dist


def run_distributed_overhead(*, K=512, Ws=(2, 4, 8), q=0.125,
                             steps_per_rep=10, reps=3, backend="sparse"):
    """Per-step cost: master/worker DistributedCodedGD vs single-device
    Scheme2, same problem/key — returns (table_rows, json_records)."""
    rows, records = [], []
    for W in Ws:
        code, scheme, topo, dist = _build(K, decode_iters=8, n_workers=W,
                                          backend=backend)
        stragglers = WorkerStragglers(BernoulliStragglers(q), topo)
        keys = jax.random.split(jax.random.PRNGKey(0), steps_per_rep)
        masks = [stragglers.sample_workers(k) for k in keys]
        ref_step = jax.jit(scheme.step)
        sym_masks = [topo.to_symbol_erasure(m) for m in masks]

        def run_dist():
            th = jnp.zeros(K)
            for m in masks:
                th, _, _, _ = dist.step(th, m)
            th.block_until_ready()

        def run_single():
            th = jnp.zeros(K)
            for m in sym_masks:
                th, _ = ref_step(th, m)
            th.block_until_ready()

        run_dist(); run_single()            # compile + warm
        ratios, t_d, t_s = [], [], []
        for _ in range(reps):
            t0 = time.perf_counter(); run_dist()
            td = time.perf_counter() - t0
            t0 = time.perf_counter(); run_single()
            ts = time.perf_counter() - t0
            t_d.append(td); t_s.append(ts); ratios.append(ts / td)
        td = float(np.median(t_d)) / steps_per_rep
        ts = float(np.median(t_s)) / steps_per_rep
        ratio = float(np.median(ratios))
        records.append({
            "mode": "distributed-overhead", "W": W, "N": code.N, "K": K,
            "devices": int(dist.mesh.devices.size), "straggler_q": q,
            "per_step_us": td * 1e6, "single_per_step_us": ts * 1e6,
            "single_vs_distributed": ratio,
            "jax_backend": jax.default_backend(),
        })
        rows.append([W, int(dist.mesh.devices.size), code.N,
                     f"{td * 1e6:.0f}", f"{ts * 1e6:.0f}", f"{ratio:.2f}x"])
    return rows, records


# Mixed straggler climate for the telemetry sweep: calm → storm → calm.
PHASES = ((30, 0.05), (30, 0.3), (30, 0.1))


def run_telemetry_sweep(*, K=512, W=8, max_rounds=32, seed=0):
    """Telemetry-driven per-step budgets vs the fixed worst-case budget.

    Both runs see the SAME per-worker straggler realizations (same keys);
    the fixed run burns ``max_rounds`` decode rounds every step (the
    worst-case fixed-D budget the paper's Remark-3 monotonicity argument
    sizes for the heaviest climate), the telemetry run decodes adaptively
    under the EMA-chosen per-step budget.  Deterministic for a fixed seed.
    """
    code, scheme, topo, dist_fix = _build(
        K, decode_iters=max_rounds, n_workers=W, seed=seed,
        budget_mode="fixed")
    *_, dist_tel = _build(K, decode_iters=max_rounds, n_workers=W,
                          seed=seed, budget_mode="telemetry",
                          max_rounds=max_rounds)
    key = jax.random.PRNGKey(seed)
    masks = []
    for steps, q in PHASES:
        key, sub = jax.random.split(key)
        stragglers = WorkerStragglers(BernoulliStragglers(q), topo)
        for k in jax.random.split(sub, steps):
            masks.append(stragglers.sample_workers(k))

    def drive(dist):
        th = jnp.zeros(K)
        rounds, budgets, unresolved = [], [], []
        for m in masks:
            th, n_unres, spent, budget = dist.step(th, m)
            rounds.append(spent); budgets.append(budget)
            unresolved.append(n_unres)
        return (np.asarray(rounds), np.asarray(budgets),
                np.asarray(unresolved))

    r_fix, _, u_fix = drive(dist_fix)
    r_tel, b_tel, u_tel = drive(dist_tel)
    savings = float(r_fix.mean() / max(r_tel.mean(), 1e-9))
    # quality_preservation (fixed/telemetry unresolved, ≤1 when telemetry
    # gives something up) is GATED alongside round_savings: a budget cut
    # that buys rounds by abandoning recovery lowers it and fails CI.
    quality = float(u_fix.mean() / max(u_tel.mean(), 1e-9))
    record = {
        "mode": "telemetry", "W": W, "N": code.N, "K": K,
        "max_rounds": max_rounds, "steps": len(masks),
        "phases": [list(p) for p in PHASES],
        "fixed_mean_rounds": float(r_fix.mean()),
        "telemetry_mean_rounds": float(r_tel.mean()),
        "telemetry_mean_budget": float(b_tel.mean()),
        "fixed_mean_unresolved": float(u_fix.mean()),
        "telemetry_mean_unresolved": float(u_tel.mean()),
        "round_savings": savings,
        "quality_preservation": quality,
        "criterion_met": savings >= 1.5,
        "jax_backend": jax.default_backend(),
    }
    row = [W, code.N, len(masks), f"{r_fix.mean():.1f}",
           f"{r_tel.mean():.2f}", f"{b_tel.mean():.1f}",
           f"{u_tel.mean():.2f}", f"{savings:.1f}x"]
    return [row], [record]


def run_master_stream(*, K=512, W=8, n_runs=6, steps=20, budget=32,
                      chunk=4, seed=0):
    """Multi-tenant master: serve several concurrent runs' per-step
    survivor patterns through the shared continuous slot lifecycle."""
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    topo = WorkerTopology(W, code.N)
    rng = np.random.default_rng(seed)
    qs = rng.uniform(0.05, 0.3, n_runs)
    msgs = rng.standard_normal((n_runs * steps, K))
    cws = (code.G @ msgs.T).T.astype(np.float32)
    worker_masks = np.concatenate(
        [rng.random((steps, W)) < q for q in qs])          # per-WORKER
    erased = np.asarray(
        topo.to_symbol_erasure(jnp.asarray(worker_masks)))  # lifted (N,)
    rx = np.where(erased, 0.0, cws)
    serve, stats = serve_continuous(code, rx, erased, B=W, budget=budget,
                                    chunk=chunk)
    serve()                             # compile + warm (pool rebuilt per run)
    t0 = time.perf_counter(); serve()
    t = time.perf_counter() - t0
    nq = rx.shape[0]
    record = {
        "mode": "master-stream", "W": W, "N": code.N, "K": K,
        "n_queries": nq, "budget": budget, "chunk": chunk,
        "launches": stats["launches"],
        "launch_rounds": stats["launch_rounds"],
        "slot_rounds": stats["slot_rounds"],
        "per_query_us": t / nq * 1e6,
        "jax_backend": jax.default_backend(),
    }
    row = [W, code.N, nq, stats["launches"], stats["launch_rounds"],
           f"{record['per_query_us']:.0f}"]
    return [row], [record]


def run_pipeline_section(*, K=512, W=8, steps=48, max_rounds=10, depth=2,
                         max_staleness=1, decay=0.5, reps=2, seed=0,
                         quick=False):
    """Pipelined vs synchronous runtime under one deterministic delay
    schedule (schema v7).

    Per step, three workers miss the wait-for cutoff: on two of every
    three steps one of them lands exactly one step late (foldable at
    lag 1) and two are hopeless (past ``max_staleness`` — today's drop);
    on the third step all three are hopeless.  Positions rotate so the
    erased codeword symbols vary.  The wait-for policy settles at 5-of-8,
    so the cut is 3/8 erasure — just inside q*(3,6) ≈ 0.43, where the
    scarce fixed-D budget (``max_rounds = 10``) runs out on bad rotations
    and leaves coordinates unresolved for the fold path to recover.

    The simulated clock prices a decode round at ``mean(wait) /
    max_rounds``: the full fixed-D budget costs exactly one worker phase —
    the balanced decode-heavy point where a depth-2 pipeline's ideal
    speedup is 2× (overlap hides ``min(worker, master)`` behind the max).
    Fold decodes bill the master's timeline too, so the recovery path
    cannot pretend to be free.  ``sim_steps_per_sec_ratio`` is
    deterministic for a fixed seed and carries the hard ≥1.5× floor;
    ``host_steps_per_sec_ratio`` is the measured wall-clock of the two
    driver loops and is gated only against its own baseline (a single-core
    host serializes the overlapped device programs).
    """
    if quick:
        steps, reps = 32, 1
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    backend, msg = resolve_bench_backend(code, "sparse")
    if msg:
        print(f"[pipeline K={K}] {msg}")
    prob = make_linear_problem(m=2 * K, k=K, seed=seed)
    # Delayed gradients (the depth-1 extra lag of the pipelined worker
    # launch) need a stepsize cut for stability; BOTH runtimes get the same
    # halved lr so the quality comparison is apples-to-apples.
    scheme = Scheme2.build(code, second_moment(prob.X, prob.y),
                           lr=prob.lr * 0.5, decode_iters=max_rounds,
                           decode_backend=backend)
    topo = WorkerTopology(W, code.N)
    n_dev = jax.device_count()
    mesh_dev = max(d for d in range(1, min(W, n_dev) + 1) if W % d == 0)
    mesh = make_worker_mesh(mesh_dev)
    sync = DistributedCodedGD(scheme, topo, mesh, budget_mode="fixed",
                              estimator=StragglerRateEstimator())
    pipe = AsyncDistributedCodedGD(scheme, topo, mesh, depth=depth,
                                   max_staleness=max_staleness,
                                   staleness_decay=decay,
                                   budget_mode="fixed",
                                   estimator=StragglerRateEstimator())

    row_fold = np.full(W, 1.0)
    row_fold[W - 3] = 1.5                 # lag-1: foldable next step
    row_fold[W - 2:] = 9.0                # never: past the fold window
    row_drop = np.full(W, 1.0)
    row_drop[W - 3:] = 9.0                # all three cut workers hopeless
    sched = np.stack([np.roll(row_fold if t % 3 != 2 else row_drop, t)
                      for t in range(steps)])

    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(seed)

    def reset():
        # Same telemetry trajectory every (timed) run: fresh EMA state
        # without rebuilding the drivers (which would re-jit their programs).
        for est in (sync.estimator, pipe.estimator):
            est._ema, est._norm, est.steps = 0.0, 0.0, 0

    def run_sync():
        reset()
        return sync.run(theta0, None, steps, key=key,
                        theta_star=prob.theta_star,
                        delay_model=ScheduledDelays.build(sched))

    def run_pipe():
        reset()
        return pipe.run(theta0, None, steps, key=key,
                        theta_star=prob.theta_star,
                        delay_model=ScheduledDelays.build(sched))

    rs, rp = run_sync(), run_pipe()       # compile + warm
    t_sync, t_pipe = [], []
    for _ in range(reps):
        t0 = time.perf_counter()
        rs = run_sync(); rs.theta.block_until_ready()
        t_sync.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        rp = run_pipe(); rp.theta.block_until_ready()
        t_pipe.append(time.perf_counter() - t0)
    ts, tp = float(np.median(t_sync)), float(np.median(t_pipe))
    host_ratio = ts / tp

    # Simulated clock: both runtimes' step_times are the injected wait at
    # the cutoff (identical schedules ⇒ identical waits); decode service is
    # rounds × c_round, and the pipeline's recurrence overlaps worker t+1
    # with master t (depth 2).  Sync is the same recurrence at depth 1.
    c_round = float(rs.step_times.mean()) / max_rounds
    _, m_sync = pipeline_timeline(rs.step_times, rs.rounds * c_round, 1)
    _, m_pipe = pipeline_timeline(
        rp.step_times, (rp.rounds + rp.fold_rounds) * c_round, depth)
    sim_ratio = float(m_sync[-1] / m_pipe[-1])

    sync_err = float(rs.errors[-1])
    pipe_err = float(rp.errors[-1])
    sync_unres = float(rs.unresolved.mean())
    pipe_unres = float(rp.unresolved.mean())
    record = {
        "mode": "pipeline", "W": W, "N": code.N, "K": K,
        "devices": int(mesh.devices.size), "steps": steps,
        "depth": depth, "max_staleness": max_staleness,
        "staleness_decay": decay, "max_rounds": max_rounds,
        "decode_round_cost": c_round,
        "sim_makespan_sync": float(m_sync[-1]),
        "sim_makespan_pipeline": float(m_pipe[-1]),
        "sim_steps_per_sec_ratio": sim_ratio,
        "host_steps_per_sec_ratio": host_ratio,
        "sync_per_step_us": ts / steps * 1e6,
        "pipeline_per_step_us": tp / steps * 1e6,
        "sync_mean_unresolved": sync_unres,
        "pipeline_mean_unresolved": pipe_unres,
        "sync_final_error": sync_err,
        "pipeline_final_error": pipe_err,
        "resolved_late_total": int(rp.resolved_late.sum()),
        "mean_fold_rounds": float(rp.fold_rounds.mean()),
        "criterion_met": bool(sim_ratio >= 1.5
                              and pipe_unres <= sync_unres + 1e-9
                              and pipe_err <= sync_err * 1.05),
        "jax_backend": jax.default_backend(),
    }
    trow = [W, code.N, steps, f"{sim_ratio:.2f}x", f"{host_ratio:.2f}x",
            f"{sync_unres:.2f}", f"{pipe_unres:.2f}",
            f"{sync_err:.4f}", f"{pipe_err:.4f}",
            int(rp.resolved_late.sum())]
    return [trow], [record]


def run_obs_overhead_section(*, K=256, W=8, steps=24, max_rounds=8,
                             depth=2, max_staleness=1, decay=0.5,
                             reps=3, seed=0, quick=False):
    """Observability overhead: the SAME pipelined run, instrumentation off
    vs on (metrics registry + span tracer both active), alternating reps.

    Three claims, two gated (schema v9):

      * ``bit_identical`` — the obs-on run's theta bits, per-step rounds,
        and unresolved counts equal the obs-off run's.  Instrumentation
        only ever touches already-fetched host values, so any divergence
        means a recording leaked into a traced program.
      * ``sim_steps_per_sec_ratio`` — obs-off / obs-on makespan on the
        deterministic simulated clock (identical trajectories ⇒ exactly
        1.0).  Gated ≥ 0.95: the ≤5% bound on instrumented sim overhead.
      * ``host_overhead_pct`` — measured wall-clock cost of recording
        (machine-dependent, recorded but NOT gated; CI runners are too
        noisy for a hard host-time floor).

    Non-vacuousness travels in the record: ``metrics_recorded`` and
    ``trace_events`` must be > 0 or the gate fails — a silently-disabled
    registry would otherwise make the overhead test pass trivially.
    """
    if quick:
        steps, reps = 16, 2
    from repro.obs import metrics as obs_metrics
    from repro.obs import trace as obs_trace
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    backend, msg = resolve_bench_backend(code, "sparse")
    if msg:
        print(f"[obs-overhead K={K}] {msg}")
    prob = make_linear_problem(m=2 * K, k=K, seed=seed)
    scheme = Scheme2.build(code, second_moment(prob.X, prob.y),
                           lr=prob.lr * 0.5, decode_iters=max_rounds,
                           decode_backend=backend)
    topo = WorkerTopology(W, code.N)
    n_dev = jax.device_count()
    mesh_dev = max(d for d in range(1, min(W, n_dev) + 1) if W % d == 0)
    mesh = make_worker_mesh(mesh_dev)
    pipe = AsyncDistributedCodedGD(scheme, topo, mesh, depth=depth,
                                   max_staleness=max_staleness,
                                   staleness_decay=decay,
                                   budget_mode="fixed",
                                   estimator=StragglerRateEstimator())
    row_fold = np.full(W, 1.0)
    row_fold[W - 3] = 1.5
    row_fold[W - 2:] = 9.0
    row_drop = np.full(W, 1.0)
    row_drop[W - 3:] = 9.0
    sched = np.stack([np.roll(row_fold if t % 3 != 2 else row_drop, t)
                      for t in range(steps)])
    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(seed)

    def reset():
        # Identical telemetry state every run: wait-for and fold-window
        # choices read the estimators, so bit-parity needs a clean slate.
        est, lag = pipe.estimator, pipe.lag_estimator
        est._ema, est._norm, est.steps = 0.0, 0.0, 0
        lag._mass[:] = 0.0
        lag._norm, lag.steps = 0.0, 0

    def run_once():
        reset()
        return pipe.run(theta0, None, steps, key=key,
                        theta_star=prob.theta_star,
                        delay_model=ScheduledDelays.build(sched))

    @contextlib.contextmanager
    def obs_off():
        # The "plain" leg must be sink-free even when the whole benchmark
        # runs under a global --obs-out session.
        prev_reg = obs_metrics.disable()
        prev_tr = obs_trace.disable_tracing()
        try:
            yield
        finally:
            if prev_reg is not None:
                obs_metrics.enable(prev_reg)
            if prev_tr is not None:
                obs_trace.enable_tracing(prev_tr)

    run_once()                                     # compile + warm
    t_plain, t_obs = [], []
    r_plain = r_obs = None
    metrics_recorded = trace_events = 0
    for _ in range(reps):
        with obs_off():
            t0 = time.perf_counter()
            r_plain = run_once(); r_plain.theta.block_until_ready()
            t_plain.append(time.perf_counter() - t0)
        reg, tracer = obs_metrics.MetricsRegistry(), obs_trace.Tracer()
        with obs_metrics.recording(reg), obs_trace.tracing(tracer):
            t0 = time.perf_counter()
            r_obs = run_once(); r_obs.theta.block_until_ready()
            t_obs.append(time.perf_counter() - t0)
        metrics_recorded = len(reg)
        trace_events = len(tracer.events)
    tp, to = float(np.median(t_plain)), float(np.median(t_obs))

    bit_identical = bool(
        np.asarray(r_plain.theta).tobytes() == np.asarray(r_obs.theta).tobytes()
        and np.array_equal(r_plain.rounds, r_obs.rounds)
        and np.array_equal(r_plain.unresolved, r_obs.unresolved))
    c_round = float(r_plain.step_times.mean()) / max_rounds
    _, m_plain = pipeline_timeline(
        r_plain.step_times, (r_plain.rounds + r_plain.fold_rounds) * c_round,
        depth)
    _, m_obs = pipeline_timeline(
        r_obs.step_times, (r_obs.rounds + r_obs.fold_rounds) * c_round,
        depth)
    sim_ratio = float(m_plain[-1] / m_obs[-1])
    host_overhead_pct = (to - tp) / tp * 100.0

    record = {
        "mode": "obs-overhead", "W": W, "N": code.N, "K": K,
        "devices": int(mesh.devices.size), "steps": steps, "depth": depth,
        "max_rounds": max_rounds,
        "sim_steps_per_sec_ratio": sim_ratio,
        "bit_identical": bit_identical,
        "host_overhead_pct": host_overhead_pct,
        "metrics_recorded": int(metrics_recorded),
        "trace_events": int(trace_events),
        "per_step_us_plain": tp / steps * 1e6,
        "per_step_us_obs": to / steps * 1e6,
        "jax_backend": jax.default_backend(),
    }
    row = [W, code.N, steps, f"{sim_ratio:.3f}x",
           "yes" if bit_identical else "NO",
           f"{host_overhead_pct:+.1f}%", metrics_recorded, trace_events]
    return [row], [record]


def main(quick: bool = False, json_path: str | Path = BENCH_JSON,
         backend: str | None = None, obs_out: str | Path | None = None):
    from repro.obs import ObsSession
    session = ObsSession.start(obs_out)
    try:
        return _main(quick=quick, json_path=json_path, backend=backend)
    finally:
        session.finish()


def _main(quick: bool = False, json_path: str | Path = BENCH_JSON,
          backend: str | None = None):
    n_dev = jax.device_count()
    if backend:
        # Forced-backend run (VMEM-failover path): only the overhead sweep,
        # smallest worker count, no JSON rewrite.
        orows, _ = run_distributed_overhead(reps=1, steps_per_rep=4,
                                            Ws=(2,), backend=backend)
        print_table(f"Distributed overhead — forced backend {backend!r} "
                    "(failover-resolved)",
                    ["W", "devices", "N", "dist_step_us", "single_step_us",
                     "single/dist"], orows)
        return orows
    orows, orecs = run_distributed_overhead(
        reps=2 if quick else 4,
        steps_per_rep=6 if quick else 12)
    print_table(
        f"Distributed overhead — DistributedCodedGD vs single-device "
        f"Scheme2 ({n_dev} devices)",
        ["W", "devices", "N", "dist_step_us", "single_step_us",
         "single/dist"], orows)

    trows, trecs = run_telemetry_sweep()
    print_table("Telemetry budgets — mixed straggler climate "
                "(calm/storm/calm), fixed worst-case vs EMA-chosen",
                ["W", "N", "steps", "fixed_rounds", "telemetry_rounds",
                 "mean_budget", "mean_unresolved", "round_savings"], trows)

    srows, srecs = run_master_stream()
    print_table("Master decode-stream serving (shared slot lifecycle)",
                ["W", "N", "queries", "launches", "launch_rounds",
                 "per_query_us"], srows)

    prows, precs = run_pipeline_section(quick=quick)
    print_table("Pipelined vs synchronous runtime (deterministic delay "
                "schedule, depth-2, fold window 1)",
                ["W", "N", "steps", "sim_ratio", "host_ratio",
                 "sync_unres", "pipe_unres", "sync_err", "pipe_err",
                 "folded"], prows)

    obrows, obrecs = run_obs_overhead_section(quick=quick)
    print_table("Observability overhead — pipelined run, instrumentation "
                "off vs on (metrics + tracer)",
                ["W", "N", "steps", "sim_ratio", "bit_identical",
                 "host_overhead", "metrics", "trace_events"], obrows)

    records = orecs + trecs + srecs + precs + obrecs
    path = Path(json_path)
    try:
        out = json.loads(path.read_text())
    except (FileNotFoundError, json.JSONDecodeError):
        out = {"benchmark": "decoder_scaling"}
    # v7: the pipeline section's records join distributed_scaling
    # v9: adds the "obs-overhead" record (instrumented-vs-plain pipelined
    # run: bit-identity, sim steps/sec ratio ≥ 0.95, non-vacuous
    # metric/trace counts — gated by check_regression --sections obs).
    out["schema_version"] = max(9, int(out.get("schema_version", 5)))
    out["distributed_scaling"] = records
    path.write_text(json.dumps(out, indent=2))
    print(f"\nappended distributed_scaling ({len(records)} records) "
          f"to {path}")
    return records


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["dense", "sparse", "pallas", "pallas_tiled"],
                    help="FORCE the master decode backend (failover-resolved "
                         "past the VMEM limit instead of crashing); skips "
                         "the JSON rewrite")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="export obs metrics JSONL (+ .trace.json spans) "
                         "from the instrumented sweeps to PATH")
    a = ap.parse_args()
    main(quick=a.quick, backend=a.backend, obs_out=a.obs_out)
