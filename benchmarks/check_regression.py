"""CI gate: fail on batched-decode regression vs the committed
``BENCH_decoder_scaling.json`` baseline.

The gated quantity is ``speedup_vs_sequential`` — the batched launch's
per-query advantage over B sequential single-pattern decodes, where BOTH
sides are measured in the SAME benchmark run on the SAME machine.  Gating
that ratio (rather than absolute per-query microseconds) makes the check
hardware-independent: a CI runner that is uniformly slower than the machine
that produced the committed baseline shifts both numerator and denominator
and leaves the ratio alone, while a code change that erodes the batching
win moves the ratio directly.

Every (mode, N, B, D) batched_scaling record present in both files is
compared; the run fails if any fresh speedup drops more than ``--tol``
(relative) below the baseline's.  Interpret-mode Pallas records are skipped
(interpret-mode latency is not a tracked quantity).  Absolute per-query
times are printed for context but never gate.

  python benchmarks/check_regression.py \
      --baseline BENCH_baseline.json --new BENCH_decoder_scaling.json
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _batched_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("batched_scaling", []):
        if rec["mode"].startswith("batched") and not rec.get("interpret_mode"):
            out[(rec["mode"], rec["N"], rec["B"], rec["D"])] = rec
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True, type=Path)
    ap.add_argument("--new", required=True, type=Path)
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed relative drop in speedup_vs_sequential "
                         "(default 25%%)")
    args = ap.parse_args(argv)

    base = _batched_records(args.baseline)
    new = _batched_records(args.new)
    shared = sorted(set(base) & set(new))
    if not shared:
        print("check_regression: no overlapping batched records — nothing "
              "to compare (did the sweep configs diverge?)")
        return 1

    failed = False
    for key in shared:
        sb = base[key]["speedup_vs_sequential"]
        sn = new[key]["speedup_vs_sequential"]
        ratio = sn / sb if sb > 0 else float("inf")
        status = "OK"
        if ratio < 1.0 - args.tol:
            status, failed = "REGRESSION", True
        print(f"  {key}: speedup {sb:6.2f}x -> {sn:6.2f}x ({ratio:5.2f} of "
              f"baseline)  [{base[key]['per_query_us']:8.1f} -> "
              f"{new[key]['per_query_us']:8.1f} us/q]  {status}")
    if failed:
        print(f"check_regression: FAILED (batching speedup dropped >"
              f"{args.tol:.0%} vs committed baseline)")
        return 2
    print(f"check_regression: all {len(shared)} batched records within "
          f"{args.tol:.0%} of baseline speedup")
    return 0


if __name__ == "__main__":
    sys.exit(main())
