"""CI gate: fail on batched-decode, serving-policy, or distributed-runtime
regression vs the committed ``BENCH_decoder_scaling.json`` baseline.

All gated quantities are SAME-RUN ratios (numerator and denominator
measured in one benchmark run on one machine), which makes the checks
hardware-independent — a CI runner that is uniformly slower than the
machine that produced the committed baseline shifts both sides and leaves
the ratio alone, while a code change that erodes the win moves it directly:

* ``speedup_vs_sequential`` (``batched_scaling``) — the batched launch's
  per-query advantage over B sequential single-pattern decodes;
* ``speedup_vs_lockstep`` (``serving_sweep``) — continuous admission's
  mean per-query decode-cost advantage over lockstep waves on the mixed
  light/heavy straggler stream;
* ``single_vs_distributed`` (``distributed_scaling``) — the distributed
  master/worker step's same-run overhead ratio vs the single-device step
  (a control-plane or placement regression drags it down);
* ``round_savings`` (``distributed_scaling``) — the telemetry budget
  loop's mean-decode-rounds advantage over the fixed worst-case budget
  (deterministic for a fixed seed: PRNG masks, count-based metric) —
  together with ``quality_preservation`` (fixed/telemetry mean
  unresolved), so round savings bought by abandoning recovery fail.
* ``speedup_vs_dense`` (``large_n``, schema v5) — the scalable decode's
  same-run advantage over the dense reference PAST the whole-H-in-VMEM
  regime (N up to 16384): sparse everywhere, the check-axis-tiled fused
  kernel where compiled (TPU) — interpret-mode tiled records are skipped
  like every interpret record.
* ``traffic_ratio_vs_tiled`` (``seeded``, schema v6) — the seeded kernel's
  modeled per-decode operand HBM traffic advantage over the check-axis
  tiled one (tiled streams H every round; seeded regenerates it
  in-register).  Besides the relative-drop gate, the ratio carries a HARD
  floor: ≥ 10× at N = 16384, the PR's headline memory-wall claim.  The
  timed seeded record also trips if its same-run
  ``wallclock_ratio_vs_tiled`` exceeds 1.2 (the regeneration must not buy
  bandwidth with compute the kernel cannot afford).
* ``flops_ratio_vs_dense_tile`` (``seeded_gather``, schema v8) — the
  edge-proportional gather round's modeled per-round FLOPs advantage over
  the dense regenerated tile inside the same seeded kernel (the
  :mod:`repro.core.hwcaps` crossover model behind ``seeded_mode="auto"``).
  Besides the relative-drop gate, the ratio carries a HARD floor: ≥ 8× at
  N = 16384, the PR's headline arithmetic claim.  The timed gather record
  also trips if its same-run ``wallclock_ratio_vs_dense_tile`` exceeds
  1.2 (the gather/segment-sum round must not buy FLOPs with launch or
  layout overhead it cannot afford).
* ``sim_steps_per_sec_ratio`` (``pipeline``, schema v7) — the depth-2
  pipelined runtime's same-run makespan advantage over the synchronous
  barrier driver on the simulated clock (deterministic: fixed delay
  schedule, fixed seed).  Besides the relative-drop gate it carries a
  HARD floor of ≥ 1.5×, and quality floors: the pipeline's mean
  unresolved (after late folds) must not exceed the sync run's, and its
  final error must stay within 5% of sync.  The measured
  ``host_steps_per_sec_ratio`` is gated only relative to its own baseline
  (single-core runners serialize the overlapped device programs and keep
  only the control-plane savings).
* ``obs`` (``obs-overhead``, schema v9) — the observability layer's
  instrumented-vs-plain pipelined run.  Baseline-independent floors only:
  ``sim_steps_per_sec_ratio`` ≥ 0.95 (the ≤5% instrumented-overhead bound
  on the deterministic simulated clock), ``bit_identical`` must hold (a
  recording that leaked into a traced program diverges the trajectory),
  and the run must be non-vacuous (``metrics_recorded`` > 0,
  ``trace_events`` > 0 — a silently-disabled registry would otherwise
  pass trivially).  ``host_overhead_pct`` is recorded but never gated
  (wall-clock recording cost is machine-dependent).
* ``replay`` (schema v10) — pattern-compiled peeling on a recurring
  straggler stream: the cache-hit schedule-replay decode vs the flooding
  sparse decode, same run, same queries.  Gated relatively:
  ``cache_hit_speedup_vs_sparse`` (timed) and ``modeled_work_ratio``
  (flooding edge-ops / replayed edge-ops, deterministic).  HARD floors on
  the fresh record at N = 8192: speedup ≥ 2×, realized
  ``schedule_cache_hit_rate`` ≥ 0.8 (read back from the obs
  ``sched_cache.hit_rate`` gauge), and ``bit_identical`` — the replay
  must reproduce the flooding decode's values and erasure trajectory
  exactly, or the speedup is vacuous.

Every gate lives in the ``SECTIONS`` registry (name → description +
runner); ``--sections`` selects which ones run (CI's tier-1 job gates
batched+serving+large_n+seeded+seeded_gather+replay; the fake-8-device
distributed job gates distributed+pipeline), ``--list-sections`` prints
the registry, and an unknown name fails loudly rather than silently
gating nothing.  Every record present in both files is compared
(batched records key on (mode, N, B, D); serving on (mode, N, B, budget,
chunk, n_queries); distributed/pipeline on (mode, W, N); large_n on
(backend, N, D); replay on (N, n_queries, n_patterns, budget)); the
run fails if any fresh ratio drops more than ``--tol`` (relative) below
the baseline's.  Interpret-mode Pallas records are skipped (interpret-mode
latency is not a tracked quantity).  Absolute per-query/per-step times are
printed for context but never gate.

  python benchmarks/check_regression.py \
      --baseline BENCH_baseline.json --new BENCH_decoder_scaling.json \
      --sections batched,serving,large_n,seeded,replay
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def _batched_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("batched_scaling", []):
        if rec["mode"].startswith("batched") and not rec.get("interpret_mode"):
            out[(rec["mode"], rec["N"], rec["B"], rec["D"])] = rec
    return out


def _serving_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("serving_sweep", []):
        if rec["mode"] != "continuous":
            continue  # the lockstep row is the (unit-speedup) denominator
        out[(rec["mode"], rec["N"], rec["B"], rec["budget"], rec["chunk"],
             rec["n_queries"])] = rec
    return out


def _large_n_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("large_n", []):
        # dense is the (unit-speedup) denominator; interpret-mode records
        # are correctness tripwires, not timed quantities; forced-backend
        # runs never rewrite the JSON but guard anyway
        if (rec["backend"] != "dense" and not rec.get("interpret_mode")
                and rec.get("speedup_vs_dense") and not rec.get("forced_backend")):
            out[(rec["backend"], rec["N"], rec["D"])] = rec
    return out


def _seeded_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("seeded", []):
        # lower-only feasibility records have no ratio to gate
        if "traffic_ratio_vs_tiled" in rec:
            out[(rec["N"], rec["D"])] = rec
    return out


def _seeded_floors(new: dict[tuple, dict], *, floor_n: int = 16384,
                   floor_ratio: float = 10.0,
                   max_wallclock_ratio: float = 1.2) -> bool:
    """Absolute gates on the FRESH seeded records (baseline-independent):
    the ≥10× traffic floor at N=16384 and the ≤1.2× same-run wall-clock
    ceiling on the timed record.  Returns True iff any floor failed."""
    failed = False
    floor_recs = [r for (n, _), r in new.items() if n == floor_n]
    if not floor_recs:
        print(f"check_regression [seeded]: no N={floor_n} record to hold "
              "to the traffic floor")
        failed = True
    for rec in floor_recs:
        ratio = rec["traffic_ratio_vs_tiled"]
        ok = ratio >= floor_ratio
        print(f"  (N={floor_n}, D={rec['D']}): traffic_ratio_vs_tiled "
              f"{ratio:.0f}x (floor {floor_ratio:.0f}x)  "
              f"{'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
    for key, rec in sorted(new.items()):
        if not rec.get("timed"):
            continue
        wr = rec["wallclock_ratio_vs_tiled"]
        ok = wr <= max_wallclock_ratio
        print(f"  {key}: wallclock_ratio_vs_tiled {wr:.2f}x (ceiling "
              f"{max_wallclock_ratio:.1f}x)  "
              f"{'OK' if ok else 'CEILING FAILED'}")
        failed |= not ok
    return failed


def _seeded_gather_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("seeded_gather", []):
        if "flops_ratio_vs_dense_tile" in rec:
            out[(rec["N"], rec["D"])] = rec
    return out


def _seeded_gather_floors(new: dict[tuple, dict], *, floor_n: int = 16384,
                          floor_ratio: float = 8.0,
                          max_wallclock_ratio: float = 1.2) -> bool:
    """Absolute gates on the FRESH seeded-gather records
    (baseline-independent): the ≥8× per-round FLOPs floor at N=16384 and
    the ≤1.2× same-run wall-clock ceiling on the timed record.  Returns
    True iff any floor failed."""
    failed = False
    floor_recs = [r for (n, _), r in new.items() if n == floor_n]
    if not floor_recs:
        print(f"check_regression [seeded_gather]: no N={floor_n} record to "
              "hold to the FLOPs floor")
        failed = True
    for rec in floor_recs:
        ratio = rec["flops_ratio_vs_dense_tile"]
        ok = ratio >= floor_ratio
        print(f"  (N={floor_n}, D={rec['D']}): flops_ratio_vs_dense_tile "
              f"{ratio:.0f}x (floor {floor_ratio:.0f}x)  "
              f"{'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
    for key, rec in sorted(new.items()):
        if not rec.get("timed"):
            continue
        wr = rec["wallclock_ratio_vs_dense_tile"]
        ok = wr <= max_wallclock_ratio
        print(f"  {key}: wallclock_ratio_vs_dense_tile {wr:.2f}x (ceiling "
              f"{max_wallclock_ratio:.1f}x)  "
              f"{'OK' if ok else 'CEILING FAILED'}")
        failed |= not ok
    return failed


def _distributed_records(path: Path, mode: str) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("distributed_scaling", []):
        if rec["mode"] == mode:
            out[(rec["mode"], rec["W"], rec["N"])] = rec
    return out


def _pipeline_floors(new: dict[tuple, dict], *, floor_ratio: float = 1.5,
                     max_error_ratio: float = 1.05) -> bool:
    """Absolute gates on the FRESH pipeline records (baseline-independent):
    the ≥1.5× simulated-clock speedup floor, and the quality floors —
    mean unresolved after folds no worse than sync, final error within 5%
    of sync.  Returns True iff any floor failed."""
    failed = False
    if not new:
        print("check_regression [pipeline]: no pipeline records to hold "
              "to the speedup floor")
        return True
    for key, rec in sorted(new.items()):
        ratio = rec["sim_steps_per_sec_ratio"]
        ok = ratio >= floor_ratio
        print(f"  {key}: sim_steps_per_sec_ratio {ratio:.2f}x (floor "
              f"{floor_ratio:.1f}x)  {'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
        pu, su = rec["pipeline_mean_unresolved"], rec["sync_mean_unresolved"]
        ok = pu <= su + 1e-9
        print(f"  {key}: mean_unresolved pipeline {pu:.2f} vs sync {su:.2f}"
              f"  {'OK' if ok else 'QUALITY FAILED'}")
        failed |= not ok
        pe, se = rec["pipeline_final_error"], rec["sync_final_error"]
        ok = pe <= se * max_error_ratio
        print(f"  {key}: final_error pipeline {pe:.4f} vs sync {se:.4f} "
              f"(ceiling {max_error_ratio:.2f}x)  "
              f"{'OK' if ok else 'QUALITY FAILED'}")
        failed |= not ok
    return failed


def _obs_floors(new: dict[tuple, dict], *,
                min_sim_ratio: float = 0.95) -> bool:
    """Absolute gates on the FRESH obs-overhead records
    (baseline-independent): instrumented sim steps/sec within 5% of plain,
    bit-identical trajectories, and non-vacuous metric/trace counts.
    Returns True iff any floor failed."""
    failed = False
    if not new:
        print("check_regression [obs]: no obs-overhead records to hold "
              "to the overhead floor")
        return True
    for key, rec in sorted(new.items()):
        ratio = rec["sim_steps_per_sec_ratio"]
        ok = ratio >= min_sim_ratio
        print(f"  {key}: sim_steps_per_sec_ratio {ratio:.3f}x (floor "
              f"{min_sim_ratio:.2f}x)  {'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
        ok = bool(rec.get("bit_identical"))
        print(f"  {key}: bit_identical {rec.get('bit_identical')}  "
              f"{'OK' if ok else 'PARITY FAILED'}")
        failed |= not ok
        nm, ne = rec.get("metrics_recorded", 0), rec.get("trace_events", 0)
        ok = nm > 0 and ne > 0
        print(f"  {key}: metrics_recorded {nm}, trace_events {ne}  "
              f"{'OK' if ok else 'VACUOUS (instrumentation off?)'}"
              f"  [host_overhead {rec.get('host_overhead_pct', 0.0):+.1f}% "
              "ungated]")
        failed |= not ok
    return failed


def _replay_records(path: Path) -> dict[tuple, dict]:
    data = json.loads(path.read_text())
    out = {}
    for rec in data.get("replay", []):
        out[(rec["N"], rec["n_queries"], rec["n_patterns"],
             rec["budget"])] = rec
    return out


def _replay_floors(new: dict[tuple, dict], *, floor_n: int = 8192,
                   floor_speedup: float = 2.0,
                   min_hit_rate: float = 0.8) -> bool:
    """Absolute gates on the FRESH replay records (baseline-independent):
    cache-hit replay ≥2× faster than flooding sparse at N=8192, realized
    schedule-cache hit rate ≥0.8 on the recurring stream, and the
    bit-identical trajectory tripwire.  Returns True iff any floor
    failed."""
    failed = False
    floor_recs = [r for (n, *_), r in sorted(new.items()) if n == floor_n]
    if not floor_recs:
        print(f"check_regression [replay]: no N={floor_n} record to hold "
              "to the speedup floor")
        return True
    for rec in floor_recs:
        sp = rec["cache_hit_speedup_vs_sparse"]
        ok = sp >= floor_speedup
        print(f"  (N={floor_n}, Q={rec['n_queries']}): "
              f"cache_hit_speedup_vs_sparse {sp:.2f}x (floor "
              f"{floor_speedup:.1f}x)  {'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
        hr = rec["schedule_cache_hit_rate"]
        ok = hr >= min_hit_rate
        print(f"  (N={floor_n}, Q={rec['n_queries']}): "
              f"schedule_cache_hit_rate {hr:.3f} (floor {min_hit_rate:.2f})"
              f"  {'OK' if ok else 'FLOOR FAILED'}")
        failed |= not ok
        ok = bool(rec.get("bit_identical"))
        print(f"  (N={floor_n}, Q={rec['n_queries']}): bit_identical "
              f"{rec.get('bit_identical')}  "
              f"{'OK' if ok else 'PARITY FAILED'}")
        failed |= not ok
    return failed


def _gate(name: str, metric: str, base: dict, new: dict, tol: float,
          context_key: str = "per_query_us") -> bool | None:
    """Compare shared records on ``metric``.

    Returns True iff any record regressed, None if there was nothing to
    compare (config divergence — a distinct failure from a regression).
    ``context_key`` names an absolute-time field printed for context when
    both records carry it (never gated).
    """
    shared = sorted(set(base) & set(new))
    if not shared:
        print(f"check_regression: no overlapping {name} records — nothing "
              "to compare (did the sweep configs diverge?)")
        return None
    failed = False
    for key in shared:
        sb, sn = base[key][metric], new[key][metric]
        ratio = sn / sb if sb > 0 else float("inf")
        status = "OK"
        if ratio < 1.0 - tol:
            status, failed = "REGRESSION", True
        ctx = ""
        if context_key in base[key] and context_key in new[key]:
            ctx = (f"  [{context_key} {base[key][context_key]:8.1f} -> "
                   f"{new[key][context_key]:8.1f}]")
        print(f"  {key}: {metric} {sb:6.2f}x -> {sn:6.2f}x ({ratio:5.2f} of "
              f"baseline){ctx}  {status}")
    print(f"check_regression [{name}]: {len(shared)} records "
          f"{'FAILED' if failed else 'within tolerance'}")
    return failed


def _run_batched(args) -> list:
    return [_gate("batched", "speedup_vs_sequential",
                  _batched_records(args.baseline),
                  _batched_records(args.new), args.tol)]


def _run_serving(args) -> list:
    return [_gate("serving", "speedup_vs_lockstep",
                  _serving_records(args.baseline),
                  _serving_records(args.new), args.tol)]


def _run_large_n(args) -> list:
    return [_gate("large_n", "speedup_vs_dense",
                  _large_n_records(args.baseline),
                  _large_n_records(args.new), args.tol,
                  context_key="per_round_us")]


def _run_seeded(args) -> list:
    new_seeded = _seeded_records(args.new)
    return [_gate("seeded", "traffic_ratio_vs_tiled",
                  _seeded_records(args.baseline), new_seeded, args.tol,
                  context_key="modeled_seeded_bytes"),
            _seeded_floors(new_seeded)]


def _run_seeded_gather(args) -> list:
    new_sg = _seeded_gather_records(args.new)
    return [_gate("seeded_gather", "flops_ratio_vs_dense_tile",
                  _seeded_gather_records(args.baseline), new_sg, args.tol,
                  context_key="modeled_gather_flops_per_round"),
            _seeded_gather_floors(new_sg)]


def _run_replay(args) -> list:
    new_replay = _replay_records(args.new)
    return [_gate("replay", "cache_hit_speedup_vs_sparse",
                  _replay_records(args.baseline), new_replay, args.tol,
                  context_key="per_query_us_replay"),
            _gate("replay-work", "modeled_work_ratio",
                  _replay_records(args.baseline), new_replay, args.tol,
                  context_key="modeled_replay_edge_ops"),
            _replay_floors(new_replay)]


def _run_distributed(args) -> list:
    # round savings must not be bought by giving up on recovery: the
    # fixed/telemetry mean-unresolved ratio is gated alongside the savings
    return [_gate("dist-overhead", "single_vs_distributed",
                  _distributed_records(args.baseline, "distributed-overhead"),
                  _distributed_records(args.new, "distributed-overhead"),
                  args.tol, context_key="per_step_us"),
            _gate("dist-telemetry", "round_savings",
                  _distributed_records(args.baseline, "telemetry"),
                  _distributed_records(args.new, "telemetry"), args.tol,
                  context_key="telemetry_mean_rounds"),
            _gate("dist-quality", "quality_preservation",
                  _distributed_records(args.baseline, "telemetry"),
                  _distributed_records(args.new, "telemetry"), args.tol,
                  context_key="telemetry_mean_unresolved")]


def _run_pipeline(args) -> list:
    new_pipe = _distributed_records(args.new, "pipeline")
    return [_gate("pipeline-sim", "sim_steps_per_sec_ratio",
                  _distributed_records(args.baseline, "pipeline"),
                  new_pipe, args.tol, context_key="pipeline_per_step_us"),
            _gate("pipeline-host", "host_steps_per_sec_ratio",
                  _distributed_records(args.baseline, "pipeline"),
                  new_pipe, args.tol, context_key="sync_per_step_us"),
            _pipeline_floors(new_pipe)]


def _run_obs(args) -> list:
    # baseline-independent floors only: the obs record is fresh-run
    # self-contained (sim ratio, bit-identity, non-vacuousness)
    return [_obs_floors(_distributed_records(args.new, "obs-overhead"))]


# Gate registry: section name -> (one-line description, runner).  The
# runner returns a list of per-gate outcomes (True = regressed, None = no
# overlapping records).  ``--sections`` defaults, the unknown-name check,
# and ``--list-sections`` all derive from this dict — adding a section
# here is the whole registration.
SECTIONS: dict[str, tuple[str, object]] = {
    "batched": ("batched-decode speedup vs B sequential single-pattern "
                "decodes", _run_batched),
    "serving": ("continuous-admission serving speedup vs lockstep waves",
                _run_serving),
    "distributed": ("distributed step overhead, telemetry round savings, "
                    "and recovery-quality preservation", _run_distributed),
    "large_n": ("scalable-decode speedup vs dense past the VMEM regime",
                _run_large_n),
    "seeded": ("seeded-kernel modeled HBM traffic vs tiled (≥10x floor at "
               "N=16384)", _run_seeded),
    "seeded_gather": ("gather-round modeled FLOPs vs dense tile (≥8x floor "
                      "at N=16384)", _run_seeded_gather),
    "replay": ("cache-hit schedule replay vs flooding sparse (≥2x floor at "
               "N=8192, hit-rate ≥0.8, bit-identical)", _run_replay),
    "pipeline": ("pipelined runtime speedup and quality floors vs the sync "
                 "driver", _run_pipeline),
    "obs": ("observability overhead, bit-identity, and non-vacuousness "
            "floors", _run_obs),
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", type=Path)
    ap.add_argument("--new", type=Path)
    ap.add_argument("--tol", type=float, default=0.25,
                    help="allowed relative drop in the gated same-run "
                         "speedup ratios (default 25%%)")
    ap.add_argument("--sections", default=",".join(SECTIONS),
                    help="comma-separated gates to run "
                         f"({'|'.join(SECTIONS)})")
    ap.add_argument("--list-sections", action="store_true",
                    help="print the gate registry (name + description) "
                         "and exit")
    args = ap.parse_args(argv)
    if args.list_sections:
        for name, (desc, _) in SECTIONS.items():
            print(f"{name:14s} {desc}")
        return 0
    if args.baseline is None or args.new is None:
        ap.error("--baseline and --new are required "
                 "(unless --list-sections)")
    sections = [s for s in args.sections.split(",") if s]
    unknown = set(sections) - set(SECTIONS)
    if unknown:
        print(f"check_regression: unknown sections {sorted(unknown)} "
              f"(known: {','.join(SECTIONS)})")
        return 1

    results = []
    for name, (_, runner) in SECTIONS.items():
        if name in sections:
            results.extend(runner(args))
    if any(r is None for r in results):
        print("check_regression: FAILED (a gated section had no "
              "overlapping records — regenerate the committed baseline?)")
        return 1
    if any(results):
        print(f"check_regression: FAILED (a gated speedup dropped >"
              f"{args.tol:.0%} vs committed baseline)")
        return 2
    print(f"check_regression: all gated speedups within {args.tol:.0%} of "
          "baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
