"""Shared benchmark infrastructure.

Metrics, matching the paper's Section 4 evaluation:
  * iterations-to-converge: steps until ||θ_t − θ*|| < tol·||θ*||;
  * simulated wall time: per-step time = (shifted-exponential worker latency,
    waiting for the fastest w−s workers) + measured master-side computation.
    The worker latencies are simulated (no real cluster here — DESIGN.md §3);
    the master decode/combine cost is real measured CPU time of the jit'd
    step, which preserves the paper's LDPC-decode-is-cheap comparison.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FixedCountStragglers,
    DelayModel,
    Scheme2Blocked,
    make_regular_ldpc,
    run_pgd,
    second_moment,
)
from repro.core.schemes import Karakus, Replication, Uncoded

W = 40  # the paper's worker count


def resolve_bench_backend(code, requested: str, *,
                          vmem_budget_bytes: int | None = None,
                          pallas_cpu_max_n: int = 256) -> tuple[str, str | None]:
    """Fail a forced decode backend over to one that can actually run.

    Benchmarks used to crash (or effectively hang in interpret mode) when
    ``--backend pallas`` was forced at large N — past the resident kernel's
    VMEM limit on TPU, or past any reasonable interpret-mode budget on CPU.
    Returns ``(backend, message)``: the backend to run and a human-readable
    failover explanation (``None`` when the request stands).

    * on TPU, "pallas" whose :func:`repro.core.decoder.vmem_bytes_estimate`
      exceeds the VMEM budget fails over to "pallas_tiled" (same fused
      contract, H streamed over check tiles);
    * off-TPU, "pallas"/"pallas_tiled" beyond ``pallas_cpu_max_n`` fails
      over to "sparse" (interpret mode is a correctness path, not a timed
      one — see the interpret_mode flags in the emitted records);
    * "pallas_seeded" forced on a code that does not carry a seeded
      structure (anything but ``make_seeded_ldpc`` / ``SeededLDPC``) fails
      over to "pallas_tiled" on TPU / "sparse" off-TPU — the in-kernel H
      regeneration needs the layered-permutation ensemble's seed.
    """
    from repro.core.decoder import (_DEFAULT_VMEM_BUDGET_BYTES,
                                    vmem_bytes_estimate)
    from repro.core.ldpc import is_seeded

    N = code.N
    on_tpu = jax.default_backend() == "tpu"
    if requested == "pallas_seeded" and not (
            is_seeded(code) and getattr(code, "kind", "") != "ldgm-seeded"):
        fallback = "pallas_tiled" if on_tpu else "sparse"
        return fallback, (
            f"backend='pallas_seeded' forced at N={N} on a code without a "
            f"seeded parity structure (kind="
            f"{getattr(code, 'kind', type(code).__name__)!r}): the "
            f"in-kernel H regeneration needs a make_seeded_ldpc/SeededLDPC "
            f"code — failing over to {fallback!r}")
    if requested in ("pallas", "pallas_tiled", "pallas_seeded") \
            and not on_tpu and N > pallas_cpu_max_n:
        if requested == "pallas_seeded" and not hasattr(code, "H"):
            # structure-only SeededLDPC: there is no materialized H for
            # sparse to fall back on — the seeded kernel IS the decode.
            return requested, None
        return "sparse", (
            f"backend={requested!r} forced at N={N} off-TPU: interpret-mode "
            f"Pallas is not timeable past N={pallas_cpu_max_n} — failing "
            f"over to 'sparse' (use a TPU for compiled kernel numbers)")
    if requested == "pallas":
        budget = vmem_budget_bytes or _DEFAULT_VMEM_BUDGET_BYTES
        est = vmem_bytes_estimate(code)
        if est > budget:
            return "pallas_tiled", (
                f"backend='pallas' forced at N={N}: resident working set "
                f"~{est / 2**20:.0f} MiB exceeds the {budget / 2**20:.0f} MiB "
                f"VMEM budget — failing over to 'pallas_tiled' (H streamed "
                f"over check tiles)")
    return requested, None


def build_code(seed=0):
    """The paper's (40, 20) rate-1/2 LDPC code."""
    return make_regular_ldpc(20, l=3, r=6, seed=seed)


def build_schemes(prob, *, projection=None, seed=0,
                  decode_backend="auto") -> dict:
    """All compared schemes on one problem (paper Fig. 1-3 lineup)."""
    from repro.optim import projections as Pj
    proj = projection or Pj.identity
    mom = second_moment(prob.X, prob.y)
    code = build_code(seed)
    return {
        "ldpc-moment (this paper)": Scheme2Blocked.build(
            code, mom, lr=prob.lr, decode_iters=12, projection=proj,
            decode_backend=decode_backend),
        "uncoded": Uncoded(prob.X, prob.y, w=W, lr=prob.lr, projection=proj),
        "2-replication": Replication(prob.X, prob.y, w=W, lr=prob.lr, r=2,
                                     projection=proj),
        "KSDY17-hadamard": Karakus.build(prob.X, prob.y, W, lr=prob.lr * 0.8,
                                         kind="hadamard", seed=seed,
                                         projection=proj),
        "KSDY17-gaussian": Karakus.build(prob.X, prob.y, W, lr=prob.lr * 0.8,
                                         kind="gaussian", seed=seed,
                                         projection=proj),
    }


def iterations_to_converge(scheme, prob, s: int, *, steps=1500, tol=2e-2,
                           key=None) -> tuple[int | None, float]:
    """(first step with rel-err < tol, final rel-err)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    res = run_pgd(scheme, jnp.zeros_like(prob.theta_star),
                  FixedCountStragglers(s), steps,
                  theta_star=prob.theta_star, key=key)
    norm = float(jnp.linalg.norm(prob.theta_star))
    errs = np.asarray(res.errors) / norm
    hit = np.nonzero(errs < tol)[0]
    return (int(hit[0]) + 1 if hit.size else None), float(errs[-1])


def master_step_seconds(scheme, prob, s: int, *, reps=20) -> float:
    """Measured master-side cost of one jit'd coded step."""
    mask = FixedCountStragglers(s).sample(jax.random.PRNGKey(0), scheme.w)
    theta = jnp.zeros_like(prob.theta_star)
    step = jax.jit(lambda t, m: scheme.step(t, m)[0])
    step(theta, mask).block_until_ready()  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        theta = step(theta, mask)
    theta.block_until_ready()
    return (time.perf_counter() - t0) / reps


def simulated_wall_time(iters: int, master_s: float, s: int, *,
                        key=None, tau=0.5e-3, mu=2000.0) -> float:
    """Total time: per-step worker latency (wait for fastest w−s) + master."""
    key = key if key is not None else jax.random.PRNGKey(1)
    dm = DelayModel(tau=tau, mu=mu)
    total = 0.0
    for t in range(iters):
        key, k = jax.random.split(key)
        delays = dm.sample_delays(k, W)
        _, cutoff = DelayModel.mask_and_time(delays, W - s)
        total += float(cutoff) + master_s
    return total


def print_table(title: str, header: list[str], rows: list[list]):
    print(f"\n### {title}")
    widths = [max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
              for i, h in enumerate(header)]
    line = " | ".join(str(h).ljust(w) for h, w in zip(header, widths))
    print(line)
    print("-" * len(line))
    for r in rows:
        print(" | ".join(str(c).ljust(w) for c, w in zip(r, widths)))
