"""Paper Figure 3: sparse recovery in an UNDERDETERMINED system
(k = 2000, m = 1024, u ∈ {100, 200}), IHT with coded gradients.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (
    build_schemes,
    iterations_to_converge,
    master_step_seconds,
    print_table,
    simulated_wall_time,
)
from repro.data import make_sparse_problem
from repro.optim import projections


def run(*, k=2000, m=1024, us=(100, 200), stragglers=(5, 10), trials=2,
        steps=1500, tol=2e-2) -> list[dict]:
    results = []
    for u in us:
        for s in stragglers:
            per: dict[str, list] = {}
            for trial in range(trials):
                prob = make_sparse_problem(m=m, k=k, u=u, seed=trial)
                schemes = build_schemes(
                    prob, projection=projections.hard_threshold(u), seed=trial)
                for name, sch in schemes.items():
                    iters, final = iterations_to_converge(
                        sch, prob, s, steps=steps, tol=tol,
                        key=jax.random.PRNGKey(trial))
                    per.setdefault(name, []).append(
                        (iters if iters is not None else steps, final, sch, prob))
            for name, runs in per.items():
                iters_m = float(np.mean([r[0] for r in runs]))
                master_s = master_step_seconds(runs[0][2], runs[0][3], s, reps=3)
                results.append({
                    "u": u, "s": s, "scheme": name, "iters": iters_m,
                    "final_err": float(np.mean([r[1] for r in runs])),
                    "master_ms": master_s * 1e3,
                    "sim_wall_s": simulated_wall_time(int(iters_m), master_s, s),
                })
    return results


def main(quick: bool = False):
    kw = dict(us=(100,), trials=1, steps=1000) if quick else {}
    results = run(**kw)
    rows = [[r["u"], r["s"], r["scheme"], f"{r['iters']:.0f}",
             f"{r['final_err']:.3f}", f"{r['master_ms']:.2f}",
             f"{r['sim_wall_s']:.2f}"] for r in results]
    print_table("Fig 3 — sparse recovery, underdetermined (k=2000, m=1024)",
                ["u", "s", "scheme", "iters", "final_rel_err",
                 "master_ms/step", "sim_wall_s"], rows)
    return results


if __name__ == "__main__":
    main()
