"""LDPC decoding complexity & adaptivity (Section 3 claims):

  1. the adaptive peeling decoder's round count AND cost track the number of
     realized stragglers (few stragglers -> 1-2 rounds -> "decoding effort
     auto-adjusts");
  2. decode quality (|unresolved|) is monotone in the fixed round budget D;
  3. LDPC peeling cost vs MDS/Vandermonde least-squares recovery cost — the
     paper's low-complexity-decode argument (O(edges) vs O(w·K²) flops).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table
from repro.core import FixedCountStragglers, make_regular_ldpc, peel_decode, \
    peel_decode_adaptive


def run(*, Ks=(64, 256, 1024), ss=(2, 8, 24), reps=10):
    rows = []
    for K in Ks:
        code = make_regular_ldpc(K, l=3, r=6, seed=0)
        H = jnp.asarray(code.H, jnp.float32)
        G = jnp.asarray(code.G, jnp.float32)
        rng = np.random.default_rng(0)
        cw = jnp.asarray(code.encode(rng.standard_normal(K)), jnp.float32)
        for s in ss:
            key = jax.random.PRNGKey(s)
            mask = FixedCountStragglers(s).sample(key, code.N)
            rx = jnp.where(mask, 0.0, cw)

            dec = peel_decode_adaptive(code, rx, mask)
            rounds = int(dec.rounds_used)
            unresolved = int(dec.erased.sum())

            f = jax.jit(lambda v, e: peel_decode_adaptive(code, v, e).values)
            f(rx, mask).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                f(rx, mask).block_until_ready()
            t_ldpc = (time.perf_counter() - t0) / reps

            # MDS-style exact recovery: weighted lstsq on surviving rows
            def mds(v, e):
                alive = (~e).astype(jnp.float32)
                sol, *_ = jnp.linalg.lstsq(G * alive[:, None], v * alive)
                return sol

            g = jax.jit(mds)
            g(rx, mask).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                g(rx, mask).block_until_ready()
            t_lstsq = (time.perf_counter() - t0) / reps

            rows.append([code.N, K, s, rounds, unresolved,
                         f"{t_ldpc*1e6:.0f}", f"{t_lstsq*1e6:.0f}",
                         f"{t_lstsq/max(t_ldpc,1e-12):.1f}x"])
    return rows


def main(quick: bool = False):
    rows = run(Ks=(64, 256) if quick else (64, 256, 1024))
    print_table("Decoder scaling — adaptive peeling vs least-squares recovery",
                ["N", "K", "s", "rounds", "unresolved",
                 "ldpc_us", "lstsq_us", "speedup"], rows)
    # D-monotonicity (Remark 3)
    code = make_regular_ldpc(256, l=3, r=6, seed=1)
    rng = np.random.default_rng(1)
    erased = jnp.asarray(rng.random(code.N) < 0.25)
    dummy = jnp.zeros((code.N,), jnp.float32)
    drows = [[D, int(peel_decode(code, dummy, erased, D).erased.sum())]
             for D in (0, 1, 2, 4, 8, 16)]
    print_table("Unresolved coordinates vs decode rounds D (q0≈0.25)",
                ["D", "unresolved"], drows)
    return rows


if __name__ == "__main__":
    main()
