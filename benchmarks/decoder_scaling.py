"""LDPC decoding complexity & adaptivity (Section 3 claims), plus the
decode-backend scaling comparison that tracks the sparse/fused-kernel
hillclimb across PRs.

Sections:

  1. backend scaling — dense vs sparse (neighbor-table) vs fused-Pallas
     fixed-D decode latency at growing N, with achieved FLOP/s.  Emits the
     machine-readable ``BENCH_decoder_scaling.json`` (repo root by default)
     so the perf trajectory is comparable across PRs.
  2. batched decode over B INDEPENDENT erasure patterns (the engine's
     serving axis): per-query cost of one batched launch (vmapped-sparse /
     batched-Pallas) vs B sequential single-pattern decodes, B ∈
     {1, 8, 64, 256}.
  2b. mixed light/heavy straggler SERVING sweep — continuous admission
     (per-slot adaptive decode, slots retire/refill independently, chunked
     round budgets — the policy behind
     ``serving.coded_queries.CodedQueryBatcher(mode="continuous")``) vs
     lockstep waves (every wave pays the worst-case fixed round budget).
     Simulated on the decode path itself so the measured quantity is the
     mean per-query DECODE cost; ``speedup_vs_lockstep`` is a same-run
     ratio (both policies timed in one run on one machine), which is what
     ``check_regression.py`` gates.
  3. the adaptive peeling decoder's round count AND cost track the number of
     realized stragglers (few stragglers -> 1-2 rounds -> "decoding effort
     auto-adjusts");
  4. decode quality (|unresolved|) is monotone in the fixed round budget D;
  5. LDPC peeling cost vs MDS/Vandermonde least-squares recovery cost — the
     paper's low-complexity-decode argument (O(edges) vs O(w·K²) flops).
  6. LARGE-N sweep (schema v5): decode latency past the whole-H-in-VMEM
     regime, N up to 16384 — dense vs sparse everywhere both fit, plus the
     check-axis-TILED fused kernel (``backend="pallas_tiled"``) where it is
     timeable (compiled on TPU at every N; off-TPU a small-N interpret-mode
     correctness record only, flagged).  ``speedup_vs_dense`` is the
     same-run ratio ``check_regression.py --sections large_n`` gates.
     Codes are built PARITY-ONLY (``make_parity_only_ldpc``) — the decode
     trajectory never needs a generator, and the systematic solve is the
     construction bottleneck past N ≈ 4096.

  7. SEEDED sweep (schema v6): the seed-regenerated kernel
     (``backend="pallas_seeded"``) vs the check-axis-tiled one.  Per N up
     to 32768: the MODELED per-decode operand HBM traffic of both (tiled
     streams the whole padded (p, N) f32 H from HBM every round; seeded
     regenerates each tile in-register and streams only the payload) and
     the same-run ``traffic_ratio_vs_tiled`` that
     ``check_regression.py --sections seeded`` gates (≥10× at N=16384).
     At N=2048 both kernels are also TIMED (interpret mode off-TPU, a
     same-run ``wallclock_ratio_vs_tiled``) with a bit-identical-values
     trajectory tripwire; one lower-only record proves the seeded kernel
     lowers at N=262144, where even materializing H (128 GiB f32) is
     infeasible — there is nothing to compare against there.

  8. SEEDED-GATHER sweep (schema v8): the edge-proportional gather round
     (``seeded_mode="gather"``) vs the dense regenerated-tile round inside
     the same seeded kernel.  Per N up to 32768: the MODELED per-round
     FLOPs of both (the :mod:`repro.core.hwcaps` expressions behind
     ``seeded_mode="auto"``: the dense round contracts a ``p_pad × n_pad``
     tile per payload lane; the gather round touches only the r generated
     edges per check row plus the per-layer inverse-permutation merge) and
     the same-run ``flops_ratio_vs_dense_tile`` that
     ``check_regression.py --sections seeded_gather`` gates (hard ≥8×
     floor at N=16384).  At N=2048 both modes are also TIMED (interpret
     off-TPU) with a trajectory tripwire: erasure masks bit-identical,
     never-erased values bit-equal (resolved VALUES agree only up to f32
     summation order — the two rounds sum in different shapes).

  9. REPLAY sweep (schema v10): pattern-compiled peeling on a RECURRING
     straggler stream at N = 8192 — the ``backend="replay"`` +
     :class:`repro.core.schedule_cache.ScheduleCache` serving loop vs the
     flooding sparse adaptive decode, per query.  Records the MODELED work
     ratio (flooding touches every check row's r_max edges every round;
     replay touches only the schedule's resolving rows once), the TIMED
     same-run ``cache_hit_speedup_vs_sparse`` over the warm-cache stream,
     the realized ``schedule_cache_hit_rate`` of a cold cache over the
     same stream (read back from the obs ``sched_cache.hit_rate`` gauge),
     and a bit-identity tripwire: every pattern's replay must reproduce
     the flooding decode's values and erasure trajectory exactly.
     ``check_regression.py --sections replay`` gates the speedup (hard
     ≥2× floor), the hit rate (≥0.8), and the tripwire.

Forcing ``--backend pallas`` (CLI) past the VMEM limit no longer crashes:
``benchmarks.common.resolve_bench_backend`` fails over with a clear message
(to "pallas_tiled" on TPU, "sparse" off-TPU), and the quick CI run
exercises that path; ``--backend pallas_seeded`` on the sweep's unseeded
codes fails over the same way (the seed is a property of the CODE).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_table, resolve_bench_backend
from repro.core import FixedCountStragglers, make_regular_ldpc, peel_decode, \
    peel_decode_adaptive, peel_decode_batch, peel_decode_batch_adaptive
from repro.core.ldpc import make_parity_only_ldpc
from repro.serving.slot_lifecycle import SlotPool

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_decoder_scaling.json"

# The fused kernel runs in interpret mode on CPU — orders of magnitude
# slower than compiled, so its latency is NOT comparable; measure it only
# at small N off-TPU to keep the benchmark fast, and flag it in the JSON.
_PALLAS_CPU_MAX_N = 256


def _median_seconds(fn, *args, reps):
    fn(*args)[0].block_until_ready()  # compile
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn(*args)[0].block_until_ready()
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def run_backend_scaling(*, Ks=(64, 256, 512, 1024, 2048), V=8, D=8, q=0.25,
                        reps=5):
    """Fixed-D decode latency per backend; returns (table_rows, json_records)."""
    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for K in Ks:
        code = make_regular_ldpc(K, l=3, r=6, seed=0)
        N, p = code.N, code.p
        r_max = code.check_idx.shape[1]
        rng = np.random.default_rng(K)
        cw = jnp.asarray(code.encode(rng.standard_normal((K, V))), jnp.float32)
        erased = jnp.asarray(rng.random(N) < q)
        rx = jnp.where(erased[:, None], 0.0, cw)

        backends = ["dense", "sparse"]
        if on_tpu or N <= _PALLAS_CPU_MAX_N:
            backends.append("pallas")

        t_dense = None
        for backend in backends:
            fn = jax.jit(
                lambda v, e, b=backend: peel_decode(code, v, e, D, backend=b
                                                    ).values)
            t = _median_seconds(lambda v, e: (fn(v, e),), rx, erased,
                                reps=reps)
            if backend == "dense":
                t_dense = t
            # Arithmetic actually performed per decode by this backend:
            # dense touches the full (p, N) H thrice per round (counted once
            # as the dominating 2·p·N matmul per payload+mask column);
            # sparse/pallas-equivalent useful work is edge-proportional.
            if backend == "dense":
                work = 2.0 * p * N * (V + 1) * D
            else:
                work = 2.0 * p * r_max * (V + 1) * D
            rec = {
                "backend": backend,
                "N": N, "K": K, "p": p, "V": V, "D": D,
                "erasure_q": q,
                "median_s": t,
                "per_round_us": t / D * 1e6,
                "work_flops": work,
                "achieved_gflops": work / t / 1e9,
                "speedup_vs_dense": (t_dense / t) if t_dense else 1.0,
                "interpret_mode": backend == "pallas" and not on_tpu,
                "single_kernel_launch": backend == "pallas",
            }
            records.append(rec)
            rows.append([N, K, backend, f"{t * 1e6:.0f}",
                         f"{t / D * 1e6:.1f}",
                         f"{rec['achieved_gflops']:.3f}",
                         f"{rec['speedup_vs_dense']:.2f}x"])
    return rows, records


def run_batched_scaling(*, Ks=(64, 256, 1024), Bs=(1, 8, 64, 256), D=8,
                        q=0.25, reps=5):
    """Per-query cost: ONE batched decode of B patterns vs B sequential
    single-pattern decodes (same backend — the honest baseline is the
    FASTEST single-pattern decode, i.e. sparse).  The batched-sparse mode is
    the scatter-free batch-major round (``peel_round_sparse_batch``); the
    batched-Pallas mode is the one-launch grid-over-batch kernel (interpret
    mode off-TPU, so it is only timed at small N there).  Returns
    (table_rows, json_records); ``speedup_vs_sequential`` is vs
    sequential-sparse.
    """
    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for K in Ks:
        code = make_regular_ldpc(K, l=3, r=6, seed=0)
        N = code.N
        rng = np.random.default_rng(K)
        for B in Bs:
            msgs = rng.standard_normal((B, K))
            cw = jnp.asarray((code.G @ msgs.T).T, jnp.float32)  # (B, N)
            erased = jnp.asarray(rng.random((B, N)) < q)
            rx = jnp.where(erased, 0.0, cw)

            # sequential baseline: B separate single-pattern launches
            single = jax.jit(
                lambda v, e: peel_decode(code, v, e, D, backend="sparse").values)
            single(rx[0], erased[0]).block_until_ready()  # compile
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                for i in range(B):
                    single(rx[i], erased[i]).block_until_ready()
                ts.append(time.perf_counter() - t0)
            t_seq = float(np.median(ts))

            modes = {"batched-sparse": "sparse"}
            if on_tpu or N <= _PALLAS_CPU_MAX_N:
                modes["batched-pallas"] = "pallas"
            t_per_mode = {}
            for mode, backend in modes.items():
                fn = jax.jit(lambda v, e, b=backend: peel_decode_batch(
                    code, v, e, D, backend=b).values)
                t_per_mode[mode] = _median_seconds(
                    lambda v, e: (fn(v, e),), rx, erased, reps=reps)

            base = {"N": N, "K": K, "B": B, "D": D, "erasure_q": q,
                    "jax_backend": jax.default_backend()}
            records.append({**base, "mode": "sequential-sparse",
                            "median_s": t_seq,
                            "per_query_us": t_seq / B * 1e6,
                            "speedup_vs_sequential": 1.0,
                            "interpret_mode": False})
            rows.append([N, K, B, "sequential-sparse",
                         f"{t_seq / B * 1e6:.0f}", "1.00x"])
            for mode, t in t_per_mode.items():
                records.append({**base, "mode": mode, "median_s": t,
                                "per_query_us": t / B * 1e6,
                                "speedup_vs_sequential": t_seq / t,
                                "interpret_mode": mode == "batched-pallas"
                                and not on_tpu})
                rows.append([N, K, B, mode, f"{t / B * 1e6:.0f}",
                             f"{t_seq / t:.2f}x"])
    return rows, records


def _serve_lockstep(code, rx, erased, *, B, budget):
    """Wave policy: ONE fixed-budget batched decode per wave of B queries
    (partial final wave padded with clean no-op slots).  Returns a callable
    running the whole queue once, plus the launch count."""
    N = code.N
    fn = jax.jit(lambda v, e: peel_decode_batch(
        code, v, e, budget, backend="sparse").values)
    nq = rx.shape[0]
    pad = (-nq) % B
    rx_p = np.concatenate([rx, np.zeros((pad, N), np.float32)])
    er_p = np.concatenate([erased, np.zeros((pad, N), bool)])
    waves = [(jnp.asarray(rx_p[i:i + B]), jnp.asarray(er_p[i:i + B]))
             for i in range(0, nq + pad, B)]

    def serve():
        for v, e in waves:
            fn(v, e).block_until_ready()

    return serve, len(waves)


def serve_continuous(code, rx, erased, *, B, budget, chunk,
                     backend="sparse"):
    """Continuous admission simulated on the decode path: a pool of B slots
    advances by at most ``chunk`` per-slot adaptive rounds per launch;
    converged / budget-exhausted slots retire and refill FIFO — the
    ``CodedQueryBatcher(mode="continuous")`` slot lifecycle, minus the
    worker matvec and epilogue that both policies pay once per query (so
    the measured quantity is pure DECODE cost, the paper's adaptivity
    claim).  The lifecycle itself (admission order, budget chunking,
    retire condition) is the SHARED ``serving.slot_lifecycle.SlotPool``
    state machine — the same object the batcher drives, so the two can no
    longer drift apart; ``benchmarks/distributed_scaling`` reuses this
    driver for the master's decode-stream serving.  Returns a callable
    running the whole queue once and a stats dict (filled per run)."""
    N = code.N
    nq = rx.shape[0]
    def _launch(v, e, bu):
        dec = peel_decode_batch_adaptive(code, v, e, backend=backend,
                                         budgets=bu)
        # per-slot unresolved counts on device: host only pulls (B,) stats
        return dec.values, dec.erased, dec.rounds_used, dec.erased.sum(axis=1)

    launch = jax.jit(_launch)
    # fixed-size refill (unused rows carry the drop sentinel B) so varying
    # admission counts reuse ONE compilation
    refill = jax.jit(
        lambda v, e, idx, nv, ne: (v.at[idx].set(nv, mode="drop"),
                                   e.at[idx].set(ne, mode="drop")))
    stats = {"launches": 0, "launch_rounds": 0, "slot_rounds": 0}

    def serve():
        # slot state stays DEVICE-RESIDENT across launches (free slots get
        # budget 0, so the decode passes their rows through untouched and
        # the outputs can be carried wholesale); the host sees only (B,)
        # stats vectors for the retire/refill decisions, which live in the
        # shared SlotPool.
        pool = SlotPool(B, budget, chunk)
        vals = jnp.zeros((B, N), jnp.float32)
        er = jnp.zeros((B, N), bool)
        nxt = done = launches = launch_rounds = slot_rounds = 0
        while done < nq:
            fill = pool.free_slots()[: nq - nxt]
            if fill:
                idx = np.full((B,), B, np.int32)   # sentinel rows: dropped
                nv = np.zeros((B, N), np.float32)
                ne = np.zeros((B, N), bool)
                for j, s in enumerate(fill):
                    pool.admit(s, nxt + j)         # owner = query index
                    idx[j] = s
                    nv[j] = rx[nxt + j]
                    ne[j] = erased[nxt + j]
                nxt += len(fill)
                vals, er = refill(vals, er, jnp.asarray(idx),
                                  jnp.asarray(nv), jnp.asarray(ne))
            occupied = pool.occupied
            budgets = pool.launch_budgets()
            vals, er, rounds_d, unres_d = launch(
                vals, er, jnp.asarray(budgets))
            launches += 1
            rounds = np.asarray(rounds_d)
            unres = np.asarray(unres_d)
            # wall-cost proxy: the launch's while_loop runs until its
            # slowest active slot stops; work proxy: per-slot rounds spent.
            launch_rounds += int(rounds.max(initial=0))
            slot_rounds += int(rounds[occupied].sum())
            done += len(pool.account(rounds, unres))
        stats["launches"] = launches
        stats["launch_rounds"] = launch_rounds
        stats["slot_rounds"] = slot_rounds

    return serve, stats


def run_serving_sweep(*, K=1024, B=64, n_queries=320, heavy_frac=0.15,
                      light_q=0.08, heavy_q=0.42, budget=32, chunk=4,
                      reps=3, seed=0):
    """Mixed light/heavy straggler serving: continuous vs lockstep.

    A stream of ``n_queries`` coded queries, ``heavy_frac`` of them with
    near-threshold erasure rates (many peeling rounds to converge) and the
    rest light (1-2 rounds).  Lockstep waves pay the worst-case ``budget``
    rounds for every wave; continuous admission lets each slot stop at its
    own fixpoint and refill, so the mean per-query decode cost tracks the
    REALIZED straggler mix.  Returns (table_rows, json_records);
    ``speedup_vs_lockstep`` is the same-run per-query cost ratio.
    """
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    N = code.N
    rng = np.random.default_rng(seed)
    msgs = rng.standard_normal((n_queries, K))
    cws = (code.G @ msgs.T).T.astype(np.float32)
    heavy = rng.random(n_queries) < heavy_frac
    qs = np.where(heavy, heavy_q, light_q)
    erased = rng.random((n_queries, N)) < qs[:, None]
    rx = np.where(erased, 0.0, cws)

    serve_ls, n_waves = _serve_lockstep(code, rx, erased, B=B, budget=budget)
    serve_ct, ct_stats = serve_continuous(code, rx, erased, B=B,
                                           budget=budget, chunk=chunk)
    results = {}
    for mode, serve in (("lockstep", serve_ls), ("continuous", serve_ct)):
        serve()  # compile + warm
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            serve()
            ts.append(time.perf_counter() - t0)
        results[mode] = float(np.median(ts))

    base = {"N": N, "K": K, "B": B, "n_queries": n_queries,
            "heavy_frac": heavy_frac, "light_q": light_q, "heavy_q": heavy_q,
            "budget": budget, "chunk": chunk,
            "jax_backend": jax.default_backend()}
    speedup = results["lockstep"] / results["continuous"]
    rows, records = [], []
    for mode, extra in (
            ("lockstep", {"launches": n_waves,
                          "launch_rounds": n_waves * budget,
                          "slot_rounds": n_waves * B * budget,
                          "speedup_vs_lockstep": 1.0}),
            ("continuous", {"launches": ct_stats["launches"],
                            "launch_rounds": ct_stats["launch_rounds"],
                            "slot_rounds": ct_stats["slot_rounds"],
                            "speedup_vs_lockstep": speedup})):
        t = results[mode]
        records.append({**base, "mode": mode, "median_s": t,
                        "per_query_us": t / n_queries * 1e6, **extra})
        rows.append([N, B, mode, extra["launches"], extra["launch_rounds"],
                     f"{t / n_queries * 1e6:.0f}",
                     f"{extra['speedup_vs_lockstep']:.2f}x"])
    return rows, records


def run_large_n_sweep(*, Ns=(2048, 4096, 8192, 16384), D=8, q=0.25, reps=3,
                      dense_max_n=16384, tiled_cpu_max_n=2048,
                      forced_backend: str | None = None):
    """Decode latency PAST the whole-H-in-VMEM regime (the tiled path's
    reason to exist).  Per N: the dense reference (kept through
    ``dense_max_n`` = the full sweep — its (p, N) f32 operand is ~512 MiB
    at N = 16384, the denominator every N's gate needs), sparse (the
    scalable CPU path, every N), and the check-axis-tiled fused kernel —
    timed compiled on TPU at every N; off-TPU one interpret-mode record at
    ``tiled_cpu_max_n`` only, run for trajectory parity and flagged
    ``interpret_mode`` (skipped by the gate, like every interpret record).
    The same-run ``speedup_vs_dense`` is what CI gates
    (``--sections large_n``).

    ``forced_backend`` exercises the VMEM-failover bugfix: the requested
    backend is resolved through ``resolve_bench_backend`` per N and the
    failover message (if any) is printed instead of crashing.
    """
    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for K in (n // 2 for n in Ns):
        code = make_parity_only_ldpc(K, l=3, r=6, seed=0)
        N, p = code.N, code.p
        r_max = code.check_idx.shape[1]
        rng = np.random.default_rng(N)
        # The trajectory depends only on H and the mask — any payload does
        # (these codes are parity-only; there is no generator to encode with).
        vals = jnp.asarray(rng.standard_normal(N), jnp.float32)
        erased = jnp.asarray(rng.random(N) < q)
        rx = jnp.where(erased, 0.0, vals)

        backends = []
        if forced_backend:
            backend, msg = resolve_bench_backend(code, forced_backend)
            if msg:
                print(f"[large_n N={N}] {msg}")
            backends.append(backend)
        else:
            if N <= dense_max_n:
                backends.append("dense")
            backends.append("sparse")
            if on_tpu or N <= tiled_cpu_max_n:
                backends.append("pallas_tiled")

        t_dense = None
        ref_erased = None
        for backend in backends:
            # bv=8: scalar payloads need 8 lanes, not the default 128
            # (ignored by dense/sparse; keeps the interpret record cheap).
            # ONE jitted decode serves both the timing (values) and the
            # trajectory tripwire (erased) — no second compile/execute.
            fn = jax.jit(lambda v, e, b=backend: tuple(peel_decode(
                code, v, e, D, backend=b, bv=8)[:2]))
            t = _median_seconds(lambda v, e: fn(v, e), rx, erased,
                                reps=reps)
            if backend == "dense":
                t_dense = t
            # trajectory spot-check: every backend must land on the same
            # unresolved set (bit-identical masks are the tiled path's
            # correctness claim; tests prove it exhaustively, the bench
            # keeps a tripwire on the exact configs it times)
            got_erased = np.asarray(fn(rx, erased)[1])
            if ref_erased is None:
                ref_erased = got_erased
            elif (got_erased != ref_erased).any():
                raise AssertionError(
                    f"large_n N={N}: backend={backend} erasure trajectory "
                    "diverged from the first backend's")
            work = (2.0 * p * N * 2 * D if backend == "dense"
                    else 2.0 * p * r_max * 2 * D)
            interp = backend in ("pallas", "pallas_tiled") and not on_tpu
            rec = {
                "backend": backend, "N": N, "K": K, "p": p, "D": D,
                "erasure_q": q, "median_s": t,
                "per_round_us": t / D * 1e6,
                "achieved_gflops": work / t / 1e9,
                "speedup_vs_dense": (t_dense / t) if t_dense else None,
                "interpret_mode": interp,
                "forced_backend": forced_backend,
                "jax_backend": jax.default_backend(),
            }
            records.append(rec)
            rows.append([N, K, backend, f"{t * 1e6:.0f}",
                         f"{t / D * 1e6:.1f}",
                         (f"{rec['speedup_vs_dense']:.2f}x"
                          if rec["speedup_vs_dense"] else "-"),
                         "interp" if interp else ""])
    return rows, records


def _decode_operand_bytes(N: int, D: int, *, bp: int, bv: int,
                          seeded: bool) -> float:
    """Modeled per-decode operand HBM traffic (bytes) of the fused kernels.

    Both kernels hold the payload in VMEM across all D rounds (one grid
    pass over the V axis): payload traffic is the one-time load + store of
    the padded ``(n_pad, bv)`` values and ``(n_pad, 1)`` erasure columns.
    The TILED kernel additionally DMAs the whole padded ``(p_pad, n_pad)``
    f32 parity-check matrix from HBM EVERY round (check tiles of height
    ``bp``); the SEEDED kernel regenerates those tiles in-register from
    ``(seed, row)`` — zero H bytes.  This is the memory wall the seeded
    construction removes, and the quantity the regression gate tracks.
    """
    p = N // 2                       # the sweep's rate-1/2 shapes
    n_pad = N + (-N) % 128
    p_pad = p + (-p) % bp
    payload = 2 * 4.0 * (n_pad * bv + n_pad)     # in + out, values + erased
    h_stream = 0.0 if seeded else float(D) * p_pad * n_pad * 4.0
    return payload + h_stream


def run_seeded_sweep(*, Ns=(2048, 4096, 8192, 16384, 32768), D=8, q=0.25,
                     reps=3, timed_n=2048, lower_only_n=262144, bv=8):
    """Seeded vs tiled fused decode: modeled operand traffic at every N,
    wall-clock + trajectory tripwire where timeable, and a lower-only
    feasibility record at an N where H cannot be materialized at all.

    Returns (table_rows, json_records).  ``traffic_ratio_vs_tiled`` (tiled
    bytes / seeded bytes, same model both sides) is gated by
    ``check_regression.py --sections seeded`` — including the hard ≥10×
    floor at N=16384.  The timed record at ``timed_n`` runs BOTH kernels on
    one seeded code (``make_seeded_ldpc`` materializes H exactly so the
    tiled reference exists) and asserts bit-identical values and erasure
    trajectories — the seeded kernel's summation is tile-shaped like the
    tiled one's, so even the f32 values must match bit for bit.
    """
    from repro.core.ldpc import make_seeded_ldpc, seeded_structure

    on_tpu = jax.default_backend() == "tpu"
    bp = 128
    rows, records = [], []
    for N in Ns:
        tiled_b = _decode_operand_bytes(N, D, bp=bp, bv=bv, seeded=False)
        seeded_b = _decode_operand_bytes(N, D, bp=bp, bv=bv, seeded=True)
        rec = {
            "N": N, "D": D, "bp": bp, "bv": bv, "erasure_q": q,
            "modeled_tiled_bytes": tiled_b,
            "modeled_seeded_bytes": seeded_b,
            "traffic_ratio_vs_tiled": tiled_b / seeded_b,
            "timed": False,
            "jax_backend": jax.default_backend(),
        }
        timed = N == timed_n and (on_tpu or N <= 2048)
        if timed:
            code = make_seeded_ldpc(N // 2, l=4, r=8, seed=0)
            assert code.N == N, (code.N, N)
            rng = np.random.default_rng(N)
            vals = jnp.asarray(rng.standard_normal(N), jnp.float32)
            erased = jnp.asarray(rng.random(N) < q)
            rx = jnp.where(erased, 0.0, vals)
            ts, outs = {}, {}
            for backend in ("pallas_tiled", "pallas_seeded"):
                fn = jax.jit(lambda v, e, b=backend: tuple(peel_decode(
                    code, v, e, D, backend=b, bp=bp, bv=bv)[:2]))
                ts[backend] = _median_seconds(lambda v, e: fn(v, e), rx,
                                              erased, reps=reps)
                outs[backend] = tuple(np.asarray(x) for x in fn(rx, erased))
            # tripwire: same tile-shaped summation → bit-identical VALUES,
            # not just the same erasure trajectory
            if (outs["pallas_seeded"][0] != outs["pallas_tiled"][0]).any() \
                    or (outs["pallas_seeded"][1]
                        != outs["pallas_tiled"][1]).any():
                raise AssertionError(
                    f"seeded N={N}: decode diverged from pallas_tiled on "
                    "the same code (values or erasure trajectory)")
            rec.update({
                "timed": True,
                "median_s_tiled": ts["pallas_tiled"],
                "median_s_seeded": ts["pallas_seeded"],
                "wallclock_ratio_vs_tiled":
                    ts["pallas_seeded"] / ts["pallas_tiled"],
                "interpret_mode": not on_tpu,
            })
        records.append(rec)
        rows.append([N, f"{tiled_b / 2**20:.1f}", f"{seeded_b / 2**20:.3f}",
                     f"{rec['traffic_ratio_vs_tiled']:.0f}x",
                     (f"{rec['wallclock_ratio_vs_tiled']:.2f}x"
                      if timed else "-"),
                     "interp" if timed and not on_tpu else ""])

    # Feasibility: the seeded kernel LOWERS at an N where the (p, N) f32 H
    # is 128 GiB — no materialized backend can even be constructed there.
    spec = seeded_structure(lower_only_n // 2, lower_only_n, 8, 0)
    from repro.kernels.ldpc_peel import peel_decode_seeded_pallas
    fn = jax.jit(lambda v, e: peel_decode_seeded_pallas(
        spec, v, e, D, bp=512, bv=bv))
    lowered = fn.lower(
        jax.ShapeDtypeStruct((lower_only_n,), jnp.float32),
        jax.ShapeDtypeStruct((lower_only_n,), jnp.bool_))
    del lowered
    h_bytes = (lower_only_n // 2) * lower_only_n * 4.0
    records.append({
        "N": lower_only_n, "D": D, "mode": "lower-only", "lower_ok": True,
        "h_bytes_if_materialized": h_bytes,
        "jax_backend": jax.default_backend(),
    })
    rows.append([lower_only_n, f"(H would be {h_bytes / 2**30:.0f} GiB)",
                 "seed-only", "-", "lowered OK", ""])
    return rows, records


def run_seeded_gather_sweep(*, Ns=(2048, 4096, 8192, 16384, 32768), D=8,
                            V=8, q=0.25, reps=3, timed_n=2048, bp=128):
    """Gather vs dense-tile seeded rounds: modeled per-round FLOPs at every
    N, wall-clock + trajectory tripwire where timeable.

    Returns (table_rows, json_records).  ``flops_ratio_vs_dense_tile``
    (dense FLOPs / gather FLOPs, the same :mod:`repro.core.hwcaps` model
    ``seeded_mode="auto"`` dispatches on) is gated by
    ``check_regression.py --sections seeded_gather`` — including the hard
    ≥8× floor at N=16384.  The timed record at ``timed_n`` runs BOTH modes
    on one seeded code and asserts the bit-exact part of the contract:
    identical erasure trajectories and untouched never-erased values
    (resolved values agree to f32 summation order only — the dense round
    contracts over N, the gather round sums r edges per row).
    """
    from repro.core.hwcaps import (seeded_dense_round_flops,
                                   seeded_gather_round_flops)
    from repro.core.ldpc import make_seeded_ldpc, seeded_structure

    on_tpu = jax.default_backend() == "tpu"
    rows, records = [], []
    for N in Ns:
        spec = seeded_structure(N // 2, N, 8, 0)
        dense_f = seeded_dense_round_flops(spec, V, bp=bp)
        gather_f = seeded_gather_round_flops(spec, V, bp=bp)
        rec = {
            "N": N, "D": D, "V": V, "bp": bp, "erasure_q": q,
            "modeled_dense_tile_flops_per_round": dense_f,
            "modeled_gather_flops_per_round": gather_f,
            "flops_ratio_vs_dense_tile": dense_f / gather_f,
            "timed": False,
            "jax_backend": jax.default_backend(),
        }
        timed = N == timed_n and (on_tpu or N <= 2048)
        if timed:
            code = make_seeded_ldpc(N // 2, l=4, r=8, seed=0)
            assert code.N == N, (code.N, N)
            rng = np.random.default_rng(N)
            vals = jnp.asarray(rng.standard_normal((N, V)), jnp.float32)
            erased = jnp.asarray(rng.random(N) < q)
            rx = jnp.where(erased[:, None], 0.0, vals)
            ts, outs = {}, {}
            for mode in ("dense_tile", "gather"):
                fn = jax.jit(lambda v, e, m=mode: tuple(peel_decode(
                    code, v, e, D, backend="pallas_seeded", bp=bp, bv=8,
                    seeded_mode=m)[:2]))
                ts[mode] = _median_seconds(lambda v, e: fn(v, e), rx,
                                           erased, reps=reps)
                outs[mode] = tuple(np.asarray(x) for x in fn(rx, erased))
            # tripwire: the TRAJECTORY is bit-exact across modes, and
            # never-erased coordinates pass through untouched
            still = ~np.asarray(erased)
            if (outs["gather"][1] != outs["dense_tile"][1]).any() or \
                    (outs["gather"][0][still]
                     != outs["dense_tile"][0][still]).any():
                raise AssertionError(
                    f"seeded_gather N={N}: gather round diverged from "
                    "dense_tile (erasure trajectory or known values)")
            rec.update({
                "timed": True,
                "median_s_dense_tile": ts["dense_tile"],
                "median_s_gather": ts["gather"],
                "wallclock_ratio_vs_dense_tile":
                    ts["gather"] / ts["dense_tile"],
                "interpret_mode": not on_tpu,
            })
        records.append(rec)
        rows.append([N, f"{dense_f / 1e6:.1f}", f"{gather_f / 1e6:.2f}",
                     f"{rec['flops_ratio_vs_dense_tile']:.0f}x",
                     (f"{rec['wallclock_ratio_vs_dense_tile']:.2f}x"
                      if timed else "-"),
                     "interp" if timed and not on_tpu else ""])
    return rows, records


def run_replay_sweep(*, N=8192, n_patterns=8, n_queries=64, q=0.25,
                     budget=32, reps=3, seed=0):
    """Pattern-compiled replay vs flooding sparse on a recurring stream.

    ``n_queries`` coded queries cycle through ``n_patterns`` distinct
    erasure patterns (straggler patterns are sticky in practice — that is
    the schedule cache's premise), so a cold :class:`ScheduleCache` over
    the stream realizes a hit rate of ``1 - n_patterns / n_queries``
    (0.875 at the defaults).  Three quantities per config:

    * modeled work — flooding runs every check row's ``r_max`` edges every
      round until fixpoint (+1 probe round); replay runs each resolving
      row's edges exactly once.  ``modeled_work_ratio`` is their quotient.
    * timed — per-query decode over the whole stream, flooding sparse
      adaptive vs warm-cache schedule replay (both jitted, same queries,
      same machine): ``cache_hit_speedup_vs_sparse`` is the same-run ratio
      ``check_regression.py --sections replay`` gates (hard ≥2× floor at
      N=8192).
    * tripwire — per pattern, the replay's values AND erasure trajectory
      must be bit-identical to the flooding sparse decode's (the "hi"
      tie-break rule exists for exactly this).

    The hit rate is read back from the obs ``sched_cache.hit_rate`` gauge
    (a scoped registry around the cold pass), so the gate also covers the
    cache's instrumentation path.  Returns (table_rows, json_records).
    """
    from repro.core import compile_peel_schedule
    from repro.core.schedule_cache import ScheduleCache
    from repro.obs import metrics as obs_metrics

    code = make_parity_only_ldpc(N // 2, l=3, r=6, seed=seed)
    assert code.N == N, (code.N, N)
    p = code.p
    r_max = code.check_idx.shape[1]
    rng = np.random.default_rng(seed)
    pats = rng.random((n_patterns, N)) < q                   # (P, N)
    # Any payload traces the same schedule (parity-only code: the decode
    # trajectory depends only on H and the mask, same as the large-N sweep).
    vals = rng.standard_normal((n_queries, N)).astype(np.float32)
    erased_np = pats[np.arange(n_queries) % n_patterns]      # (Q, N)
    rx_np = np.where(erased_np, 0.0, vals)
    rx = jnp.asarray(rx_np)
    er = jnp.asarray(erased_np)

    # modeled work: edge-ops per decode, averaged over the pattern set
    scheds = [compile_peel_schedule(code, pats[i]) for i in range(n_patterns)]
    flood_edges = float(np.mean(
        [(s.n_rounds + (0 if s.fully_resolved else 1)) * p * r_max
         for s in scheds]))
    replay_edges = float(np.mean(
        [max(s.n_resolved, 1) * r_max for s in scheds]))

    # bit-identity tripwire: replay ("hi" rule) vs single-pattern sparse
    sparse_fn = jax.jit(lambda v, e: tuple(peel_decode_adaptive(
        code, v, e, budget, backend="sparse")[:3]))
    for i in range(n_patterns):
        sv, se, sd = (np.asarray(x) for x in sparse_fn(rx[i], er[i]))
        dec = peel_decode_adaptive(code, rx[i], er[i], budget,
                                   backend="replay", schedule=scheds[i])
        if (np.asarray(dec.values) != sv).any() \
                or (np.asarray(dec.erased) != se).any() \
                or int(dec.rounds_used) != int(sd):
            raise AssertionError(
                f"replay N={N} pattern {i}: replay diverged from the "
                "flooding sparse decode (values, erasure trajectory, or "
                "round count)")

    # realized hit rate: a COLD cache over the stream, read back from the
    # obs gauge the cache maintains
    with obs_metrics.recording() as reg:
        cache = ScheduleCache()
        for i in range(n_queries):
            cache.get(code, erased_np[i])
        hit_rate = reg.gauge("sched_cache.hit_rate").value

    # timed: per-query decode over the whole stream (the cache is warm now
    # — every lookup hits, which is the steady state the gate is about)
    def serve_sparse():
        for i in range(n_queries):
            sparse_fn(rx[i], er[i])[0].block_until_ready()

    def serve_replay():
        for i in range(n_queries):
            s = cache.get(code, erased_np[i])
            peel_decode_adaptive(code, rx[i], er[i], budget,
                                 backend="replay", schedule=s
                                 ).values.block_until_ready()

    results = {}
    for mode, serve in (("sparse", serve_sparse), ("replay", serve_replay)):
        serve()  # compile + warm (one executable per distinct segment shape)
        ts = []
        for _ in range(reps):
            t0 = time.perf_counter()
            serve()
            ts.append(time.perf_counter() - t0)
        results[mode] = float(np.median(ts))

    speedup = results["sparse"] / results["replay"]
    rec = {
        "N": N, "K": N // 2, "p": p, "r_max": r_max,
        "n_patterns": n_patterns, "n_queries": n_queries,
        "budget": budget, "erasure_q": q,
        "mean_flood_rounds": float(np.mean(
            [s.n_rounds + (0 if s.fully_resolved else 1) for s in scheds])),
        "mean_resolved": float(np.mean([s.n_resolved for s in scheds])),
        "modeled_flooding_edge_ops": flood_edges,
        "modeled_replay_edge_ops": replay_edges,
        "modeled_work_ratio": flood_edges / replay_edges,
        "median_s_sparse": results["sparse"],
        "median_s_replay": results["replay"],
        "per_query_us_sparse": results["sparse"] / n_queries * 1e6,
        "per_query_us_replay": results["replay"] / n_queries * 1e6,
        "cache_hit_speedup_vs_sparse": speedup,
        "schedule_cache_hit_rate": float(hit_rate),
        "cache_stats": cache.stats(),
        "bit_identical": True,      # the tripwire above raises otherwise
        "jax_backend": jax.default_backend(),
    }
    rows = [[N, n_patterns, n_queries,
             f"{rec['modeled_work_ratio']:.0f}x",
             f"{rec['per_query_us_sparse']:.0f}",
             f"{rec['per_query_us_replay']:.0f}",
             f"{speedup:.2f}x", f"{hit_rate:.3f}"]]
    return rows, [rec]


def run(*, Ks=(64, 256, 1024), ss=(2, 8, 24), reps=10):
    rows = []
    for K in Ks:
        code = make_regular_ldpc(K, l=3, r=6, seed=0)
        G = jnp.asarray(code.G, jnp.float32)
        rng = np.random.default_rng(0)
        cw = jnp.asarray(code.encode(rng.standard_normal(K)), jnp.float32)
        for s in ss:
            key = jax.random.PRNGKey(s)
            mask = FixedCountStragglers(s).sample(key, code.N)
            rx = jnp.where(mask, 0.0, cw)

            dec = peel_decode_adaptive(code, rx, mask)
            rounds = int(dec.rounds_used)
            unresolved = int(dec.erased.sum())

            f = jax.jit(lambda v, e: peel_decode_adaptive(code, v, e).values)
            f(rx, mask).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                f(rx, mask).block_until_ready()
            t_ldpc = (time.perf_counter() - t0) / reps

            # MDS-style exact recovery: weighted lstsq on surviving rows
            def mds(v, e):
                alive = (~e).astype(jnp.float32)
                sol, *_ = jnp.linalg.lstsq(G * alive[:, None], v * alive)
                return sol

            g = jax.jit(mds)
            g(rx, mask).block_until_ready()
            t0 = time.perf_counter()
            for _ in range(reps):
                g(rx, mask).block_until_ready()
            t_lstsq = (time.perf_counter() - t0) / reps

            rows.append([code.N, K, s, rounds, unresolved,
                         f"{t_ldpc*1e6:.0f}", f"{t_lstsq*1e6:.0f}",
                         f"{t_lstsq/max(t_ldpc,1e-12):.1f}x"])
    return rows


def main(quick: bool = False, json_path: str | Path = BENCH_JSON,
         backend: str | None = None, obs_out: str | Path | None = None):
    from repro.obs import ObsSession
    session = ObsSession.start(obs_out)
    try:
        return _main(quick=quick, json_path=json_path, backend=backend)
    finally:
        session.finish()


def _main(quick: bool = False, json_path: str | Path = BENCH_JSON,
          backend: str | None = None):
    if backend:
        # Forced-backend run (the VMEM-failover bugfix path): resolve the
        # request with a clear message and run ONE size past the limit
        # (N=2048 triggers both failovers: > interpret budget off-TPU,
        # > VMEM budget on TPU) — proving the path, not re-measuring the
        # sweep.  Leaves the committed JSON alone.
        lrows, _ = run_large_n_sweep(Ns=(2048,), reps=1,
                                     forced_backend=backend)
        print_table(f"Large-N sweep — forced backend {backend!r} "
                    "(failover-resolved)",
                    ["N", "K", "backend", "decode_us", "round_us",
                     "speedup_vs_dense", ""], lrows)
        return lrows

    # 1. backend scaling (the per-PR perf trajectory)
    Ks = (64, 256, 1024) if quick else (64, 256, 512, 1024, 2048)
    brows, records = run_backend_scaling(Ks=Ks, reps=3 if quick else 5)
    print_table("Decode backends — fixed-D latency (dense vs sparse vs "
                "fused-Pallas)",
                ["N", "K", "backend", "decode_us", "round_us",
                 "achieved_GFLOP/s", "speedup"], brows)

    # 2. batched decode over independent erasure patterns (serving axis)
    # K=64 (N=128) exists so the batched-Pallas kernel is exercised off-TPU
    # too (interpret mode, small N only — see _PALLAS_CPU_MAX_N).
    batch_rows, batch_records = run_batched_scaling(
        Ks=(1024,) if quick else (64, 256, 1024),
        Bs=(1, 64) if quick else (1, 8, 64, 256),
        reps=3 if quick else 5)
    print_table("Batched decode — B independent erasure patterns, one launch",
                ["N", "K", "B", "mode", "per_query_us", "speedup_vs_seq"],
                batch_rows)

    # 2b. mixed light/heavy serving: continuous admission vs lockstep waves
    # (B=64, N=2048 — the acceptance config).  Quick mode trims only reps:
    # the sweep config must stay IDENTICAL to the committed baseline's so
    # check_regression finds matching records to gate.
    serve_rows, serve_records = run_serving_sweep(reps=2 if quick else 3)
    print_table("Serving sweep — mixed light/heavy stragglers, mean "
                "per-query decode cost",
                ["N", "B", "mode", "launches", "launch_rounds",
                 "per_query_us", "speedup_vs_lockstep"], serve_rows)

    # 6. large-N sweep — the check-axis-tiled regime.  The config is FIXED
    # (identical in quick mode, reps included: the whole sweep is ~20 s and
    # the gated dense/sparse ratio is noise-sensitive at reps=2) so
    # check_regression always finds matching (backend, N, D) records.
    lrows, large_records = run_large_n_sweep(reps=3)
    print_table("Large-N sweep — past the whole-H-in-VMEM regime "
                "(tiled kernel where timeable)",
                ["N", "K", "backend", "decode_us", "round_us",
                 "speedup_vs_dense", ""], lrows)

    # 7. seeded sweep — in-kernel H regeneration vs streamed H.  Config is
    # FIXED in quick mode (the sweep is modeled arithmetic + one timed N +
    # one lower-only record, ~seconds total) so check_regression always
    # finds matching (N, D) records.
    srows, seeded_records = run_seeded_sweep(reps=3)
    print_table("Seeded sweep — modeled operand HBM traffic and wall-clock, "
                "seeded vs check-axis-tiled",
                ["N", "tiled_MiB", "seeded_MiB", "traffic_ratio",
                 "wallclock_ratio", ""], srows)

    # 8. seeded-gather sweep — edge-proportional rounds vs dense tiles.
    # Fixed config in quick mode for the same reason as section 7 (modeled
    # arithmetic + one timed N, seconds total; the gate needs matching
    # (N, D, V) records).
    sgrows, seeded_gather_records = run_seeded_gather_sweep(reps=3)
    print_table("Seeded-gather sweep — modeled per-round FLOPs and "
                "wall-clock, gather vs dense-tile rounds",
                ["N", "dense_MFLOP", "gather_MFLOP", "flops_ratio",
                 "wallclock_ratio", ""], sgrows)

    # 9. replay sweep — pattern-compiled peeling on a recurring stream.
    # Config is FIXED in quick mode (reps trimmed only): the gate needs a
    # matching (N, n_queries, n_patterns, budget) record, and the hard
    # speedup floor is a same-run ratio either way.
    rrows, replay_records = run_replay_sweep(reps=2 if quick else 3)
    print_table("Replay sweep — cache-hit schedule replay vs flooding "
                "sparse, recurring straggler stream",
                ["N", "P", "Q", "work_ratio", "sparse_us", "replay_us",
                 "speedup", "hit_rate"], rrows)

    # 3+5. adaptivity & vs-lstsq
    rows = run(Ks=(64, 256) if quick else (64, 256, 1024))
    print_table("Decoder scaling — adaptive peeling vs least-squares recovery",
                ["N", "K", "s", "rounds", "unresolved",
                 "ldpc_us", "lstsq_us", "speedup"], rows)

    # 4. D-monotonicity (Remark 3)
    code = make_regular_ldpc(256, l=3, r=6, seed=1)
    rng = np.random.default_rng(1)
    erased = jnp.asarray(rng.random(code.N) < 0.25)
    dummy = jnp.zeros((code.N,), jnp.float32)
    drows = [[D, int(peel_decode(code, dummy, erased, D).erased.sum())]
             for D in (0, 1, 2, 4, 8, 16)]
    print_table("Unresolved coordinates vs decode rounds D (q0≈0.25)",
                ["D", "unresolved"], drows)

    out = {
        "benchmark": "decoder_scaling",
        # v6: adds the "seeded" section (in-kernel H regeneration: modeled
        # operand-traffic ratio vs the tiled kernel, gated ≥10× at N=16384,
        # plus the timed + lower-only feasibility records).
        # v8: adds the "seeded_gather" section (edge-proportional gather
        # rounds: modeled per-round FLOPs ratio vs the dense regenerated
        # tile — the hwcaps crossover model — gated ≥8× at N=16384, plus a
        # timed interpret record with a trajectory tripwire).
        # v10: adds the "replay" section (pattern-compiled peeling: modeled
        # flooding/replay work ratio, the timed cache-hit replay speedup on
        # a recurring straggler stream — gated ≥2× at N=8192 — the realized
        # schedule-cache hit rate via the obs gauge, and the bit-identity
        # tripwire).
        "schema_version": 10,
        "jax_backend": jax.default_backend(),
        "fused_decode_single_kernel_launch": True,  # see ldpc_peel/ops.py
        "backend_scaling": records,
        "batched_scaling": batch_records,
        "serving_sweep": serve_records,
        "large_n": large_records,
        "seeded": seeded_records,
        "seeded_gather": seeded_gather_records,
        "replay": replay_records,
        "adaptive_vs_lstsq": [
            dict(zip(["N", "K", "s", "rounds", "unresolved",
                      "ldpc_us", "lstsq_us", "speedup"], r)) for r in rows
        ],
        "d_monotonicity": [dict(zip(["D", "unresolved"], r)) for r in drows],
    }
    # since schema v4: the distributed sweep (distributed_scaling.py, run
    # on its own fake-worker mesh process) appends its section to the same
    # file — carry it through instead of dropping it on rewrite.
    try:
        prev = json.loads(Path(json_path).read_text())
        if "distributed_scaling" in prev:
            out["distributed_scaling"] = prev["distributed_scaling"]
    except (FileNotFoundError, json.JSONDecodeError):
        pass
    Path(json_path).write_text(json.dumps(out, indent=2))
    print(f"\nwrote {json_path}")
    return brows


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--backend", default=None,
                    choices=["dense", "sparse", "pallas", "pallas_tiled",
                             "pallas_seeded"],
                    help="FORCE one decode backend through the large-N "
                         "sweep (failover-resolved past the VMEM limit — "
                         "or past a missing seed — instead of crashing); "
                         "skips the JSON rewrite")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="export obs metrics JSONL (+ .trace.json spans) "
                         "from the instrumented sweeps to PATH")
    a = ap.parse_args()
    main(quick=a.quick, backend=a.backend, obs_out=a.obs_out)
