"""Benchmark harness — one module per paper figure plus the system-level
reports.  ``python -m benchmarks.run [--full]``.

  fig1_least_squares — paper Fig. 1 (least squares, k sweep, s ∈ {5,10})
  fig2_sparse_over   — paper Fig. 2 (overdetermined IHT sparsity sweep)
  fig3_sparse_under  — paper Fig. 3 (underdetermined IHT)
  decoder_scaling    — Section 3 decode-complexity/adaptivity claims
  roofline           — §Roofline table from the dry-run artifacts

Default mode is sized for this CPU container (fewer trials / smaller k
grids than the paper's 100-trial cluster runs); --full restores the paper's
grids.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true",
                    help="paper-sized grids (slower)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: fig1,fig2,fig3,decoder,roofline")
    args = ap.parse_args(argv)
    quick = not args.full

    from benchmarks import (decoder_scaling, fig1_least_squares,
                            fig2_sparse_over, fig3_sparse_under, roofline)
    suite = {
        "fig1": fig1_least_squares.main,
        "fig2": fig2_sparse_over.main,
        "fig3": fig3_sparse_under.main,
        "decoder": decoder_scaling.main,
        "roofline": roofline.main,
    }
    only = args.only.split(",") if args.only else list(suite)
    t0 = time.time()
    for name in only:
        t = time.time()
        print(f"\n================ {name} ================")
        suite[name](quick=quick)
        print(f"[{name}: {time.time()-t:.1f}s]")
    print(f"\nbenchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
