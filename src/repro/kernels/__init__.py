"""Pallas TPU kernels for the compute hot spots.

Each kernel package contains:
  kernel.py — pl.pallas_call with explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd wrapper (padding, grid setup, epilogue)
  ref.py    — pure-jnp oracle used by the allclose test sweeps

On this CPU container kernels are validated with interpret=True (the kernel
body executes in Python); the BlockSpecs are written for TPU VMEM/MXU tiling
(128-aligned matmul dims, f32 accumulation).

Kernels:
  ldpc_peel       — fused check-node pass of the peeling decoder (the paper's
                    per-step master-side hot loop)
  block_matmul    — tiled C = A @ B (moment encode G@M; worker matvec C@theta)
  flash_attention — causal online-softmax attention (zoo serving/training)
"""
