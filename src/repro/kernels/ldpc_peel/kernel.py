"""Fused check-node pass of the LDPC peeling decoder, as a Pallas TPU kernel.

Per flooding round, for every parity check row i we need four quantities:

  cnt_i   = #erased neighbours              (solvable iff == 1)
  sums_i  = H[i,:] @ (values ⊙ known)       (the resolved value numerator)
  pos_i   = index of the (unique) erased neighbour
  coeff_i = H[i, pos_i]

The reference decoder computes these with three separate dense ops over H
(mask matvec, matmul, argmax) — three passes over the H block from HBM.  The
kernel fuses them into ONE pass: each grid step loads a (BP x N) tile of H
into VMEM once and produces all four outputs.

TPU notes:
  * matmul dims padded to multiples of 128 (MXU), f32 accumulation;
  * pos is computed with broadcasted_iota + max (no 1-D iota on TPU);
  * 1-D per-check outputs are materialized as (BP, 1) tiles (TPU wants >=2D);
  * grid = (p/BP, V/BV): the H tile is re-used across the V (payload) axis,
    value tiles stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["check_pass"]


def _check_kernel(H_ref, vals_ref, erased_ref, sums_ref, cnt_ref, pos_ref,
                  coeff_ref):
    H = H_ref[...]  # (BP, N) f32
    e = erased_ref[...][:, 0]  # (N,) f32: 1.0 = erased
    Hb = (H != 0.0).astype(jnp.float32)

    cnt = jax.lax.dot(Hb, e[:, None], precision=jax.lax.Precision.HIGHEST)  # (BP,1)
    known = vals_ref[...] * (1.0 - e)[:, None]  # (N, BV)
    sums = jax.lax.dot(H, known, precision=jax.lax.Precision.HIGHEST)  # (BP,BV)

    # erased-neighbour index per row: max over iota masked to erased edges
    idx = jax.lax.broadcasted_iota(jnp.int32, H.shape, 1)
    mask = (Hb * e[None, :]) > 0.0
    pos = jnp.max(jnp.where(mask, idx, -1), axis=1)  # (BP,)
    onehot = (idx == pos[:, None]).astype(jnp.float32)
    coeff = jnp.sum(H * onehot, axis=1)  # (BP,)

    sums_ref[...] = sums
    cnt_ref[...] = cnt
    pos_ref[...] = pos[:, None]
    coeff_ref[...] = coeff[:, None]


@functools.partial(jax.jit, static_argnames=("bp", "bv", "interpret"))
def check_pass(H: jax.Array, values: jax.Array, erased_f: jax.Array, *,
               bp: int = 128, bv: int = 128, interpret: bool = True):
    """Inputs (already padded by ops.py): H (p, N) f32, values (N, V) f32,
    erased_f (N, 1) f32.  p % bp == 0, V % bv == 0, N % 128 == 0.

    Returns (sums (p, V), cnt (p, 1), pos (p, 1) i32, coeff (p, 1))."""
    p, N = H.shape
    V = values.shape[1]
    grid = (p // bp, V // bv)
    return pl.pallas_call(
        _check_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, N), lambda i, j: (i, 0)),   # H tile: reused over j
            pl.BlockSpec((N, bv), lambda i, j: (0, j)),   # payload tile
            pl.BlockSpec((N, 1), lambda i, j: (0, 0)),    # erasure mask
        ],
        out_specs=[
            pl.BlockSpec((bp, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, V), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)
