"""LDPC peeling-decoder Pallas TPU kernels.

Four kernels live here:

* :func:`check_pass` — the fused check-node pass of ONE flooding round
  (kept as the building block for the per-round path and its tests);
* :func:`decode_fused` — the whole fixed-``D`` decode in ONE ``pallas_call``:
  the ``(p, N)`` H tile is loaded into VMEM once and stays resident across a
  ``fori_loop`` over rounds, with the variable-node scatter epilogue fused
  in-kernel.  This removes the per-round kernel relaunch, re-padding, and
  HBM round-trips of the old ``ops.peel_decode_pallas`` (D launches → 1);
* :func:`decode_fused_batch` — ``B`` INDEPENDENT erasure patterns decoded in
  one launch: grid ``(B, V/bv)`` with the same H block mapped at every grid
  step, so H is loaded into VMEM once and stays resident across the whole
  batch while per-query payload/mask tiles stream through.  This is the
  kernel behind ``CodedComputeEngine.decode_batch`` (serving many concurrent
  coded queries);
* :func:`decode_fused_adaptive` — the early-exit decode as one launch: an
  in-kernel ``lax.while_loop`` on the unresolved count replicates
  ``peel_decode_adaptive``'s exact stopping rule (progress made AND
  erasures remain AND round budget left), emitting the rounds-used count;
* :func:`decode_fused_batch_adaptive` — per-slot adaptive decode of ``B``
  independent erasure patterns in one launch: the grid runs over the slots
  (H resident/shared in VMEM as in :func:`decode_fused_batch`) and each
  grid step runs its OWN in-kernel ``while_loop`` whose predicate combines
  that slot's convergence state with a PER-SLOT round budget streamed in as
  a ``(1, 1)`` int32 block — a light-straggler slot exits after 1-2 rounds
  while a heavy one keeps peeling, and the per-slot rounds-used vector
  comes back out.  This is the kernel behind
  ``CodedComputeEngine.decode_batch(adaptive=True)`` and the serving
  layer's continuous-admission slot server.

The in-kernel "scatter" is expressed MXU-style: the per-check resolution
one-hot ``(p, N)`` is transposed into a matmul that accumulates each
resolved coordinate's new value — TPUs have no efficient in-kernel scatter,
but a ``(N, p) @ (p, V)`` dot is native.  Checks that resolve the same
coordinate in the same round write consistent values (they are parity checks
of one codeword); the kernel deterministically keeps the lowest-index
check's value.

TPU notes:
  * matmul dims padded to multiples of 128 (MXU), f32 accumulation;
  * pos is computed with broadcasted_iota + max (no 1-D iota on TPU);
  * 1-D per-check outputs are materialized as (BP, 1) tiles (TPU wants >=2D);
  * check_pass grid = (p/BP, V/BV): the H tile is re-used across the V
    (payload) axis, value tiles stream through VMEM;
  * decode_fused grid = (V/BV,): H stays whole in VMEM — with several
    (p, N)-shaped temporaries live per round, the "auto" backend only
    routes N ≤ 512 codes here (see core/decoder.py) — and each
    grid step runs all D rounds for its payload slice.  The erasure
    trajectory depends only on H and the initial mask, so every slice
    recomputes the identical trajectory and the shared erasure output is
    written consistently by each step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["check_pass", "decode_fused", "decode_fused_batch",
           "decode_fused_adaptive", "decode_fused_batch_adaptive",
           "detect_interpret"]


def detect_interpret(interpret: bool | None) -> bool:
    """Pallas runs compiled only on TPU; anywhere else use interpret mode."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _check_kernel(H_ref, vals_ref, erased_ref, sums_ref, cnt_ref, pos_ref,
                  coeff_ref):
    H = H_ref[...]  # (BP, N) f32
    e = erased_ref[...][:, 0]  # (N,) f32: 1.0 = erased
    Hb = (H != 0.0).astype(jnp.float32)

    cnt = jax.lax.dot(Hb, e[:, None], precision=jax.lax.Precision.HIGHEST)  # (BP,1)
    known = vals_ref[...] * (1.0 - e)[:, None]  # (N, BV)
    sums = jax.lax.dot(H, known, precision=jax.lax.Precision.HIGHEST)  # (BP,BV)

    # erased-neighbour index per row: max over iota masked to erased edges
    idx = jax.lax.broadcasted_iota(jnp.int32, H.shape, 1)
    mask = (Hb * e[None, :]) > 0.0
    pos = jnp.max(jnp.where(mask, idx, -1), axis=1)  # (BP,)
    onehot = (idx == pos[:, None]).astype(jnp.float32)
    coeff = jnp.sum(H * onehot, axis=1)  # (BP,)

    sums_ref[...] = sums
    cnt_ref[...] = cnt
    pos_ref[...] = pos[:, None]
    coeff_ref[...] = coeff[:, None]


@functools.partial(jax.jit, static_argnames=("bp", "bv", "interpret"))
def check_pass(H: jax.Array, values: jax.Array, erased_f: jax.Array, *,
               bp: int = 128, bv: int = 128, interpret: bool | None = None):
    """Inputs (already padded by ops.py): H (p, N) f32, values (N, V) f32,
    erased_f (N, 1) f32.  p % bp == 0, V % bv == 0, N % 128 == 0.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (sums (p, V), cnt (p, 1), pos (p, 1) i32, coeff (p, 1))."""
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (p // bp, V // bv)
    return pl.pallas_call(
        _check_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, N), lambda i, j: (i, 0)),   # H tile: reused over j
            pl.BlockSpec((N, bv), lambda i, j: (0, j)),   # payload tile
            pl.BlockSpec((N, 1), lambda i, j: (0, 0)),    # erasure mask
        ],
        out_specs=[
            pl.BlockSpec((bp, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, V), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# ------------------------------------------------------------ fused decode --


def _flood_round(H):
    """Build the in-kernel flooding-round function for a resident H tile.

    Shared by the fixed-D, batched, and adaptive fused kernels so all three
    follow the identical erasure trajectory (same solvability decisions,
    same resolved neighbour, same lowest-index-check tie-break).
    """
    Hb = (H != 0.0).astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, H.shape, 1)  # (p, N)
    row = jax.lax.broadcasted_iota(jnp.int32, H.shape, 0)  # (p, N)
    HIGH = jax.lax.Precision.HIGHEST

    def round_body(vals, e):
        # vals (N, BV) f32, e (N, 1) f32 (1.0 = erased)
        cnt = jax.lax.dot(Hb, e, precision=HIGH)  # (p, 1)
        solvable = cnt[:, 0] == 1.0  # (p,)
        known = vals * (1.0 - e)
        sums = jax.lax.dot(H, known, precision=HIGH)  # (p, BV)
        emask = (Hb * e[:, 0][None, :]) > 0.0
        pos = jnp.max(jnp.where(emask, col, -1), axis=1)  # (p,)
        onehot = ((col == pos[:, None]) & solvable[:, None])  # (p, N) bool
        coeff = jnp.sum(H * onehot.astype(jnp.float32), axis=1)  # (p,)
        new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
        # Several checks may resolve the same coordinate; keep the
        # lowest-index check's (consistent) value deterministically.
        winner_row = jnp.min(jnp.where(onehot, row, H.shape[0]), axis=0)  # (N,)
        winner = (onehot & (row == winner_row[None, :])).astype(jnp.float32)
        resolved = jnp.max(winner, axis=0)[:, None]  # (N, 1) ∈ {0, 1}
        scattered = jax.lax.dot(winner.T, new_val, precision=HIGH)  # (N, BV)
        vals = jnp.where(resolved > 0.0, scattered, vals)
        e = jnp.where(resolved > 0.0, 0.0, e)
        return vals, e

    return round_body


def _decode_kernel(H_ref, vals_ref, erased_ref, out_vals_ref, out_erased_ref,
                   *, iters: int):
    round_body = _flood_round(H_ref[...])  # H resident across all rounds
    vals, e = jax.lax.fori_loop(
        0, iters, lambda _, c: round_body(*c), (vals_ref[...], erased_ref[...])
    )
    out_vals_ref[...] = vals
    out_erased_ref[...] = e


@functools.partial(jax.jit, static_argnames=("iters", "bv", "interpret"))
def decode_fused(H: jax.Array, values: jax.Array, erased_f: jax.Array, *,
                 iters: int, bv: int = 128, interpret: bool | None = None):
    """Whole fixed-``iters`` decode in one ``pallas_call``.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (N, V) f32 with V % bv == 0; erased_f (N, 1) f32.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (N, V) f32, erased (N, 1) f32) after ``iters`` rounds.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda j: (0, 0)),  # H: resident, reused over j
            pl.BlockSpec((N, bv), lambda j: (0, j)),  # payload slice
            pl.BlockSpec((N, 1), lambda j: (0, 0)),   # initial erasure mask
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            # every grid step recomputes the identical erasure trajectory and
            # rewrites the same block — benign (sequential grid on TPU).
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# --------------------------------------------------- batched fused decode --


def _decode_batch_kernel(H_ref, vals_ref, erased_ref, out_vals_ref,
                         out_erased_ref, *, iters: int):
    round_body = _flood_round(H_ref[...])  # H shared across the whole batch
    vals, e = jax.lax.fori_loop(
        0, iters, lambda _, c: round_body(*c),
        (vals_ref[0], erased_ref[0])  # drop the leading (1,) batch-block dim
    )
    out_vals_ref[0] = vals
    out_erased_ref[0] = e


@functools.partial(jax.jit, static_argnames=("iters", "bv", "interpret"))
def decode_fused_batch(H: jax.Array, values: jax.Array, erased_f: jax.Array,
                       *, iters: int, bv: int = 128,
                       interpret: bool | None = None):
    """``B`` independent erasure patterns, one ``pallas_call``.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (B, N, V) f32 with V % bv == 0; erased_f (B, N, 1)
    f32.  The grid is ``(B, V // bv)``; the H block's index map is constant,
    so H is fetched into VMEM once and stays resident while each query's
    payload/mask tiles stream through — the per-query marginal cost is the
    decode arithmetic alone, not a kernel launch + H reload.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (B, N, V) f32, erased (B, N, 1) f32).
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_batch_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda b, j: (0, 0)),      # H: resident
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            # grid steps sharing a batch index recompute the identical
            # trajectory and rewrite the same block — benign (sequential
            # grid on TPU).
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# -------------------------------------------------- adaptive fused decode --


def _decode_adaptive_kernel(H_ref, vals_ref, erased_ref, out_vals_ref,
                            out_erased_ref, out_rounds_ref, *, max_iters: int):
    round_body = _flood_round(H_ref[...])

    def cond(carry):
        _, e, d, progressed = carry
        return (d < max_iters) & progressed & (jnp.max(e) > 0.0)

    def body(carry):
        vals, e, d, _ = carry
        vals2, e2 = round_body(vals, e)
        return vals2, e2, d + 1, jnp.any(e2 != e)

    vals, e, d, _ = jax.lax.while_loop(
        cond, body,
        (vals_ref[...], erased_ref[...], jnp.int32(0), jnp.bool_(True)),
    )
    out_vals_ref[...] = vals
    out_erased_ref[...] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_iters", "bv", "interpret"))
def decode_fused_adaptive(H: jax.Array, values: jax.Array,
                          erased_f: jax.Array, *, max_iters: int,
                          bv: int = 128, interpret: bool | None = None):
    """Early-exit decode in one launch: in-kernel ``while_loop`` that stops
    as soon as a round makes no progress (or nothing is erased), exactly the
    ``peel_decode_adaptive`` stopping rule — "decoding effort tracks the
    number of stragglers" without leaving the kernel.

    Inputs (already padded by ops.py) as for :func:`decode_fused`.  Returns
    (values (N, V) f32, erased (N, 1) f32, rounds (1, 1) i32).  The erasure
    trajectory depends only on H and the initial mask, so every payload
    slice exits after the identical round count and the shared rounds output
    is written consistently by each grid step.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_adaptive_kernel, max_iters=max_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda j: (0, 0)),  # H: resident
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# ------------------------------------- per-slot adaptive batched decode --


def _decode_batch_adaptive_kernel(H_ref, vals_ref, erased_ref, budget_ref,
                                  out_vals_ref, out_erased_ref,
                                  out_rounds_ref):
    round_body = _flood_round(H_ref[...])  # H shared across the whole batch
    budget = budget_ref[0, 0]  # THIS slot's round budget

    def cond(carry):
        _, e, d, progressed = carry
        return (d < budget) & progressed & (jnp.max(e) > 0.0)

    def body(carry):
        vals, e, d, _ = carry
        vals2, e2 = round_body(vals, e)
        return vals2, e2, d + 1, jnp.any(e2 != e)

    vals, e, d, _ = jax.lax.while_loop(
        cond, body,
        (vals_ref[0], erased_ref[0], jnp.int32(0), jnp.bool_(True)),
    )
    out_vals_ref[0] = vals
    out_erased_ref[0] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def decode_fused_batch_adaptive(H: jax.Array, values: jax.Array,
                                erased_f: jax.Array, budgets: jax.Array, *,
                                bv: int = 128, interpret: bool | None = None):
    """Per-slot adaptive decode of ``B`` independent patterns, ONE launch.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (B, N, V) f32 with V % bv == 0; erased_f (B, N, 1)
    f32; budgets (B, 1) int32 — each slot's round budget.  The grid is
    ``(B, V // bv)`` with the H block's index map constant, so H is fetched
    into VMEM once and stays resident across the whole batch while per-slot
    payload/mask/budget tiles stream through.  Each grid step runs its own
    ``while_loop`` with the slot's convergence predicate (progress made AND
    erasures remain AND slot budget left) — converged slots exit after the
    exact round count ``peel_decode_adaptive`` would use, independent of the
    other slots.  The round budget is a TRACED operand, so serving layers
    can vary per-slot budgets launch-to-launch without recompiling.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (B, N, V) f32, erased (B, N, 1) f32, rounds (B, 1) i32).
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    grid = (B, V // bv)
    return pl.pallas_call(
        _decode_batch_adaptive_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda b, j: (0, 0)),      # H: resident
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),      # slot budget
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            # grid steps sharing a batch index recompute the identical
            # trajectory (it depends only on H, the mask, and the budget)
            # and rewrite the same block — benign (sequential grid on TPU).
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(H, values, erased_f, budgets)
