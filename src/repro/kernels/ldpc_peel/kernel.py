"""LDPC peeling-decoder Pallas TPU kernels.

Kernel families (all built from ONE shared flooding-round implementation —
see :func:`_check_tile_proposal` / :func:`_resident_round` /
:func:`_streamed_round` and the two loop drivers :func:`_fixed_loop` /
:func:`_adaptive_loop`):

* :func:`check_pass` — the fused check-node pass of ONE flooding round
  (kept as the building block for the per-round path and its tests);
* resident-H fused decodes — the whole decode in ONE ``pallas_call`` with
  the ``(p, N)`` H tile loaded into VMEM once and kept resident:
  :func:`decode_fused` (fixed-``D``), :func:`decode_fused_batch` (``B``
  independent erasure patterns, grid over the batch, H shared),
  :func:`decode_fused_adaptive` (early-exit in-kernel ``while_loop``), and
  :func:`decode_fused_batch_adaptive` (per-slot ``while_loop`` with a
  TRACED per-slot round budget).  These are the fast path while the
  kernel's whole working set fits in VMEM (see
  ``core/decoder.vmem_bytes_estimate``).
* check-axis-TILED fused decodes — the same four variants with H living in
  HBM (``memory_space=ANY``) and streamed tile-by-tile over the CHECK axis
  through a double-buffered VMEM scratch (``(2, bp, N)`` slots + DMA
  semaphores), while the ``(N, bv)`` value carry stays in VMEM as the loop
  carry: :func:`decode_fused_tiled`, :func:`decode_fused_batch_tiled`,
  :func:`decode_fused_adaptive_tiled`,
  :func:`decode_fused_batch_adaptive_tiled`.  This removes the
  whole-H-in-VMEM cap (N ≲ 2048 f32) — problem size is bounded by HBM, not
  one core's VMEM; the VMEM cost is ``2·bp·N`` stream slots plus the value
  carry, independent of ``p``.

The in-kernel "scatter" is expressed MXU-style: the per-check resolution
one-hot ``(bp, N)`` is transposed into a matmul that accumulates each
resolved coordinate's new value — TPUs have no efficient in-kernel scatter,
but a ``(N, bp) @ (bp, BV)`` dot is native.  Checks that resolve the same
coordinate in the same round write consistent values (they are parity checks
of one codeword); the kernel deterministically keeps the lowest-index
check's value.  The tiled round preserves that rule exactly: tiles are
processed in ascending check order and a coordinate takes the FIRST tile's
resolution (within a tile, the lowest row — so the merge winner is the
globally lowest check row, the same check the resident merge picks), and
every tile's proposal is computed against the ROUND-START state, so the
tiled schedule is still flooding, not layered.  Erasure trajectories are
therefore bit-identical across resident/tiled; values agree to f32
summation order (XLA may block a tile-shaped row-sum reduction differently
than the whole-H one).

TPU notes:
  * matmul dims padded to multiples of 128 (MXU), f32 accumulation;
  * pos is computed with broadcasted_iota + max (no 1-D iota on TPU);
  * 1-D per-check outputs are materialized as (BP, 1) tiles (TPU wants >=2D);
  * resident grids re-map the same H block at every step, so H is fetched
    once and stays resident; the erasure trajectory depends only on H and
    the initial mask, so grid steps sharing a pattern recompute the
    identical trajectory and rewrite shared outputs consistently
    (benign — the grid is sequential on TPU);
  * tiled kernels stream H with ``pltpu.make_async_copy``: tile ``j+1``'s
    DMA is started before waiting on tile ``j`` (double buffering), and the
    pipeline runs on a GLOBAL tile counter so tile 0 of round ``t+1`` is
    prefetched during the LAST tile of round ``t`` (cross-round prefetch —
    the double buffer never resets at a round boundary); ``bp``/``bv``
    tuning on real TPUs is the recorded follow-on (ROADMAP);
  * off-TPU everything runs in interpret mode (correct but not fast),
    including the DMA pipeline.

SEEDED kernels (``decode_seeded*``): the same four decode contracts with
NO H operand at all — each ``bp x N`` tile is regenerated in-register
inside the round from the code's counter-based seed
(:class:`repro.core.ldpc.SeededStructure`, passed as a STATIC argument so
the per-layer affine constants compile into the kernel).  The jnp tile
generator :func:`seeded_h_tile` is bit-exact against the NumPy reference
``repro.core.ldpc.seeded_h_rows`` — every step is 32-bit integer
arithmetic or exact-in-f32 float math — so seeded trajectories are
bit-identical to every materialized backend on the same code, while the
operand traffic for H drops to zero bytes.

Each seeded kernel takes a static ``mode`` selecting HOW the round is
computed (the trajectory is identical either way):

* ``mode="dense_tile"`` (default) — regenerate the dense ``(bp, N)`` tile
  and reuse the tiled round's MXU matmuls on it: O(p·N) FLOPs per round.
* ``mode="gather"`` — never build the tile.  The check pass generates only
  the ``r`` (column, weight) pairs per check row from the seed and computes
  cnt/pos/coeff/sums as ``r`` gathers + a static segment-sum
  (:func:`_seeded_gather_round`); the variable pass inverts the layered
  affine permutations (a per-layer modular inverse, compiled in) so each
  column finds its ``l`` candidate check rows by direct index arithmetic —
  no scatter, no one-hot.  O(p·r + N·l·p/bp) FLOPs per round, an ~N/r
  compute win over the dense tile.  All solvability quantities are
  integer-exact, and the first-match/first-tile-wins merges reproduce the
  lowest-check-row tie-break, so gather-mode ERASURE TRAJECTORIES are
  bit-identical to dense-tile (and hence to every materialized backend);
  VALUES agree to f32 summation order (r-term draw-order sums vs tile dot
  reductions), the same caveat that already distinguishes resident from
  tiled.  The gathers are expressed as jnp ``take``s — exact in interpret
  mode everywhere; tuning their lowering on real TPU rides the ROADMAP
  item 5 profiling pass.

:func:`encode_seeded_fused` is the encode-side twin: one ``pallas_call``
that regenerates seeded-LDGM GENERATOR rows in-register (systematic +
sorted parity draws, an odd-even transposition network standing in for the
host-side argsort) and applies them to the payload as a sequential
gather-FMA — bit-identical to ``repro.core.encoding.gather_encode`` over
``seeded_generator_rows`` tables, with zero table operand traffic.  The
row offset is a TRACED scalar so sharded workers can encode their row
slice under ``shard_map`` without per-shard recompilation.

:func:`decode_replay` is the pattern-compiled fast path: it takes a PACKED
:class:`repro.core.decoder.PeelSchedule` (per-round sentinel-padded entry
segments) and applies the whole pre-solved elimination order in ONE
``pallas_call`` — no flooding loop, no convergence mask, no H operand;
work is O(schedule entries · r_max), i.e. proportional to the resolved
edges, not rounds × p·r.  Its edge-sum duplicates the decoder's
scan-boundary compensated chain (:func:`_replay_edge_sum`), so replayed
values are bit-identical to the ``backend="replay"`` executors and hence
to the sparse flooding decode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["check_pass", "decode_fused", "decode_fused_batch",
           "decode_fused_adaptive", "decode_fused_batch_adaptive",
           "decode_fused_tiled", "decode_fused_batch_tiled",
           "decode_fused_adaptive_tiled", "decode_fused_batch_adaptive_tiled",
           "decode_seeded", "decode_seeded_batch", "decode_seeded_adaptive",
           "decode_seeded_batch_adaptive", "seeded_h_tile",
           "encode_seeded_fused", "decode_replay", "detect_interpret"]

SEEDED_MODES = ("dense_tile", "gather")

_HIGH = jax.lax.Precision.HIGHEST


def detect_interpret(interpret: bool | None) -> bool:
    """Pallas runs compiled only on TPU; anywhere else use interpret mode."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def _check_kernel(H_ref, vals_ref, erased_ref, sums_ref, cnt_ref, pos_ref,
                  coeff_ref):
    H = H_ref[...]  # (BP, N) f32
    e = erased_ref[...][:, 0]  # (N,) f32: 1.0 = erased
    Hb = (H != 0.0).astype(jnp.float32)

    cnt = jax.lax.dot(Hb, e[:, None], precision=_HIGH)  # (BP,1)
    known = vals_ref[...] * (1.0 - e)[:, None]  # (N, BV)
    sums = jax.lax.dot(H, known, precision=_HIGH)  # (BP,BV)

    # erased-neighbour index per row: max over iota masked to erased edges
    idx = jax.lax.broadcasted_iota(jnp.int32, H.shape, 1)
    mask = (Hb * e[None, :]) > 0.0
    pos = jnp.max(jnp.where(mask, idx, -1), axis=1)  # (BP,)
    onehot = (idx == pos[:, None]).astype(jnp.float32)
    coeff = jnp.sum(H * onehot, axis=1)  # (BP,)

    sums_ref[...] = sums
    cnt_ref[...] = cnt
    pos_ref[...] = pos[:, None]
    coeff_ref[...] = coeff[:, None]


@functools.partial(jax.jit, static_argnames=("bp", "bv", "interpret"))
def check_pass(H: jax.Array, values: jax.Array, erased_f: jax.Array, *,
               bp: int = 128, bv: int = 128, interpret: bool | None = None):
    """Inputs (already padded by ops.py): H (p, N) f32, values (N, V) f32,
    erased_f (N, 1) f32.  p % bp == 0, V % bv == 0, N % 128 == 0.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (sums (p, V), cnt (p, 1), pos (p, 1) i32, coeff (p, 1))."""
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (p // bp, V // bv)
    return pl.pallas_call(
        _check_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, N), lambda i, j: (i, 0)),   # H tile: reused over j
            pl.BlockSpec((N, bv), lambda i, j: (0, j)),   # payload tile
            pl.BlockSpec((N, 1), lambda i, j: (0, 0)),    # erasure mask
        ],
        out_specs=[
            pl.BlockSpec((bp, bv), lambda i, j: (i, j)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, V), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
            jax.ShapeDtypeStruct((p, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# ------------------------------------------------- shared flooding round --


def _check_tile_proposal(H, known, e):
    """One check tile's resolution proposal against the ROUND-START state.

    ``H (bp, N)`` is a tile of check rows; ``known (N, BV) = vals·(1-e)``
    and ``e (N, 1)`` are the round-start known values / erasure mask.
    Returns ``(resolved (N, 1) ∈ {0, 1}, scattered (N, BV))``: which
    coordinates THIS tile resolves and the values it writes, with the
    lowest row in the tile winning intra-tile ties.  This is the ONE
    implementation of the flooding-round check/variable math — every fused
    kernel (resident or tiled, fixed or adaptive, batched or not) builds
    its round from it, so all variants follow the identical erasure
    trajectory (same solvability decisions, same resolved neighbour, same
    lowest-index-check tie-break).
    """
    Hb = (H != 0.0).astype(jnp.float32)
    col = jax.lax.broadcasted_iota(jnp.int32, H.shape, 1)  # (bp, N)
    row = jax.lax.broadcasted_iota(jnp.int32, H.shape, 0)  # (bp, N)
    cnt = jax.lax.dot(Hb, e, precision=_HIGH)  # (bp, 1)
    solvable = cnt[:, 0] == 1.0  # (bp,)
    sums = jax.lax.dot(H, known, precision=_HIGH)  # (bp, BV)
    emask = (Hb * e[:, 0][None, :]) > 0.0
    pos = jnp.max(jnp.where(emask, col, -1), axis=1)  # (bp,)
    onehot = (col == pos[:, None]) & solvable[:, None]  # (bp, N) bool
    coeff = jnp.sum(H * onehot.astype(jnp.float32), axis=1)  # (bp,)
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    # Several checks may resolve the same coordinate; keep the
    # lowest-index check's (consistent) value deterministically.
    winner_row = jnp.min(jnp.where(onehot, row, H.shape[0]), axis=0)  # (N,)
    winner = (onehot & (row == winner_row[None, :])).astype(jnp.float32)
    resolved = jnp.max(winner, axis=0)[:, None]  # (N, 1) ∈ {0, 1}
    scattered = jax.lax.dot(winner.T, new_val, precision=_HIGH)  # (N, BV)
    return resolved, scattered


def _apply_round(vals, e, resolved, scattered):
    vals = jnp.where(resolved > 0.0, scattered, vals)
    e = jnp.where(resolved > 0.0, 0.0, e)
    return vals, e


def _resident_round(H):
    """Round function for a whole-H-in-VMEM tile (the resident kernels)."""
    def round_body(vals, e, t):
        del t                              # no streaming state to rotate
        known = vals * (1.0 - e)
        return _apply_round(vals, e, *_check_tile_proposal(H, known, e))

    return round_body


def _streamed_round(h_hbm, h_scratch, sem, *, bp: int):
    """Round function streaming H over check tiles from HBM.

    ``h_hbm`` is the full ``(p, N)`` ref left in HBM (``memory_space=ANY``,
    ``p % bp == 0``); ``h_scratch (2, bp, N)`` and ``sem (2,)`` are the
    double-buffered VMEM stream slots.  The pipeline runs on a GLOBAL tile
    counter ``g = round * n_tiles + j``: slot ``g % 2``, tile ``g %
    n_tiles``.  Tile ``g+1``'s DMA is started before waiting on tile ``g``
    — unconditionally, so during round ``t``'s LAST tile the prefetch
    lands on tile 0 of round ``t+1``: the double buffer never resets at a
    round boundary and the first tile of every round (after the first) is
    already in flight when the round starts.  Every tile's proposal is
    still computed against the round-start ``(vals, e)`` and merged
    first-tile-wins (tiles ascend the check axis, so the winner is the
    globally lowest check row — bit-identical to the resident merge).

    Returns ``(round_body(vals, e, t), prime, drain)``: callers start the
    pipeline with ``prime()`` before the decode loop and consume the one
    always-in-flight prefetch with ``drain(rounds_done)`` after it (the
    loop exits with tile 0 of round ``rounds_done`` outstanding — also
    true for 0 rounds, where the primed first DMA is the outstanding one).
    """
    n_tiles = h_hbm.shape[0] // bp

    def get_dma(g):
        return pltpu.make_async_copy(
            h_hbm.at[pl.ds((g % n_tiles) * bp, bp), :],
            h_scratch.at[g % 2], sem.at[g % 2])

    def prime():
        get_dma(0).start()

    def drain(rounds_done):
        get_dma(rounds_done * n_tiles).wait()

    def round_body(vals, e, t):
        known = vals * (1.0 - e)
        base = t * n_tiles

        def tile_step(j, carry):
            resolved, scattered = carry
            g = base + j
            get_dma(g + 1).start()         # j == n_tiles-1: next ROUND's tile 0
            get_dma(g).wait()
            t_res, t_scat = _check_tile_proposal(h_scratch[g % 2], known, e)
            take = (t_res > 0.0) & (resolved <= 0.0)
            return (jnp.maximum(resolved, t_res),
                    jnp.where(take, t_scat, scattered))

        resolved, scattered = jax.lax.fori_loop(
            0, n_tiles, tile_step, (jnp.zeros_like(e), jnp.zeros_like(vals)))
        return _apply_round(vals, e, resolved, scattered)

    return round_body, prime, drain


def _fixed_loop(round_body, vals, e, iters: int):
    """Exactly ``iters`` flooding rounds (the paper's fixed-D decode).
    The round index is passed through so streamed rounds can keep their
    cross-round DMA pipeline position."""
    return jax.lax.fori_loop(0, iters, lambda t, c: round_body(*c, t),
                             (vals, e))


def _adaptive_loop(round_body, vals, e, budget):
    """Early-exit rounds: stop when a round makes no progress, nothing is
    erased, or ``budget`` rounds have run (``budget`` may be traced — the
    per-slot round budgets of the batched-adaptive kernels never
    recompile).  Returns ``(vals, e, rounds_used)``."""
    def cond(carry):
        _, e_, d, progressed = carry
        return (d < budget) & progressed & (jnp.max(e_) > 0.0)

    def body(carry):
        vals_, e_, d, _ = carry
        vals2, e2 = round_body(vals_, e_, d)
        return vals2, e2, d + 1, jnp.any(e2 != e_)

    vals, e, d, _ = jax.lax.while_loop(
        cond, body, (vals, e, jnp.int32(0), jnp.bool_(True)))
    return vals, e, d


# ------------------------------------------------------------ fused decode --


def _decode_kernel(H_ref, vals_ref, erased_ref, out_vals_ref, out_erased_ref,
                   *, iters: int):
    round_body = _resident_round(H_ref[...])  # H resident across all rounds
    vals, e = _fixed_loop(round_body, vals_ref[...], erased_ref[...], iters)
    out_vals_ref[...] = vals
    out_erased_ref[...] = e


@functools.partial(jax.jit, static_argnames=("iters", "bv", "interpret"))
def decode_fused(H: jax.Array, values: jax.Array, erased_f: jax.Array, *,
                 iters: int, bv: int = 128, interpret: bool | None = None):
    """Whole fixed-``iters`` decode in one ``pallas_call``.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (N, V) f32 with V % bv == 0; erased_f (N, 1) f32.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (N, V) f32, erased (N, 1) f32) after ``iters`` rounds.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda j: (0, 0)),  # H: resident, reused over j
            pl.BlockSpec((N, bv), lambda j: (0, j)),  # payload slice
            pl.BlockSpec((N, 1), lambda j: (0, 0)),   # initial erasure mask
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            # every grid step recomputes the identical erasure trajectory and
            # rewrites the same block — benign (sequential grid on TPU).
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# --------------------------------------------------- batched fused decode --


def _decode_batch_kernel(H_ref, vals_ref, erased_ref, out_vals_ref,
                         out_erased_ref, *, iters: int):
    round_body = _resident_round(H_ref[...])  # H shared across the whole batch
    vals, e = _fixed_loop(round_body, vals_ref[0], erased_ref[0], iters)
    out_vals_ref[0] = vals
    out_erased_ref[0] = e


@functools.partial(jax.jit, static_argnames=("iters", "bv", "interpret"))
def decode_fused_batch(H: jax.Array, values: jax.Array, erased_f: jax.Array,
                       *, iters: int, bv: int = 128,
                       interpret: bool | None = None):
    """``B`` independent erasure patterns, one ``pallas_call``.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (B, N, V) f32 with V % bv == 0; erased_f (B, N, 1)
    f32.  The grid is ``(B, V // bv)``; the H block's index map is constant,
    so H is fetched into VMEM once and stays resident while each query's
    payload/mask tiles stream through — the per-query marginal cost is the
    decode arithmetic alone, not a kernel launch + H reload.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (B, N, V) f32, erased (B, N, 1) f32).
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_batch_kernel, iters=iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda b, j: (0, 0)),      # H: resident
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            # grid steps sharing a batch index recompute the identical
            # trajectory and rewrite the same block — benign (sequential
            # grid on TPU).
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# -------------------------------------------------- adaptive fused decode --


def _decode_adaptive_kernel(H_ref, vals_ref, erased_ref, out_vals_ref,
                            out_erased_ref, out_rounds_ref, *, max_iters: int):
    round_body = _resident_round(H_ref[...])
    vals, e, d = _adaptive_loop(round_body, vals_ref[...], erased_ref[...],
                                max_iters)
    out_vals_ref[...] = vals
    out_erased_ref[...] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("max_iters", "bv", "interpret"))
def decode_fused_adaptive(H: jax.Array, values: jax.Array,
                          erased_f: jax.Array, *, max_iters: int,
                          bv: int = 128, interpret: bool | None = None):
    """Early-exit decode in one launch: in-kernel ``while_loop`` that stops
    as soon as a round makes no progress (or nothing is erased), exactly the
    ``peel_decode_adaptive`` stopping rule — "decoding effort tracks the
    number of stragglers" without leaving the kernel.

    Inputs (already padded by ops.py) as for :func:`decode_fused`.  Returns
    (values (N, V) f32, erased (N, 1) f32, rounds (1, 1) i32).  The erasure
    trajectory depends only on H and the initial mask, so every payload
    slice exits after the identical round count and the shared rounds output
    is written consistently by each grid step.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_adaptive_kernel, max_iters=max_iters),
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda j: (0, 0)),  # H: resident
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(H, values, erased_f)


# ------------------------------------- per-slot adaptive batched decode --


def _decode_batch_adaptive_kernel(H_ref, vals_ref, erased_ref, budget_ref,
                                  out_vals_ref, out_erased_ref,
                                  out_rounds_ref):
    round_body = _resident_round(H_ref[...])  # H shared across the whole batch
    vals, e, d = _adaptive_loop(round_body, vals_ref[0], erased_ref[0],
                                budget_ref[0, 0])  # THIS slot's round budget
    out_vals_ref[0] = vals
    out_erased_ref[0] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("bv", "interpret"))
def decode_fused_batch_adaptive(H: jax.Array, values: jax.Array,
                                erased_f: jax.Array, budgets: jax.Array, *,
                                bv: int = 128, interpret: bool | None = None):
    """Per-slot adaptive decode of ``B`` independent patterns, ONE launch.

    Inputs (already padded by ops.py): H (p, N) f32 with p % 8 == 0 and
    N % 128 == 0; values (B, N, V) f32 with V % bv == 0; erased_f (B, N, 1)
    f32; budgets (B, 1) int32 — each slot's round budget.  The grid is
    ``(B, V // bv)`` with the H block's index map constant, so H is fetched
    into VMEM once and stays resident across the whole batch while per-slot
    payload/mask/budget tiles stream through.  Each grid step runs its own
    ``while_loop`` with the slot's convergence predicate (progress made AND
    erasures remain AND slot budget left) — converged slots exit after the
    exact round count ``peel_decode_adaptive`` would use, independent of the
    other slots.  The round budget is a TRACED operand, so serving layers
    can vary per-slot budgets launch-to-launch without recompiling.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).

    Returns (values (B, N, V) f32, erased (B, N, 1) f32, rounds (B, 1) i32).
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    grid = (B, V // bv)
    return pl.pallas_call(
        _decode_batch_adaptive_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((p, N), lambda b, j: (0, 0)),      # H: resident
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),      # slot budget
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            # grid steps sharing a batch index recompute the identical
            # trajectory (it depends only on H, the mask, and the budget)
            # and rewrite the same block — benign (sequential grid on TPU).
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(H, values, erased_f, budgets)


# ---------------------------------------------- check-axis-tiled decodes --
#
# Same contracts as the resident kernels, with H left in HBM (p % bp == 0
# enforced by ops.py) and streamed through the double-buffered scratch.
# One scratch/semaphore signature shared by all four.


def _tiled_scratch(bp: int, N: int):
    return [pltpu.VMEM((2, bp, N), jnp.float32),
            pltpu.SemaphoreType.DMA((2,))]


def _check_tiled_operands(p: int, N: int, V: int, bp: int, bv: int) -> None:
    """The tile loops FLOOR-divide (``p // bp``, ``V // bv``), so unpadded
    operands would silently drop trailing check rows / payload columns —
    fail loudly instead (the ops.py wrappers pad before calling)."""
    if p % bp or N % 128 or V % bv:
        raise ValueError(
            "tiled decode operands must be pre-padded (ops.py wrappers do "
            f"this): need p % bp == 0, N % 128 == 0, V % bv == 0; got "
            f"p={p} bp={bp}, N={N}, V={V} bv={bv}")


def _decode_tiled_kernel(H_hbm, vals_ref, erased_ref, out_vals_ref,
                         out_erased_ref, h_scratch, sem, *, iters: int,
                         bp: int):
    round_body, prime, drain = _streamed_round(H_hbm, h_scratch, sem, bp=bp)
    prime()
    vals, e = _fixed_loop(round_body, vals_ref[...], erased_ref[...], iters)
    drain(jnp.int32(iters))
    out_vals_ref[...] = vals
    out_erased_ref[...] = e


@functools.partial(jax.jit, static_argnames=("iters", "bp", "bv", "interpret"))
def decode_fused_tiled(H: jax.Array, values: jax.Array, erased_f: jax.Array,
                       *, iters: int, bp: int = 128, bv: int = 128,
                       interpret: bool | None = None):
    """Fixed-``iters`` decode with H STREAMED over check tiles.

    Inputs (already padded by ops.py): H (p, N) f32 with p % bp == 0 and
    N % 128 == 0; values (N, V) f32 with V % bv == 0; erased_f (N, 1) f32.
    Same trajectory and output contract as :func:`decode_fused`; the VMEM
    working set is ``2·bp·N`` stream slots + the ``(N, bv)`` carry instead
    of the whole ``(p, N)`` H — this is the variant ``backend="auto"``
    routes to when ``core/decoder.vmem_bytes_estimate`` says the resident
    kernel will not fit.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    _check_tiled_operands(p, N, V, bp, bv)
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_tiled_kernel, iters=iters, bp=bp),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # H: stays in HBM
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        scratch_shapes=_tiled_scratch(bp, N),
        interpret=interpret,
    )(H, values, erased_f)


def _decode_batch_tiled_kernel(H_hbm, vals_ref, erased_ref, out_vals_ref,
                               out_erased_ref, h_scratch, sem, *, iters: int,
                               bp: int):
    round_body, prime, drain = _streamed_round(H_hbm, h_scratch, sem, bp=bp)
    prime()
    vals, e = _fixed_loop(round_body, vals_ref[0], erased_ref[0], iters)
    drain(jnp.int32(iters))
    out_vals_ref[0] = vals
    out_erased_ref[0] = e


@functools.partial(jax.jit, static_argnames=("iters", "bp", "bv", "interpret"))
def decode_fused_batch_tiled(H: jax.Array, values: jax.Array,
                             erased_f: jax.Array, *, iters: int,
                             bp: int = 128, bv: int = 128,
                             interpret: bool | None = None):
    """``B`` independent patterns with H streamed over check tiles.

    Same contract as :func:`decode_fused_batch` (values (B, N, V), erased_f
    (B, N, 1), both padded); the grid runs over ``(B, V // bv)`` and every
    grid step re-streams the H tiles from HBM while its slot's payload/mask
    tiles live in VMEM.  (On the batch axis the resident kernel amortizes
    the H fetch across slots; the tiled kernel instead bounds VMEM by
    ``2·bp·N`` — the trade recorded in the README matrix.)
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    _check_tiled_operands(p, N, V, bp, bv)
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_batch_tiled_kernel, iters=iters, bp=bp),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # H: stays in HBM
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
        ],
        scratch_shapes=_tiled_scratch(bp, N),
        interpret=interpret,
    )(H, values, erased_f)


def _decode_adaptive_tiled_kernel(H_hbm, vals_ref, erased_ref, out_vals_ref,
                                  out_erased_ref, out_rounds_ref, h_scratch,
                                  sem, *, max_iters: int, bp: int):
    round_body, prime, drain = _streamed_round(H_hbm, h_scratch, sem, bp=bp)
    prime()
    vals, e, d = _adaptive_loop(round_body, vals_ref[...], erased_ref[...],
                                max_iters)
    drain(d)
    out_vals_ref[...] = vals
    out_erased_ref[...] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("max_iters", "bp", "bv", "interpret"))
def decode_fused_adaptive_tiled(H: jax.Array, values: jax.Array,
                                erased_f: jax.Array, *, max_iters: int,
                                bp: int = 128, bv: int = 128,
                                interpret: bool | None = None):
    """Early-exit decode with H streamed over check tiles.

    Same stopping rule, trajectory, and output contract as
    :func:`decode_fused_adaptive` (values (N, V), erased (N, 1),
    rounds (1, 1)); the in-kernel ``while_loop`` wraps the streamed round,
    so an early exit also stops the H streaming — decode bandwidth tracks
    the realized straggler load, not the worst case.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    V = values.shape[1]
    _check_tiled_operands(p, N, V, bp, bv)
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_adaptive_tiled_kernel, max_iters=max_iters,
                          bp=bp),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # H: stays in HBM
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        scratch_shapes=_tiled_scratch(bp, N),
        interpret=interpret,
    )(H, values, erased_f)


def _decode_batch_adaptive_tiled_kernel(H_hbm, vals_ref, erased_ref,
                                        budget_ref, out_vals_ref,
                                        out_erased_ref, out_rounds_ref,
                                        h_scratch, sem, *, bp: int):
    round_body, prime, drain = _streamed_round(H_hbm, h_scratch, sem, bp=bp)
    prime()
    vals, e, d = _adaptive_loop(round_body, vals_ref[0], erased_ref[0],
                                budget_ref[0, 0])  # THIS slot's round budget
    drain(d)
    out_vals_ref[0] = vals
    out_erased_ref[0] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("bp", "bv", "interpret"))
def decode_fused_batch_adaptive_tiled(H: jax.Array, values: jax.Array,
                                      erased_f: jax.Array,
                                      budgets: jax.Array, *, bp: int = 128,
                                      bv: int = 128,
                                      interpret: bool | None = None):
    """Per-slot adaptive decode of ``B`` patterns with H streamed per slot.

    Same contract as :func:`decode_fused_batch_adaptive` (budgets (B, 1)
    int32 stays a TRACED operand — varying per-slot budgets never
    recompile); each grid step runs its own streamed ``while_loop``, so a
    light slot stops both its compute AND its H streaming after 1-2 rounds.
    """
    interpret = detect_interpret(interpret)
    p, N = H.shape
    B, _, V = values.shape
    _check_tiled_operands(p, N, V, bp, bv)
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_batch_adaptive_tiled_kernel, bp=bp),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # H: stays in HBM
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),      # slot budget
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        scratch_shapes=_tiled_scratch(bp, N),
        interpret=interpret,
    )(H, values, erased_f, budgets)


# --------------------------------------------------- seeded tiled decodes --
#
# The same four contracts with the DMA'd H scratch replaced by in-register
# tile GENERATION: no H operand, no stream slots, no semaphores — the only
# HBM traffic is the (N, bv) payload carry and masks.  The structure spec
# (repro.core.ldpc.SeededStructure — plain ints/tuples, hashable) is a
# STATIC argument, so the per-layer affine constants are compiled into the
# kernel and tile regeneration is pure VPU arithmetic on iotas.


def _mix32_jnp(x):
    """jnp twin of ``repro.core.ldpc._mix32`` (lowbias32 avalanche).

    uint32 in, uint32 out; multiplication wraps mod 2^32 and ``>>`` on an
    unsigned dtype is a logical shift, so every intermediate matches the
    NumPy reference bit for bit.
    """
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _seeded_row_params(spec, rows):
    """Per-row layer constants of global check rows ``rows`` (any shape).

    Returns ``(t, a, b, jl)``: layer index, affine stride/offset (selected
    by a static unroll over the — small — layer count, so ``spec`` stays
    compiled-in), and the within-layer row.  Rows outside ``[0,
    spec.rows)`` get ``a == b == 0`` (no layer matches), so their column
    draws land on 0 and callers mask them with a ``rows < spec.rows``
    validity test, exactly like the dense generator's zero rows.
    """
    t = rows // spec.rows_per_layer
    a = jnp.zeros(rows.shape, jnp.int32)
    b = jnp.zeros(rows.shape, jnp.int32)
    for tt in range(spec.layers):          # static unroll: layers == l (small)
        a = jnp.where(t == tt, jnp.int32(spec.strides[tt]), a)
        b = jnp.where(t == tt, jnp.int32(spec.offsets[tt]), b)
    jl = rows - t * spec.rows_per_layer
    return t, a, b, jl


def _seeded_edge_weight(spec, rows, s: int):
    """Edge weight of slot ``s`` on global check rows ``rows`` — the
    uint32-hash-to-exact-f32 map shared bit-for-bit with the NumPy
    reference (``repro.core.ldpc._structure_rows_raw``)."""
    edge = (rows * spec.row_weight + s).astype(jnp.uint32)
    u = _mix32_jnp(edge ^ jnp.uint32(spec.wseed))
    sign = 1.0 - 2.0 * (u & 1).astype(jnp.float32)
    m = (u >> 9).astype(jnp.int32).astype(jnp.float32)   # [0, 2^23)
    return sign * (1.0 + m * jnp.float32(2.0 ** -23))    # exact f32


def seeded_h_tile(spec, row0, bp: int, n_pad: int):
    """Regenerate the dense ``(bp, n_pad)`` H tile at check row ``row0``.

    Pure jnp — usable inside a Pallas kernel body or as a plain traced
    function (the bit-exactness tests call it directly).  Bit-exact against
    ``repro.core.ldpc.seeded_h_rows(spec, row0, row0 + bp)`` padded with
    zero columns to ``n_pad``: column draws are int32 affine arithmetic
    (``spec`` bounds the stride so ``a*x + b`` never overflows), edge
    weights are uint32 hash bits mapped through exact f32 steps.  Rows past
    ``spec.rows`` (check-axis padding) come out all-zero — never solvable,
    exactly like the zero-padded rows the materialized wrappers append.

    ``row0`` may be traced (the tile loop's ``j * bp``); ``bp``/``n_pad``
    are static.
    """
    rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (bp, 1), 0)  # global
    _, a, b, jl = _seeded_row_params(spec, rows)
    valid = (rows < spec.rows).astype(jnp.float32)      # (bp, 1) row mask
    col_iota = jax.lax.broadcasted_iota(jnp.int32, (bp, n_pad), 1)
    H = jnp.zeros((bp, n_pad), jnp.float32)
    for s in range(spec.row_weight):       # static unroll: r compares + FMAs
        x = jl * spec.row_weight + s
        col = (a * x + b) % spec.cols      # int32-safe by the stride bound
        w = _seeded_edge_weight(spec, rows, s)
        H = H + (col_iota == col).astype(jnp.float32) * (w * valid)
    return H


def _seeded_round(spec, *, bp: int, p_pad: int, n_pad: int):
    """Round function regenerating H tiles from the seed (no DMA at all).

    Mirrors :func:`_streamed_round`'s tile loop and first-tile-wins merge
    exactly — tiles ascend the check axis against the round-start state —
    so the seeded trajectory is bit-identical to the streamed/resident
    ones on the same code; the only difference is where the tile's floats
    come from.
    """
    n_tiles = p_pad // bp

    def round_body(vals, e, t):
        del t                              # no pipeline position to keep
        known = vals * (1.0 - e)

        def tile_step(j, carry):
            resolved, scattered = carry
            H_tile = seeded_h_tile(spec, j * bp, bp, n_pad)
            t_res, t_scat = _check_tile_proposal(H_tile, known, e)
            take = (t_res > 0.0) & (resolved <= 0.0)
            return (jnp.maximum(resolved, t_res),
                    jnp.where(take, t_scat, scattered))

        resolved, scattered = jax.lax.fori_loop(
            0, n_tiles, tile_step, (jnp.zeros_like(e), jnp.zeros_like(vals)))
        return _apply_round(vals, e, resolved, scattered)

    return round_body


def _mod_mul(m, mult: int, c: int):
    """``(mult * m) % c`` for traced int32 ``m`` in ``[0, c)`` with STATIC
    Python ints ``mult``/``c``, never overflowing int32.

    When the direct product fits, use it.  Otherwise split ``m = hi·2^k +
    lo`` and fold ``2^k`` into the multiplier on the host: each partial
    product is reduced mod ``c`` before the final add, so every
    intermediate stays under ``2^31``.  A ``k`` exists whenever
    ``c^3 < 2^62`` (far beyond any supported code length); otherwise the
    caller's code is too large for int32 index arithmetic and we say so.
    """
    mult %= c
    if mult * (c - 1) < 2**31:
        return (m * jnp.int32(mult)) % jnp.int32(c)
    for k in range(1, 31):
        if ((c - 1) * ((1 << k) - 1) < 2**31
                and (c - 1) * ((c - 1) >> k) < 2**31):
            mult_k = (mult << k) % c
            hi = m >> k
            lo = m & ((1 << k) - 1)
            t1 = (hi * jnp.int32(mult_k)) % jnp.int32(c)
            t2 = (lo * jnp.int32(mult)) % jnp.int32(c)
            return (t1 + t2) % jnp.int32(c)
    raise ValueError(
        f"cols={c} too large for int32 modular inverse arithmetic "
        f"(needs c^3 < 2^62); use seeded_mode='dense_tile'")


def _seeded_gather_round(spec, *, bp: int, p_pad: int, n_pad: int):
    """Edge-proportional round: gathers + segment-sums, NO dense tile.

    Check pass: for each check row in the tile, regenerate only its ``r``
    (column, weight) draws and accumulate cnt/pos/coeff/sums with ``r``
    payload gathers — cnt is an exact small-integer f32 sum, pos an int32
    sum that collapses to the single erased neighbour exactly when the row
    is solvable, coeff the single surviving weight (bit-equal to the dense
    tile's masked row-sum).  Variable pass: instead of a one-hot scatter
    matmul, invert the layered affine permutations (per-layer modular
    inverse, a compile-time Python ``pow``) so each column computes its one
    candidate check row per layer and gathers that row's proposal;
    candidates ascend in row index with the layer, so first-match-wins IS
    the lowest-row tie-break, and the cross-tile merge below is the same
    first-tile-wins carry as :func:`_seeded_round` — the erasure
    trajectory is bit-identical to the dense-tile mode.  Values agree to
    f32 summation order only (draw-order r-term sums here vs tile-dot
    reductions there).
    """
    n_tiles = p_pad // bp
    r = spec.row_weight
    # Modular inverses of the layer strides (exist: gcd(a_t, cols) == 1 by
    # construction) — Python ints, compiled into the kernel.
    inv = [pow(spec.strides[tt], -1, spec.cols) for tt in range(spec.layers)]

    def round_body(vals, e, t_round):
        del t_round                        # no pipeline position to keep
        known = vals * (1.0 - e)
        e_flat = e[:, 0]                                      # (n_pad,)
        col2 = jax.lax.broadcasted_iota(jnp.int32, (n_pad, 1), 0)

        def tile_step(j, carry):
            resolved, scattered = carry
            rows = j * bp + jax.lax.broadcasted_iota(jnp.int32, (bp, 1), 0)
            _, a, b, jl = _seeded_row_params(spec, rows)
            valid = rows < spec.rows                          # (bp, 1)
            cnt = jnp.zeros((bp, 1), jnp.float32)
            pos = jnp.zeros((bp, 1), jnp.int32)
            coeff = jnp.zeros((bp, 1), jnp.float32)
            sums = jnp.zeros((bp, known.shape[1]), jnp.float32)
            for s in range(r):             # static unroll: r gathers
                col_s = (a * (jl * r + s) + b) % spec.cols    # (bp, 1)
                w_s = (_seeded_edge_weight(spec, rows, s)
                       * valid.astype(jnp.float32))           # H entry
                eg = e_flat[col_s]                            # (bp, 1)
                cnt = cnt + eg             # exact: r << 2^24
                pos = pos + col_s * eg.astype(jnp.int32)
                coeff = coeff + w_s * eg
                sums = sums + w_s * known[col_s[:, 0]]        # (bp, BV)
            solvable = (cnt == 1.0) & valid
            new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)
            pos = jnp.where(solvable, pos, jnp.int32(-1))
            solvable_f = solvable.astype(jnp.float32)[:, 0]   # (bp,)
            pos_flat = pos[:, 0]

            # Variable pass: each column's candidate row in layer tt is
            # row tt·rpl + x//r with x = a_tt^{-1}·(col - b_tt) mod cols.
            t_res = jnp.zeros((n_pad, 1), jnp.float32)
            t_scat = jnp.zeros((n_pad, known.shape[1]), jnp.float32)
            for tt in range(spec.layers):  # static unroll, rows ascend in tt
                mm = (col2 - spec.offsets[tt]) % spec.cols
                x = _mod_mul(mm, inv[tt], spec.cols)
                row_g = tt * spec.rows_per_layer + x // r
                in_tile = row_g - j * bp
                idx = jnp.clip(in_tile, 0, bp - 1)            # (n_pad, 1)
                ok = (in_tile >= 0) & (in_tile < bp) & (col2 < spec.cols)
                sg = solvable_f[idx]
                pg = pos_flat[idx]
                nv = new_val[idx[:, 0]]                       # (n_pad, BV)
                hit = ok & (sg > 0.0) & (pg == col2)
                take = hit & (t_res <= 0.0)
                t_res = jnp.where(take, 1.0, t_res)
                t_scat = jnp.where(take, nv, t_scat)

            take = (t_res > 0.0) & (resolved <= 0.0)
            return (jnp.maximum(resolved, t_res),
                    jnp.where(take, t_scat, scattered))

        resolved, scattered = jax.lax.fori_loop(
            0, n_tiles, tile_step, (jnp.zeros_like(e), jnp.zeros_like(vals)))
        return _apply_round(vals, e, resolved, scattered)

    return round_body


def _seeded_round_for(spec, mode: str, *, bp: int, p_pad: int, n_pad: int):
    """Round-body factory behind the static ``mode`` knob of the seeded
    kernels: ``"dense_tile"`` regenerates + matmuls, ``"gather"`` runs the
    edge-proportional round.  Identical erasure trajectories."""
    if mode == "dense_tile":
        return _seeded_round(spec, bp=bp, p_pad=p_pad, n_pad=n_pad)
    if mode == "gather":
        return _seeded_gather_round(spec, bp=bp, p_pad=p_pad, n_pad=n_pad)
    raise ValueError(f"seeded mode must be one of {SEEDED_MODES}, "
                     f"got {mode!r}")


def _check_seeded_operands(spec, N: int, V: int, bp: int, bv: int) -> None:
    if N % 128 or V % bv or N < spec.cols or bp % 8:
        raise ValueError(
            "seeded decode operands must be pre-padded (ops.py wrappers do "
            f"this): need N % 128 == 0, V % bv == 0, N >= spec.cols, "
            f"bp % 8 == 0; got N={N} (cols={spec.cols}), V={V} bv={bv}, "
            f"bp={bp}")


def _seeded_p_pad(spec, bp: int) -> int:
    """Check-axis extent of the tile loop: spec.rows rounded up to bp."""
    return spec.rows + (-spec.rows) % bp


def _decode_seeded_kernel(vals_ref, erased_ref, out_vals_ref, out_erased_ref,
                          *, spec, iters: int, bp: int, mode: str):
    N = vals_ref.shape[0]
    round_body = _seeded_round_for(spec, mode, bp=bp,
                                   p_pad=_seeded_p_pad(spec, bp), n_pad=N)
    vals, e = _fixed_loop(round_body, vals_ref[...], erased_ref[...], iters)
    out_vals_ref[...] = vals
    out_erased_ref[...] = e


@functools.partial(jax.jit,
                   static_argnames=("spec", "iters", "bp", "bv", "interpret",
                                    "mode"))
def decode_seeded(spec, values: jax.Array, erased_f: jax.Array, *,
                  iters: int, bp: int = 128, bv: int = 128,
                  interpret: bool | None = None, mode: str = "dense_tile"):
    """Fixed-``iters`` decode with H REGENERATED from the seed per tile.

    Inputs (already padded by ops.py): values (N, V) f32 with N % 128 == 0
    covering ``spec.cols`` (padded columns are all-zero in the generated
    tiles, so they never move), erased_f (N, 1) f32.  ``spec`` is the
    static :class:`repro.core.ldpc.SeededStructure`.  Same trajectory and
    output contract as :func:`decode_fused` / :func:`decode_fused_tiled`
    on the materialized H of the same code; the VMEM working set is ONE
    generated ``(bp, N)`` tile plus the ``(N, bv)`` carry, and H
    contributes ZERO bytes of operand traffic.
    """
    interpret = detect_interpret(interpret)
    N = values.shape[0]
    V = values.shape[1]
    _check_seeded_operands(spec, N, V, bp, bv)
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_seeded_kernel, spec=spec, iters=iters,
                          bp=bp, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values, erased_f)


def _decode_seeded_batch_kernel(vals_ref, erased_ref, out_vals_ref,
                                out_erased_ref, *, spec, iters: int, bp: int,
                                mode: str):
    N = vals_ref.shape[1]
    round_body = _seeded_round_for(spec, mode, bp=bp,
                                   p_pad=_seeded_p_pad(spec, bp), n_pad=N)
    vals, e = _fixed_loop(round_body, vals_ref[0], erased_ref[0], iters)
    out_vals_ref[0] = vals
    out_erased_ref[0] = e


@functools.partial(jax.jit,
                   static_argnames=("spec", "iters", "bp", "bv", "interpret",
                                    "mode"))
def decode_seeded_batch(spec, values: jax.Array, erased_f: jax.Array, *,
                        iters: int, bp: int = 128, bv: int = 128,
                        interpret: bool | None = None,
                        mode: str = "dense_tile"):
    """``B`` independent patterns, H regenerated from the seed per tile.

    Same contract as :func:`decode_fused_batch_tiled` (values (B, N, V),
    erased_f (B, N, 1), both padded) minus the H operand: every grid step
    re-generates the tiles instead of re-streaming them, so the per-slot
    marginal HBM traffic is the payload alone.
    """
    interpret = detect_interpret(interpret)
    B, N, V = values.shape
    _check_seeded_operands(spec, N, V, bp, bv)
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_seeded_batch_kernel, spec=spec,
                          iters=iters, bp=bp, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
        ],
        interpret=interpret,
    )(values, erased_f)


def _decode_seeded_adaptive_kernel(vals_ref, erased_ref, out_vals_ref,
                                   out_erased_ref, out_rounds_ref, *, spec,
                                   max_iters: int, bp: int, mode: str):
    N = vals_ref.shape[0]
    round_body = _seeded_round_for(spec, mode, bp=bp,
                                   p_pad=_seeded_p_pad(spec, bp), n_pad=N)
    vals, e, d = _adaptive_loop(round_body, vals_ref[...], erased_ref[...],
                                max_iters)
    out_vals_ref[...] = vals
    out_erased_ref[...] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("spec", "max_iters", "bp", "bv",
                                             "interpret", "mode"))
def decode_seeded_adaptive(spec, values: jax.Array, erased_f: jax.Array, *,
                           max_iters: int, bp: int = 128, bv: int = 128,
                           interpret: bool | None = None,
                           mode: str = "dense_tile"):
    """Early-exit decode with seed-regenerated tiles: an early exit stops
    the tile regeneration compute the way it stops the tiled kernel's H
    streaming.  Same stopping rule and outputs as
    :func:`decode_fused_adaptive` (values (N, V), erased (N, 1), rounds
    (1, 1))."""
    interpret = detect_interpret(interpret)
    N, V = values.shape
    _check_seeded_operands(spec, N, V, bp, bv)
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_decode_seeded_adaptive_kernel, spec=spec,
                          max_iters=max_iters, bp=bp, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((N, bv), lambda j: (0, j)),
            pl.BlockSpec((N, 1), lambda j: (0, 0)),
            pl.BlockSpec((1, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, V), jnp.float32),
            jax.ShapeDtypeStruct((N, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.int32),
        ],
        interpret=interpret,
    )(values, erased_f)


def _decode_seeded_batch_adaptive_kernel(vals_ref, erased_ref, budget_ref,
                                         out_vals_ref, out_erased_ref,
                                         out_rounds_ref, *, spec, bp: int,
                                         mode: str):
    N = vals_ref.shape[1]
    round_body = _seeded_round_for(spec, mode, bp=bp,
                                   p_pad=_seeded_p_pad(spec, bp), n_pad=N)
    vals, e, d = _adaptive_loop(round_body, vals_ref[0], erased_ref[0],
                                budget_ref[0, 0])  # THIS slot's round budget
    out_vals_ref[0] = vals
    out_erased_ref[0] = e
    out_rounds_ref[...] = jnp.full((1, 1), d, jnp.int32)


@functools.partial(jax.jit, static_argnames=("spec", "bp", "bv", "interpret",
                                             "mode"))
def decode_seeded_batch_adaptive(spec, values: jax.Array,
                                 erased_f: jax.Array, budgets: jax.Array, *,
                                 bp: int = 128, bv: int = 128,
                                 interpret: bool | None = None,
                                 mode: str = "dense_tile"):
    """Per-slot adaptive decode of ``B`` patterns, seed-regenerated tiles.

    Same contract as :func:`decode_fused_batch_adaptive_tiled` (budgets
    (B, 1) int32 stays a TRACED operand) without the H operand: a light
    slot stops its regeneration compute after 1-2 rounds and no slot ever
    touches HBM for H.
    """
    interpret = detect_interpret(interpret)
    B, N, V = values.shape
    _check_seeded_operands(spec, N, V, bp, bv)
    grid = (B, V // bv)
    return pl.pallas_call(
        functools.partial(_decode_seeded_batch_adaptive_kernel, spec=spec,
                          bp=bp, mode=mode),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),      # slot budget
        ],
        out_specs=[
            pl.BlockSpec((1, N, bv), lambda b, j: (b, 0, j)),
            pl.BlockSpec((1, N, 1), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, N, V), jnp.float32),
            jax.ShapeDtypeStruct((B, N, 1), jnp.float32),
            jax.ShapeDtypeStruct((B, 1), jnp.int32),
        ],
        interpret=interpret,
    )(values, erased_f, budgets)


# ----------------------------------------------------- seeded fused encode --


def _encode_seeded_kernel(row0_ref, y_ref, out_ref, *, st, bo: int):
    """One ``(bo, bv)`` tile of seeded-LDGM codeword rows.

    Regenerates the generator gather table of each output row in-register
    — systematic rows are the identity gather, parity rows are the seeded
    draws sorted ASCENDING by column through an odd-even transposition
    network (``row_weight`` compare-exchange passes; columns within a row
    are distinct, so the network reproduces the host argsort exactly) —
    then accumulates ``sum_s w_s * y[col_s]`` as a SEQUENTIAL gather-FMA
    in table order, the same order ``repro.core.encoding.gather_encode``
    uses: the products and their addition order match bit for bit.
    """
    i = pl.program_id(0)
    K, rw = st.cols, st.row_weight
    N = st.cols + st.rows
    row = (row0_ref[0, 0] + i * bo
           + jax.lax.broadcasted_iota(jnp.int32, (bo, 1), 0))   # global row
    prow = row - K                         # parity row index (< 0: systematic)
    _, a, b, jl = _seeded_row_params(st, prow)

    pairs = []
    for s in range(rw):                    # static unroll: the r draws
        col = (a * (jl * rw + s) + b) % K
        pairs.append((col, _seeded_edge_weight(st, prow, s)))
    for p_ in range(rw):                   # odd-even transposition sort
        for q in range(p_ % 2, rw - 1, 2):
            c1, w1 = pairs[q]
            c2, w2 = pairs[q + 1]
            swap = c1 > c2
            pairs[q] = (jnp.where(swap, c2, c1), jnp.where(swap, w2, w1))
            pairs[q + 1] = (jnp.where(swap, c1, c2), jnp.where(swap, w1, w2))

    is_sys = row < K                       # systematic: identity gather
    is_par = (row >= K) & (row < N)        # pad rows (>= N): all-zero weights
    y = y_ref[...]                         # (K_pad, bv)
    acc = None
    for s in range(rw):                    # sequential FMA in table order
        c_s, w_s = pairs[s]
        if s == 0:
            c_s = jnp.where(is_sys, row, c_s)
            w_s = jnp.where(is_sys, 1.0, jnp.where(is_par, w_s, 0.0))
        else:
            c_s = jnp.where(is_sys, 0, c_s)
            w_s = jnp.where(is_sys, 0.0, jnp.where(is_par, w_s, 0.0))
        term = w_s * y[c_s[:, 0]]          # (bo, bv)
        acc = term if s == 0 else acc + term
    out_ref[...] = acc


@functools.partial(jax.jit,
                   static_argnames=("st", "n_out", "bo", "bv", "interpret"))
def encode_seeded_fused(st, y: jax.Array, row0: jax.Array, *, n_out: int,
                        bo: int = 128, bv: int = 128,
                        interpret: bool | None = None):
    """``n_out`` seeded-LDGM codeword rows starting at TRACED row ``row0``.

    ``st`` is the static :class:`repro.core.ldpc.SeededStructure` of the
    ``(p, K)`` generator parity block (``st.cols == K``); ``y`` is the
    already-padded payload (``(K_pad, V)`` f32, ``K_pad % 128 == 0``,
    ``V % bv == 0``, rows past ``K`` zero); ``row0`` a ``(1, 1)`` int32 —
    traced, so a shard_map'd worker passes ``axis_index * rows_per_worker``
    and every shard shares one compilation.  Rows at global index ``>= K +
    st.rows`` (output padding) come out exactly zero.  Returns ``(n_out,
    V)`` f32, bit-identical to ``gather_encode`` on the corresponding
    ``seeded_generator_rows`` table slice — but no table is ever
    materialized anywhere.
    """
    interpret = detect_interpret(interpret)
    K_pad, V = y.shape
    if K_pad % 128 or V % bv or K_pad < st.cols or n_out % bo or bo % 8:
        raise ValueError(
            "encode operands must be pre-padded (ops.py wrappers do this): "
            f"need K_pad % 128 == 0, V % bv == 0, K_pad >= st.cols, "
            f"n_out % bo == 0, bo % 8 == 0; got K_pad={K_pad} "
            f"(cols={st.cols}), V={V} bv={bv}, n_out={n_out} bo={bo}")
    grid = (n_out // bo, V // bv)
    return pl.pallas_call(
        functools.partial(_encode_seeded_kernel, st=st, bo=bo),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),       # traced row0
            pl.BlockSpec((K_pad, bv), lambda i, j: (0, j)),  # payload tile
        ],
        out_specs=[pl.BlockSpec((bo, bv), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((n_out, V), jnp.float32)],
        interpret=interpret,
    )(row0, y)


# ------------------------------------------------------- schedule replay --


def _replay_edge_sum(nv, w):
    """``repro.core.decoder._edge_sum``'s exact op sequence, duplicated so
    kernels stay import-free of ``core.decoder`` (which imports ops.py):
    lone multiplies OUTSIDE a ``lax.scan``, Neumaier-compensated adds
    INSIDE it.  The scan boundary is what pins the IEEE op sequence
    per-element regardless of how many schedule entries the operand
    carries — must stay in lockstep with the decoder's copy for replay
    bit-parity."""
    wx = w.reshape(w.shape + (1,) * (nv.ndim - w.ndim))
    pt = jnp.moveaxis(nv * wx, 1, 0)                # (r_max, rows, ...)

    def body(carry, x):
        s, c = carry
        t = s + x
        big = jnp.abs(s) >= jnp.abs(x)
        c = c + jnp.where(big, (s - t) + x, (x - t) + s)
        return (t, c), None

    (s, c), _ = jax.lax.scan(body, (pt[0], jnp.zeros_like(pt[0])), pt[1:])
    return s + c


def _replay_kernel(nidx_ref, w_ref, coeff_ref, tgt_ref, vals_ref, erased_ref,
                   out_vals_ref, out_erased_ref, *, rounds: int, maxseg: int,
                   n_real: int):
    """Replay a packed peeling schedule: ``rounds`` segments of ``maxseg``
    entries each (sentinel-padded), every entry one resolving check's
    gather + compensated edge-sum + guarded divide, scattered back through
    an inverse-index gather (targets are unique within a round by
    construction, so a masked max over the entry axis recovers the writer
    exactly — the resolved value is MOVED, never re-accumulated, keeping
    its bits)."""
    nidx = nidx_ref[...]                            # (R*maxseg, r_max) i32
    w = w_ref[...]                                  # (R*maxseg, r_max) f32
    cf = coeff_ref[...][:, 0]                       # (R*maxseg,)
    tgt = tgt_ref[...][:, 0]                        # (R*maxseg,) i32
    n_pad = vals_ref.shape[0]

    ent = jax.lax.broadcasted_iota(jnp.int32, (maxseg, n_pad), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (maxseg, n_pad), 1)
    colv = jax.lax.broadcasted_iota(jnp.int32, (n_pad, 1), 0)[:, 0]

    def round_body(t, carry):
        vals, e = carry
        b = t * maxseg
        idx_t = jax.lax.dynamic_slice_in_dim(nidx, b, maxseg)
        w_t = jax.lax.dynamic_slice_in_dim(w, b, maxseg)
        cf_t = jax.lax.dynamic_slice_in_dim(cf, b, maxseg)
        tg_t = jax.lax.dynamic_slice_in_dim(tgt, b, maxseg)
        nv = vals[idx_t]                            # (maxseg, r_max, BV)
        sums = _replay_edge_sum(nv, w_t)
        new_val = -sums / jnp.where(cf_t == 0.0, 1.0, cf_t)[:, None]
        # inverse-gather scatter: which entry (if any) writes each column
        inv = jnp.max(jnp.where(col == tg_t[:, None], ent, -1), axis=0)
        # sentinel targets land on padding columns; keep those rows exactly
        # +0.0 so later rounds' sentinel gathers read the same zero the
        # executor's concat row provides
        hit = (inv >= 0) & (colv < n_real)
        picked = new_val[jnp.maximum(inv, 0)]
        vals = jnp.where(hit[:, None], picked, vals)
        e = jnp.where(hit[:, None], 0.0, e)
        return vals, e

    vals, e = jax.lax.fori_loop(0, rounds, round_body,
                                (vals_ref[...], erased_ref[...]))
    out_vals_ref[...] = vals
    out_erased_ref[...] = e


@functools.partial(jax.jit, static_argnames=("rounds", "maxseg", "n_real",
                                             "bv", "interpret"))
def decode_replay(nidx: jax.Array, w: jax.Array, coeff: jax.Array,
                  tgt: jax.Array, values: jax.Array, erased_f: jax.Array, *,
                  rounds: int, maxseg: int, n_real: int, bv: int = 128,
                  interpret: bool | None = None):
    """Whole schedule replay in ONE ``pallas_call`` — no flooding loop, no
    convergence mask, no H operand: only the resolving checks' edges ride
    in as the packed schedule.

    Inputs (packed/padded by ops.py): ``nidx (R·maxseg, r_max) i32``
    neighbor columns (sentinel ``n_real`` on padding slots/entries — points
    at a guaranteed-zero padded row), ``w (R·maxseg, r_max) f32`` pre-masked
    edge weights, ``coeff (R·maxseg, 1) f32`` target-slot coefficients (0 on
    padding entries), ``tgt (R·maxseg, 1) i32`` target columns (sentinel
    ``n_real`` on padding entries), ``values (n_pad, V) f32`` with
    ``n_pad % 128 == 0`` and ``n_pad > n_real``, ``erased_f (n_pad, 1)``.

    ``interpret=None`` = backend-detected (compiled on TPU, else interpret).
    The schedule gathers lower like the seeded gather round — exact in
    interpret mode everywhere; TPU lowering tuning rides ROADMAP item 5.

    Returns (values (n_pad, V) f32, erased (n_pad, 1) f32).
    """
    interpret = detect_interpret(interpret)
    n_pad, V = values.shape
    S, r_max = nidx.shape
    grid = (V // bv,)
    return pl.pallas_call(
        functools.partial(_replay_kernel, rounds=rounds, maxseg=maxseg,
                          n_real=n_real),
        grid=grid,
        in_specs=[
            pl.BlockSpec((S, r_max), lambda j: (0, 0)),   # schedule: resident
            pl.BlockSpec((S, r_max), lambda j: (0, 0)),
            pl.BlockSpec((S, 1), lambda j: (0, 0)),
            pl.BlockSpec((S, 1), lambda j: (0, 0)),
            pl.BlockSpec((n_pad, bv), lambda j: (0, j)),  # payload slice
            pl.BlockSpec((n_pad, 1), lambda j: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_pad, bv), lambda j: (0, j)),
            # every grid step replays the identical trajectory and rewrites
            # the same mask block — benign (sequential grid on TPU).
            pl.BlockSpec((n_pad, 1), lambda j: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_pad, V), jnp.float32),
            jax.ShapeDtypeStruct((n_pad, 1), jnp.float32),
        ],
        interpret=interpret,
    )(nidx, w, coeff, tgt, values, erased_f)
