"""jit'd wrapper: padding, kernel invocation, and the scatter epilogue that
turns the fused check-node pass into a full peeling round / D-round decode."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ldpc_peel.kernel import check_pass

__all__ = ["peel_round_pallas", "peel_decode_pallas"]


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("interpret", "bp", "bv"))
def peel_round_pallas(H, values, erased, *, interpret: bool = True,
                      bp: int = 128, bv: int = 128):
    """One flooding round. H (p,N) f32; values (N,) or (N,V); erased (N,) bool.
    Returns (values, erased) updated — same contract as decoder.peel_round."""
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N = vals.shape[0]
    p = H.shape[0]

    bp_eff = min(bp, max(8, p))
    Hp = _pad_to(_pad_to(H.astype(jnp.float32), bp_eff, 0), 128, 1)
    vp = _pad_to(_pad_to(vals.astype(jnp.float32), 128, 0), bv, 1)
    ep = _pad_to(erased.astype(jnp.float32)[:, None], 128, 0)

    sums, cnt, pos, coeff = check_pass(Hp, vp, ep, bp=bp_eff,
                                       bv=min(bv, vp.shape[1]),
                                       interpret=interpret)
    sums, cnt, pos, coeff = (sums[:p, : vals.shape[1]], cnt[:p, 0],
                             pos[:p, 0], coeff[:p, 0])

    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    safe_pos = jnp.where(solvable, pos, N)
    out_vals = vals.at[safe_pos].set(new_val.astype(vals.dtype), mode="drop")
    out_erased = erased.at[safe_pos].set(False, mode="drop")
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_pallas(H, values, erased, iters: int, *, interpret: bool = True):
    """Fixed-D decode via the Pallas round (python loop: D is small)."""
    for _ in range(iters):
        values, erased = peel_round_pallas(H, values, erased,
                                           interpret=interpret)
    return values, erased
