"""jit'd wrappers around the ldpc_peel kernels.

* :func:`peel_round_pallas` — one flooding round (``check_pass`` kernel +
  host-side scatter epilogue), kept for per-round experimentation/tests;
* :func:`peel_decode_pallas` — the fused path: pad ONCE, run the whole
  fixed-``D`` decode inside a single ``pallas_call`` (H resident in VMEM
  across rounds, scatter epilogue fused in-kernel), unpad once.  This is
  what ``repro.core.decoder.peel_decode(..., backend="pallas")`` calls.
* :func:`peel_decode_batch_pallas` — ``B`` independent erasure patterns in
  one launch (grid over the batch, H resident and shared); the kernel side
  of ``CodedComputeEngine.decode_batch``.
* :func:`peel_decode_adaptive_pallas` — the early-exit decode as one launch
  (in-kernel ``while_loop`` on the unresolved count), so
  ``peel_decode_adaptive(backend="pallas")`` keeps single-launch parity with
  the fixed-D path.
* :func:`peel_decode_batch_adaptive_pallas` — per-slot adaptive decode of B
  independent patterns in one launch: grid over the slots, each with its own
  in-kernel ``while_loop`` and (traced) round budget; the kernel side of
  ``CodedComputeEngine.decode_batch(adaptive=True)`` and the serving
  layer's continuous-admission launches.
* the ``peel_decode*_tiled_pallas`` family — the same four contracts backed
  by the CHECK-AXIS-TILED kernels: H stays in HBM and is streamed
  tile-by-tile (``bp`` check rows at a time, double-buffered) while the
  value carry lives in VMEM, so problem size is no longer bounded by
  whole-H-in-VMEM.  The wrappers pad ``p`` up to a multiple of the
  effective ``bp`` (ragged tile edges become all-zero check rows: never
  counted, never solvable, never written), clamping ``bp`` down for small
  codes so a single-tile stream still works.

``interpret`` defaults to ``None`` = backend-detected: compiled on TPU,
interpret mode elsewhere (CPU CI runs the same kernel code path, slowly but
bit-faithfully).

The ``peel_decode*_seeded_pallas`` family wraps the SEEDED kernels: no H
argument at all — the caller passes the hashable
``repro.core.ldpc.SeededStructure`` spec (a static argument) and each tile
is regenerated in-register from the seed.  Only the payload is padded.
Each wrapper takes ``mode`` ("dense_tile" | "gather", static) selecting the
round implementation — dense regenerated-tile matmul vs the
edge-proportional gather/segment-sum round (same erasure trajectory,
O(p·r) instead of O(p·N) FLOPs per round).

:func:`peel_decode_replay_pallas` wraps the pattern-compiled REPLAY
kernel: it packs a pre-solved :class:`repro.core.decoder.PeelSchedule`
into sentinel-padded per-round segments (host-side, cached on the
schedule) and applies the whole elimination order in ONE ``pallas_call``
— no flooding loop, no H operand, values bit-identical to the
``backend="replay"`` executors under the matching tie-break rule.

:func:`encode_seeded_fused_pallas` is the ENCODE-side twin: the seeded
LDGM generator gather (``z = gather(G_rows, y)``) fused into one
``pallas_call`` that regenerates each output row's (column, weight) pairs
in-register — no ``(N, r+1)`` index tables materialized.  ``row0`` stays a
traced operand so sharded workers can encode their own row window without
recompiling per shard.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.padding import pad_axis_to
from repro.kernels.ldpc_peel.kernel import (
    check_pass,
    decode_fused,
    decode_fused_adaptive,
    decode_fused_adaptive_tiled,
    decode_fused_batch,
    decode_fused_batch_adaptive,
    decode_fused_batch_adaptive_tiled,
    decode_fused_batch_tiled,
    decode_fused_tiled,
    decode_replay,
    decode_seeded,
    decode_seeded_adaptive,
    decode_seeded_batch,
    decode_seeded_batch_adaptive,
    detect_interpret,
    encode_seeded_fused,
)

__all__ = ["peel_round_pallas", "peel_decode_pallas",
           "peel_decode_batch_pallas", "peel_decode_adaptive_pallas",
           "peel_decode_batch_adaptive_pallas",
           "peel_decode_tiled_pallas", "peel_decode_batch_tiled_pallas",
           "peel_decode_adaptive_tiled_pallas",
           "peel_decode_batch_adaptive_tiled_pallas",
           "peel_decode_seeded_pallas", "peel_decode_batch_seeded_pallas",
           "peel_decode_adaptive_seeded_pallas",
           "peel_decode_batch_adaptive_seeded_pallas",
           "encode_seeded_fused_pallas", "peel_decode_replay_pallas"]


@partial(jax.jit, static_argnames=("interpret", "bp", "bv"))
def _peel_round_impl(H, values, erased, *, interpret: bool,
                     bp: int = 128, bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N = vals.shape[0]
    p = H.shape[0]

    bp_eff = min(bp, max(8, p))
    Hp = pad_axis_to(pad_axis_to(H.astype(jnp.float32), bp_eff, 0), 128, 1)
    vp = pad_axis_to(pad_axis_to(vals.astype(jnp.float32), 128, 0), bv, 1)
    ep = pad_axis_to(erased.astype(jnp.float32)[:, None], 128, 0)

    sums, cnt, pos, coeff = check_pass(Hp, vp, ep, bp=bp_eff,
                                       bv=min(bv, vp.shape[1]),
                                       interpret=interpret)
    sums, cnt, pos, coeff = (sums[:p, : vals.shape[1]], cnt[:p, 0],
                             pos[:p, 0], coeff[:p, 0])

    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    safe_pos = jnp.where(solvable, pos, N)
    out_vals = vals.at[safe_pos].set(new_val.astype(vals.dtype), mode="drop")
    out_erased = erased.at[safe_pos].set(False, mode="drop")
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_round_pallas(H, values, erased, *, interpret: bool | None = None,
                      bp: int = 128, bv: int = 128):
    """One flooding round. H (p,N) f32; values (N,) or (N,V); erased (N,) bool.
    Returns (values, erased) updated — same contract as decoder.peel_round."""
    return _peel_round_impl(H, values, erased,
                            interpret=detect_interpret(interpret),
                            bp=bp, bv=bv)


def _pad_operands(H, vals, erased_f, bv):
    """Pad ONCE for a whole fused decode: N → multiple of 128 (lanes),
    p → multiple of 8 (sublanes), V → multiple of bv (payload tile).
    Padded coordinates are "known" zeros on zero H columns/rows: never
    counted, never solvable, never written."""
    Hp = pad_axis_to(pad_axis_to(H.astype(jnp.float32), 8, 0), 128, 1)
    vp = pad_axis_to(pad_axis_to(vals.astype(jnp.float32), 128, -2), bv, -1)
    ep = pad_axis_to(erased_f, 128, -2)
    return Hp, vp, ep


@partial(jax.jit, static_argnames=("iters", "interpret", "bv"))
def _peel_decode_impl(H, values, erased, *, iters: int, interpret: bool,
                      bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    Hp, vp, ep = _pad_operands(H, vals, erased.astype(jnp.float32)[:, None], bv)
    out_v, out_e = decode_fused(Hp, vp, ep, iters=iters,
                                bv=min(bv, vp.shape[1]), interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_pallas(H, values, erased, iters: int, *,
                       interpret: bool | None = None, bv: int = 128):
    """Fixed-D decode in ONE kernel launch (no per-round relaunch/re-pad).

    H (p, N) f32; values (N,) or (N, V); erased (N,) bool.  Returns
    (values, erased) after exactly ``iters`` flooding rounds — same contract
    as ``decoder.peel_decode`` restricted to fixed D.
    """
    return _peel_decode_impl(H, values, erased, iters=int(iters),
                             interpret=detect_interpret(interpret), bv=bv)


@partial(jax.jit, static_argnames=("iters", "interpret", "bv"))
def _peel_decode_batch_impl(H, values, erased, *, iters: int, interpret: bool,
                            bv: int = 128):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    Hp, vp, ep = _pad_operands(H, vals,
                               erased.astype(jnp.float32)[:, :, None], bv)
    out_v, out_e = decode_fused_batch(Hp, vp, ep, iters=iters,
                                      bv=min(bv, vp.shape[2]),
                                      interpret=interpret)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased


def peel_decode_batch_pallas(H, values, erased, iters: int, *,
                             interpret: bool | None = None, bv: int = 128):
    """Fixed-D decode of B independent erasure patterns in ONE launch.

    H (p, N) f32; values (B, N) or (B, N, V); erased (B, N) bool.  The grid
    runs over the batch with H resident in VMEM and shared across all B
    queries.  Returns (values, erased) with the batch axis preserved.
    """
    return _peel_decode_batch_impl(H, values, erased, iters=int(iters),
                                   interpret=detect_interpret(interpret),
                                   bv=bv)


@partial(jax.jit, static_argnames=("max_iters", "interpret", "bv"))
def _peel_decode_adaptive_impl(H, values, erased, *, max_iters: int,
                               interpret: bool, bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    Hp, vp, ep = _pad_operands(H, vals, erased.astype(jnp.float32)[:, None], bv)
    out_v, out_e, rounds = decode_fused_adaptive(
        Hp, vp, ep, max_iters=max_iters, bv=min(bv, vp.shape[1]),
        interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased, rounds[0, 0]


def peel_decode_adaptive_pallas(H, values, erased, max_iters: int, *,
                                interpret: bool | None = None, bv: int = 128):
    """Early-exit decode in ONE launch (in-kernel while_loop).

    Same stopping rule as ``decoder.peel_decode_adaptive``: stop when a
    round resolves nothing, nothing is erased, or ``max_iters`` is reached.
    Returns (values, erased, rounds_used ()).
    """
    return _peel_decode_adaptive_impl(H, values, erased,
                                      max_iters=int(max_iters),
                                      interpret=detect_interpret(interpret),
                                      bv=bv)


@partial(jax.jit, static_argnames=("interpret", "bv"))
def _peel_decode_batch_adaptive_impl(H, values, erased, budgets, *,
                                     interpret: bool, bv: int = 128):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    Hp, vp, ep = _pad_operands(H, vals,
                               erased.astype(jnp.float32)[:, :, None], bv)
    out_v, out_e, rounds = decode_fused_batch_adaptive(
        Hp, vp, ep, budgets.astype(jnp.int32)[:, None],
        bv=min(bv, vp.shape[2]), interpret=interpret)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased, rounds[:, 0]


def peel_decode_batch_adaptive_pallas(H, values, erased, budgets, *,
                                      interpret: bool | None = None,
                                      bv: int = 128):
    """Per-slot adaptive decode of B independent patterns in ONE launch.

    H (p, N) f32; values (B, N) or (B, N, V); erased (B, N) bool;
    budgets (B,) int — each slot's round budget (a traced operand: varying
    budgets never recompile).  Each slot follows exactly the
    ``decoder.peel_decode_adaptive`` stopping rule under its own budget.
    Returns (values, erased, rounds_used (B,)).
    """
    return _peel_decode_batch_adaptive_impl(
        H, values, erased, jnp.asarray(budgets),
        interpret=detect_interpret(interpret), bv=bv)


# ------------------------------------------------ check-axis-tiled family --


def _effective_bp(p: int, bp: int) -> int:
    """Clamp the check-tile height to the (8-aligned) padded check count so
    small codes stream as a single tile instead of over-padding."""
    p8 = p + (-p) % 8
    return max(8, min(bp - bp % 8 if bp >= 8 else 8, p8))


def _pad_operands_tiled(H, vals, erased_f, bv, bp):
    """Pad ONCE for a whole tiled decode: N → multiple of 128 (lanes),
    p → multiple of ``bp`` (every streamed tile is full — ragged check-tile
    edges become all-zero rows: never counted, never solvable, never
    written), V → multiple of bv (payload tile)."""
    Hp = pad_axis_to(pad_axis_to(H.astype(jnp.float32), bp, 0), 128, 1)
    vp = pad_axis_to(pad_axis_to(vals.astype(jnp.float32), 128, -2), bv, -1)
    ep = pad_axis_to(erased_f, 128, -2)
    return Hp, vp, ep


@partial(jax.jit, static_argnames=("iters", "interpret", "bp", "bv"))
def _peel_decode_tiled_impl(H, values, erased, *, iters: int, interpret: bool,
                            bp: int = 128, bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    bp_eff = _effective_bp(H.shape[0], bp)
    Hp, vp, ep = _pad_operands_tiled(H, vals,
                                     erased.astype(jnp.float32)[:, None],
                                     bv, bp_eff)
    out_v, out_e = decode_fused_tiled(Hp, vp, ep, iters=iters, bp=bp_eff,
                                      bv=min(bv, vp.shape[1]),
                                      interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_tiled_pallas(H, values, erased, iters: int, *,
                             interpret: bool | None = None, bp: int = 128,
                             bv: int = 128):
    """Fixed-D decode in ONE launch with H streamed over check tiles.

    Same contract as :func:`peel_decode_pallas` (H (p, N) f32; values (N,)
    or (N, V); erased (N,) bool), same erasure trajectory; ``bp`` sets the
    streamed tile height (clamped/8-aligned, p padded up to a multiple).
    """
    return _peel_decode_tiled_impl(H, values, erased, iters=int(iters),
                                   interpret=detect_interpret(interpret),
                                   bp=bp, bv=bv)


@partial(jax.jit, static_argnames=("iters", "interpret", "bp", "bv"))
def _peel_decode_batch_tiled_impl(H, values, erased, *, iters: int,
                                  interpret: bool, bp: int = 128,
                                  bv: int = 128):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    bp_eff = _effective_bp(H.shape[0], bp)
    Hp, vp, ep = _pad_operands_tiled(
        H, vals, erased.astype(jnp.float32)[:, :, None], bv, bp_eff)
    out_v, out_e = decode_fused_batch_tiled(Hp, vp, ep, iters=iters,
                                            bp=bp_eff,
                                            bv=min(bv, vp.shape[2]),
                                            interpret=interpret)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased


def peel_decode_batch_tiled_pallas(H, values, erased, iters: int, *,
                                   interpret: bool | None = None,
                                   bp: int = 128, bv: int = 128):
    """Fixed-D decode of B independent patterns, H streamed over check
    tiles.  Same contract as :func:`peel_decode_batch_pallas`."""
    return _peel_decode_batch_tiled_impl(
        H, values, erased, iters=int(iters),
        interpret=detect_interpret(interpret), bp=bp, bv=bv)


@partial(jax.jit, static_argnames=("max_iters", "interpret", "bp", "bv"))
def _peel_decode_adaptive_tiled_impl(H, values, erased, *, max_iters: int,
                                     interpret: bool, bp: int = 128,
                                     bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    bp_eff = _effective_bp(H.shape[0], bp)
    Hp, vp, ep = _pad_operands_tiled(H, vals,
                                     erased.astype(jnp.float32)[:, None],
                                     bv, bp_eff)
    out_v, out_e, rounds = decode_fused_adaptive_tiled(
        Hp, vp, ep, max_iters=max_iters, bp=bp_eff,
        bv=min(bv, vp.shape[1]), interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased, rounds[0, 0]


def peel_decode_adaptive_tiled_pallas(H, values, erased, max_iters: int, *,
                                      interpret: bool | None = None,
                                      bp: int = 128, bv: int = 128):
    """Early-exit decode in ONE launch, H streamed over check tiles.  Same
    stopping rule and contract as :func:`peel_decode_adaptive_pallas`."""
    return _peel_decode_adaptive_tiled_impl(
        H, values, erased, max_iters=int(max_iters),
        interpret=detect_interpret(interpret), bp=bp, bv=bv)


@partial(jax.jit, static_argnames=("interpret", "bp", "bv"))
def _peel_decode_batch_adaptive_tiled_impl(H, values, erased, budgets, *,
                                           interpret: bool, bp: int = 128,
                                           bv: int = 128):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    bp_eff = _effective_bp(H.shape[0], bp)
    Hp, vp, ep = _pad_operands_tiled(
        H, vals, erased.astype(jnp.float32)[:, :, None], bv, bp_eff)
    out_v, out_e, rounds = decode_fused_batch_adaptive_tiled(
        Hp, vp, ep, budgets.astype(jnp.int32)[:, None], bp=bp_eff,
        bv=min(bv, vp.shape[2]), interpret=interpret)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased, rounds[:, 0]


def peel_decode_batch_adaptive_tiled_pallas(H, values, erased, budgets, *,
                                            interpret: bool | None = None,
                                            bp: int = 128, bv: int = 128):
    """Per-slot adaptive decode of B independent patterns in ONE launch,
    H streamed over check tiles per slot.  Same contract as
    :func:`peel_decode_batch_adaptive_pallas` (budgets stay traced)."""
    return _peel_decode_batch_adaptive_tiled_impl(
        H, values, erased, jnp.asarray(budgets),
        interpret=detect_interpret(interpret), bp=bp, bv=bv)


# ------------------------------------------------------- seeded family --


def _pad_operands_seeded(vals, erased_f, bv):
    """Pad ONCE for a whole seeded decode: only the PAYLOAD needs padding
    (N → multiple of 128, V → multiple of ``bv``) — there is no H operand;
    the kernel's generated tiles are zero on padded columns and padded
    check rows by construction."""
    vp = pad_axis_to(pad_axis_to(vals.astype(jnp.float32), 128, -2), bv, -1)
    ep = pad_axis_to(erased_f, 128, -2)
    return vp, ep


@partial(jax.jit, static_argnames=("spec", "iters", "interpret", "bp", "bv",
                                   "mode"))
def _peel_decode_seeded_impl(values, erased, *, spec, iters: int,
                             interpret: bool, bp: int = 128, bv: int = 128,
                             mode: str = "dense_tile"):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    bp_eff = _effective_bp(spec.rows, bp)
    vp, ep = _pad_operands_seeded(vals, erased.astype(jnp.float32)[:, None],
                                  bv)
    out_v, out_e = decode_seeded(spec, vp, ep, iters=iters, bp=bp_eff,
                                 bv=min(bv, vp.shape[1]), interpret=interpret,
                                 mode=mode)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_seeded_pallas(spec, values, erased, iters: int, *,
                              interpret: bool | None = None, bp: int = 128,
                              bv: int = 128, mode: str = "dense_tile"):
    """Fixed-D decode in ONE launch with H REGENERATED from the seed.

    ``spec`` is the static :class:`repro.core.ldpc.SeededStructure`; values
    (N,) or (N, V); erased (N,) bool.  Same erasure trajectory as every
    materialized backend on the same code and bit-identical VALUES to the
    tiled path (same tile-shaped summation); zero H operand traffic.
    ``mode="gather"`` swaps the dense regenerated-tile round for the
    edge-proportional gather round: identical trajectory, values equal up
    to f32 summation order.
    """
    return _peel_decode_seeded_impl(values, erased, spec=spec,
                                    iters=int(iters),
                                    interpret=detect_interpret(interpret),
                                    bp=bp, bv=bv, mode=mode)


@partial(jax.jit, static_argnames=("spec", "iters", "interpret", "bp", "bv",
                                   "mode"))
def _peel_decode_batch_seeded_impl(values, erased, *, spec, iters: int,
                                   interpret: bool, bp: int = 128,
                                   bv: int = 128, mode: str = "dense_tile"):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    bp_eff = _effective_bp(spec.rows, bp)
    vp, ep = _pad_operands_seeded(vals,
                                  erased.astype(jnp.float32)[:, :, None], bv)
    out_v, out_e = decode_seeded_batch(spec, vp, ep, iters=iters, bp=bp_eff,
                                       bv=min(bv, vp.shape[2]),
                                       interpret=interpret, mode=mode)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased


def peel_decode_batch_seeded_pallas(spec, values, erased, iters: int, *,
                                    interpret: bool | None = None,
                                    bp: int = 128, bv: int = 128,
                                    mode: str = "dense_tile"):
    """Fixed-D decode of B independent patterns, H regenerated from the
    seed per grid step.  Same contract as
    :func:`peel_decode_batch_tiled_pallas` minus the H operand;
    ``mode="gather"`` selects the edge-proportional round."""
    return _peel_decode_batch_seeded_impl(
        values, erased, spec=spec, iters=int(iters),
        interpret=detect_interpret(interpret), bp=bp, bv=bv, mode=mode)


@partial(jax.jit,
         static_argnames=("spec", "max_iters", "interpret", "bp", "bv",
                          "mode"))
def _peel_decode_adaptive_seeded_impl(values, erased, *, spec,
                                      max_iters: int, interpret: bool,
                                      bp: int = 128, bv: int = 128,
                                      mode: str = "dense_tile"):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape

    bp_eff = _effective_bp(spec.rows, bp)
    vp, ep = _pad_operands_seeded(vals, erased.astype(jnp.float32)[:, None],
                                  bv)
    out_v, out_e, rounds = decode_seeded_adaptive(
        spec, vp, ep, max_iters=max_iters, bp=bp_eff,
        bv=min(bv, vp.shape[1]), interpret=interpret, mode=mode)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased, rounds[0, 0]


def peel_decode_adaptive_seeded_pallas(spec, values, erased, max_iters: int,
                                       *, interpret: bool | None = None,
                                       bp: int = 128, bv: int = 128,
                                       mode: str = "dense_tile"):
    """Early-exit decode in ONE launch, H regenerated from the seed.  Same
    stopping rule and contract as :func:`peel_decode_adaptive_tiled_pallas`
    minus the H operand; ``mode="gather"`` selects the edge-proportional
    round (identical trajectory and round counts)."""
    return _peel_decode_adaptive_seeded_impl(
        values, erased, spec=spec, max_iters=int(max_iters),
        interpret=detect_interpret(interpret), bp=bp, bv=bv, mode=mode)


@partial(jax.jit, static_argnames=("spec", "interpret", "bp", "bv", "mode"))
def _peel_decode_batch_adaptive_seeded_impl(values, erased, budgets, *, spec,
                                            interpret: bool, bp: int = 128,
                                            bv: int = 128,
                                            mode: str = "dense_tile"):
    squeeze = values.ndim == 2  # (B, N) scalar payloads
    vals = values[:, :, None] if squeeze else values
    B, N, V = vals.shape

    bp_eff = _effective_bp(spec.rows, bp)
    vp, ep = _pad_operands_seeded(vals,
                                  erased.astype(jnp.float32)[:, :, None], bv)
    out_v, out_e, rounds = decode_seeded_batch_adaptive(
        spec, vp, ep, budgets.astype(jnp.int32)[:, None], bp=bp_eff,
        bv=min(bv, vp.shape[2]), interpret=interpret, mode=mode)
    out_vals = out_v[:, :N, :V].astype(vals.dtype)
    out_erased = out_e[:, :N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, :, 0]
    return out_vals, out_erased, rounds[:, 0]


def peel_decode_batch_adaptive_seeded_pallas(spec, values, erased, budgets,
                                             *, interpret: bool | None = None,
                                             bp: int = 128, bv: int = 128,
                                             mode: str = "dense_tile"):
    """Per-slot adaptive decode of B independent patterns in ONE launch, H
    regenerated from the seed per slot.  Same contract as
    :func:`peel_decode_batch_adaptive_tiled_pallas` (budgets stay traced);
    ``mode="gather"`` selects the edge-proportional round."""
    return _peel_decode_batch_adaptive_seeded_impl(
        values, erased, jnp.asarray(budgets), spec=spec,
        interpret=detect_interpret(interpret), bp=bp, bv=bv, mode=mode)


# ------------------------------------------------------- seeded encode --


@partial(jax.jit, static_argnames=("st", "n_out", "interpret", "bo", "bv"))
def _encode_seeded_fused_impl(y, row0, *, st, n_out: int, interpret: bool,
                              bo: int = 128, bv: int = 128):
    squeeze = y.ndim == 1
    yv = y[:, None] if squeeze else y
    V = yv.shape[1]

    bo_eff = _effective_bp(n_out, bo)
    n_pad = n_out + (-n_out) % bo_eff
    yp = pad_axis_to(pad_axis_to(yv.astype(jnp.float32), 128, 0), bv, 1)
    out = encode_seeded_fused(st, yp, row0, n_out=n_pad, bo=bo_eff,
                              bv=min(bv, yp.shape[1]),
                              interpret=interpret)[0]
    out = out[:n_out, :V].astype(yv.dtype)
    if squeeze:
        out = out[:, 0]
    return out


def encode_seeded_fused_pallas(st, y, row0=0, *, n_out: int | None = None,
                               interpret: bool | None = None,
                               bo: int = 128, bv: int = 128):
    """Seeded-LDGM codeword rows ``[row0, row0 + n_out)`` from payload ``y``,
    generator gather fused into ONE kernel launch — no index tables.

    ``st`` is the static :class:`repro.core.ldpc.SeededStructure` of the
    generator's parity block (``st.cols == K``, ``st.rows == p``); ``y`` is
    (K,) or (K, V); ``row0`` may be a traced int (sharded workers pass
    ``axis_index * rows_per_worker``).  ``n_out`` defaults to the full
    codeword length ``K + p``; rows past it are computed in padding and
    sliced away, rows at global index ``>= K + p`` are exactly zero.  The
    per-row gather-sum runs in TABLE order, bit-identical to the
    (jit-compiled) :func:`repro.core.encoding.gather_encode` over
    ``seeded_generator_rows``.
    """
    if n_out is None:
        n_out = st.cols + st.rows
    r0 = jnp.asarray(row0, jnp.int32).reshape(1, 1)
    return _encode_seeded_fused_impl(y, r0, st=st, n_out=int(n_out),
                                     interpret=detect_interpret(interpret),
                                     bo=bo, bv=bv)


# ----------------------------------------------------- schedule replay --


def _pack_replay(sched, rule: str, rounds: int):
    """Pack ``rounds`` schedule segments into dense sentinel-padded arrays
    for the fused replay kernel: every round becomes ``maxseg`` entries
    (real ones first, then no-op padding whose neighbor/target indices are
    the sentinel ``N`` — a guaranteed-zero padded row/column).  Built
    host-side once per ``(rule, rounds)`` prefix and cached on the
    schedule next to the executor operands."""
    key = ("packed", rule, rounds)
    cached = sched._ops.get(key)
    if cached is not None:
        return cached
    off = np.asarray(sched.offsets)
    segs = [(int(off[k]), int(off[k + 1])) for k in range(rounds)]
    maxseg = max([s1 - s0 for s0, s1 in segs] + [1])
    R = max(rounds, 1)
    nidx = np.full((R * maxseg, sched.r_max), sched.N, np.int32)
    w = np.zeros((R * maxseg, sched.r_max), np.float32)
    cf = np.zeros((R * maxseg, 1), np.float32)
    tg = np.full((R * maxseg, 1), sched.N, np.int32)
    src_i = getattr(sched, f"idx_{rule}")
    src_w = getattr(sched, f"w_{rule}")
    src_c = getattr(sched, f"coeff_{rule}")
    for k, (s0, s1) in enumerate(segs):
        n = s1 - s0
        nidx[k * maxseg:k * maxseg + n] = src_i[s0:s1]
        w[k * maxseg:k * maxseg + n] = src_w[s0:s1]
        cf[k * maxseg:k * maxseg + n, 0] = src_c[s0:s1]
        tg[k * maxseg:k * maxseg + n, 0] = sched.target[s0:s1]
    # concrete even if first packed under a caller's jit trace — cached
    # tracers would poison later eager replays of the same schedule
    with jax.ensure_compile_time_eval():
        cached = (jnp.asarray(nidx), jnp.asarray(w), jnp.asarray(cf),
                  jnp.asarray(tg), maxseg)
    sched._ops[key] = cached
    return cached


@partial(jax.jit, static_argnames=("rounds", "maxseg", "n_real", "interpret",
                                   "bv"))
def _peel_decode_replay_impl(nidx, w, cf, tg, values, erased, *, rounds: int,
                             maxseg: int, n_real: int, interpret: bool,
                             bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape
    # pad N past the sentinel row (n_pad > N always) and up to the lane
    # multiple; sentinel gathers then read a real zero row, exactly like
    # the executors' concatenated zero row
    n_pad = N + 1 + (-(N + 1)) % 128
    vp = jnp.concatenate([vals.astype(jnp.float32),
                          jnp.zeros((n_pad - N, V), jnp.float32)])
    vp = pad_axis_to(vp, bv, -1)
    ep = jnp.concatenate([erased.astype(jnp.float32)[:, None],
                          jnp.zeros((n_pad - N, 1), jnp.float32)])
    out_v, out_e = decode_replay(nidx, w, cf, tg, vp, ep, rounds=rounds,
                                 maxseg=maxseg, n_real=n_real,
                                 bv=min(bv, vp.shape[1]), interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_replay_pallas(sched, values, erased, rounds: int | None = None,
                              *, rule: str = "hi",
                              interpret: bool | None = None, bv: int = 128):
    """Replay a pre-solved peeling schedule in ONE kernel launch.

    ``sched`` is a :class:`repro.core.decoder.PeelSchedule` (passed
    duck-typed — ops stays import-free of ``core.decoder``); values (N,)
    or (N, V); erased (N,) bool.  ``rounds`` clips the replayed prefix
    (default: the whole schedule — budgets are host-known whenever the
    schedule is, so budget clipping is a pack-time slice, not a traced
    mask).  ``rule`` picks the duplicate-check tie-break: ``"hi"`` matches
    the single-pattern dense/sparse scatter (and ``backend="replay"``'s
    single-pattern executor), ``"lo"`` the batch-major/kernel merges.
    Values are bit-identical to the matching executor; work is
    O(schedule entries · r_max).
    """
    rounds = sched.n_rounds if rounds is None else min(int(rounds),
                                                       sched.n_rounds)
    nidx, w, cf, tg, maxseg = _pack_replay(sched, rule, rounds)
    return _peel_decode_replay_impl(nidx, w, cf, tg, values, erased,
                                    rounds=rounds, maxseg=maxseg,
                                    n_real=sched.N,
                                    interpret=detect_interpret(interpret),
                                    bv=bv)
