"""jit'd wrappers around the ldpc_peel kernels.

* :func:`peel_round_pallas` — one flooding round (``check_pass`` kernel +
  host-side scatter epilogue), kept for per-round experimentation/tests;
* :func:`peel_decode_pallas` — the fused path: pad ONCE, run the whole
  fixed-``D`` decode inside a single ``pallas_call`` (H resident in VMEM
  across rounds, scatter epilogue fused in-kernel), unpad once.  This is
  what ``repro.core.decoder.peel_decode(..., backend="pallas")`` calls.

``interpret`` defaults to ``None`` = backend-detected: compiled on TPU,
interpret mode elsewhere (CPU CI runs the same kernel code path, slowly but
bit-faithfully).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.ldpc_peel.kernel import (
    check_pass,
    decode_fused,
    detect_interpret,
)

__all__ = ["peel_round_pallas", "peel_decode_pallas"]


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("interpret", "bp", "bv"))
def _peel_round_impl(H, values, erased, *, interpret: bool,
                     bp: int = 128, bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N = vals.shape[0]
    p = H.shape[0]

    bp_eff = min(bp, max(8, p))
    Hp = _pad_to(_pad_to(H.astype(jnp.float32), bp_eff, 0), 128, 1)
    vp = _pad_to(_pad_to(vals.astype(jnp.float32), 128, 0), bv, 1)
    ep = _pad_to(erased.astype(jnp.float32)[:, None], 128, 0)

    sums, cnt, pos, coeff = check_pass(Hp, vp, ep, bp=bp_eff,
                                       bv=min(bv, vp.shape[1]),
                                       interpret=interpret)
    sums, cnt, pos, coeff = (sums[:p, : vals.shape[1]], cnt[:p, 0],
                             pos[:p, 0], coeff[:p, 0])

    solvable = cnt == 1.0
    new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
    safe_pos = jnp.where(solvable, pos, N)
    out_vals = vals.at[safe_pos].set(new_val.astype(vals.dtype), mode="drop")
    out_erased = erased.at[safe_pos].set(False, mode="drop")
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_round_pallas(H, values, erased, *, interpret: bool | None = None,
                      bp: int = 128, bv: int = 128):
    """One flooding round. H (p,N) f32; values (N,) or (N,V); erased (N,) bool.
    Returns (values, erased) updated — same contract as decoder.peel_round."""
    return _peel_round_impl(H, values, erased,
                            interpret=detect_interpret(interpret),
                            bp=bp, bv=bv)


@partial(jax.jit, static_argnames=("iters", "interpret", "bv"))
def _peel_decode_impl(H, values, erased, *, iters: int, interpret: bool,
                      bv: int = 128):
    squeeze = values.ndim == 1
    vals = values[:, None] if squeeze else values
    N, V = vals.shape
    p = H.shape[0]

    # Pad ONCE for the whole decode (the old path re-padded every round):
    # N → multiple of 128 (lanes), p → multiple of 8 (sublanes),
    # V → multiple of bv (payload tile).
    Hp = _pad_to(_pad_to(H.astype(jnp.float32), 8, 0), 128, 1)
    vp = _pad_to(_pad_to(vals.astype(jnp.float32), 128, 0), bv, 1)
    ep = _pad_to(erased.astype(jnp.float32)[:, None], 128, 0)
    # Padded coordinates are "known" zeros on zero H columns / rows: they are
    # never counted, never solvable, never written.

    out_v, out_e = decode_fused(Hp, vp, ep, iters=iters,
                                bv=min(bv, vp.shape[1]), interpret=interpret)
    out_vals = out_v[:N, :V].astype(vals.dtype)
    out_erased = out_e[:N, 0] > 0.0
    if squeeze:
        out_vals = out_vals[:, 0]
    return out_vals, out_erased


def peel_decode_pallas(H, values, erased, iters: int, *,
                       interpret: bool | None = None, bv: int = 128):
    """Fixed-D decode in ONE kernel launch (no per-round relaunch/re-pad).

    H (p, N) f32; values (N,) or (N, V); erased (N,) bool.  Returns
    (values, erased) after exactly ``iters`` flooding rounds — same contract
    as ``decoder.peel_decode`` restricted to fixed D.
    """
    return _peel_decode_impl(H, values, erased, iters=int(iters),
                             interpret=detect_interpret(interpret), bv=bv)
