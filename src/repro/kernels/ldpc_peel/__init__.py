"""Pallas LDPC peeling-decoder kernels.

``peel_decode_pallas`` is the fused hot path: the whole fixed-D decode in
one kernel launch (see ops.py / kernel.py for the backend matrix and
interpret-mode behaviour off-TPU).  ``peel_round_pallas`` keeps the
single-round check-pass path for experimentation and tests.
"""
from repro.kernels.ldpc_peel.kernel import check_pass, decode_fused
from repro.kernels.ldpc_peel.ops import peel_round_pallas, peel_decode_pallas

__all__ = ["peel_round_pallas", "peel_decode_pallas", "check_pass",
           "decode_fused"]
