from repro.kernels.ldpc_peel.ops import peel_round_pallas, peel_decode_pallas

__all__ = ["peel_round_pallas", "peel_decode_pallas"]
