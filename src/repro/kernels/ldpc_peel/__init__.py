"""Pallas LDPC peeling-decoder kernels.

``peel_decode_pallas`` is the fused hot path: the whole fixed-D decode in
one kernel launch (see ops.py / kernel.py for the backend matrix and
interpret-mode behaviour off-TPU).  ``peel_decode_batch_pallas`` extends it
with a first-class batch axis over independent erasure patterns (grid over
the batch, H resident in VMEM and shared), ``peel_decode_adaptive_pallas``
runs the early-exit decode as one launch via an in-kernel while_loop, and
``peel_decode_batch_adaptive_pallas`` combines the two axes: per-slot
adaptive early exit (with per-slot round budgets) across a batch of
independent erasure patterns, still one launch.

The ``peel_decode*_tiled_pallas`` family carries the same four contracts
past the whole-H-in-VMEM limit: H stays in HBM and is streamed over CHECK
tiles (``bp`` rows at a time, double-buffered DMA) while the value carry
lives in VMEM — one launch, same erasure trajectories, problem size bounded
by HBM instead of one core's VMEM.  ``peel_round_pallas`` keeps the
single-round check-pass path for experimentation and tests.

The ``peel_decode*_seeded_pallas`` family goes one step further: NO H
operand at all.  The caller passes a hashable
``repro.core.ldpc.SeededStructure`` and each ``bp x N`` check tile is
regenerated in-register from the seed inside the flooding round
(``seeded_h_tile``), so H costs zero bytes of HBM storage and traffic —
same erasure trajectories, values bit-identical to the tiled path.

``peel_decode_replay_pallas`` drops the round structure entirely: it takes
a precompiled ``repro.core.PeelSchedule`` (value-independent elimination
order) and replays the resolved edges as one fused gather/FMA launch —
O(resolved edges) work instead of O(rounds x p x r_max), bit-identical to
the flooding trajectory under the matching tie-break rule.
"""
from repro.kernels.ldpc_peel.kernel import (
    check_pass,
    decode_fused,
    decode_fused_adaptive,
    decode_fused_adaptive_tiled,
    decode_fused_batch,
    decode_fused_batch_adaptive,
    decode_fused_batch_adaptive_tiled,
    decode_fused_batch_tiled,
    decode_fused_tiled,
    decode_replay,
    decode_seeded,
    decode_seeded_adaptive,
    decode_seeded_batch,
    decode_seeded_batch_adaptive,
    seeded_h_tile,
)
from repro.kernels.ldpc_peel.ops import (
    peel_decode_adaptive_pallas,
    peel_decode_adaptive_seeded_pallas,
    peel_decode_adaptive_tiled_pallas,
    peel_decode_batch_adaptive_pallas,
    peel_decode_batch_adaptive_seeded_pallas,
    peel_decode_batch_adaptive_tiled_pallas,
    peel_decode_batch_pallas,
    peel_decode_batch_seeded_pallas,
    peel_decode_batch_tiled_pallas,
    peel_decode_pallas,
    peel_decode_replay_pallas,
    peel_decode_seeded_pallas,
    peel_decode_tiled_pallas,
    peel_round_pallas,
)

__all__ = ["peel_round_pallas", "peel_decode_pallas",
           "peel_decode_batch_pallas", "peel_decode_adaptive_pallas",
           "peel_decode_batch_adaptive_pallas",
           "peel_decode_tiled_pallas", "peel_decode_batch_tiled_pallas",
           "peel_decode_adaptive_tiled_pallas",
           "peel_decode_batch_adaptive_tiled_pallas",
           "peel_decode_seeded_pallas", "peel_decode_batch_seeded_pallas",
           "peel_decode_adaptive_seeded_pallas",
           "peel_decode_batch_adaptive_seeded_pallas",
           "peel_decode_replay_pallas",
           "check_pass", "decode_fused", "decode_fused_batch",
           "decode_fused_adaptive", "decode_fused_batch_adaptive",
           "decode_fused_tiled", "decode_fused_batch_tiled",
           "decode_fused_adaptive_tiled",
           "decode_fused_batch_adaptive_tiled",
           "decode_replay",
           "decode_seeded", "decode_seeded_batch",
           "decode_seeded_adaptive", "decode_seeded_batch_adaptive",
           "seeded_h_tile"]
