"""Pure-jnp oracle for the fused check-node pass (and the full round)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["check_pass_ref", "peel_round_ref"]


def check_pass_ref(H, values, erased_f):
    """H (p,N) f32, values (N,V) f32, erased_f (N,1) f32 ->
    (sums (p,V), cnt (p,1), pos (p,1) i32, coeff (p,1))."""
    e = erased_f[:, 0]
    Hb = (H != 0.0).astype(jnp.float32)
    cnt = Hb @ e
    known = values * (1.0 - e)[:, None]
    sums = H @ known
    idx = jnp.broadcast_to(jnp.arange(H.shape[1], dtype=jnp.int32), H.shape)
    mask = (Hb * e[None, :]) > 0
    pos = jnp.max(jnp.where(mask, idx, -1), axis=1)
    coeff = jnp.sum(H * (idx == pos[:, None]), axis=1)
    return sums, cnt[:, None], pos[:, None], coeff[:, None]


def peel_round_ref(H, values, erased):
    """One full flooding round (matches repro.core.decoder.peel_round)."""
    from repro.core.decoder import peel_round
    Hb = H != 0.0
    return peel_round(H, Hb, values, erased)
