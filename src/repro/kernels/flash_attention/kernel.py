"""Causal flash attention (online softmax) as a Pallas TPU kernel.

Grid: (batch*heads, Sq/bq).  Each program owns one (bq, d) query tile in
VMEM and streams (bk, d) key/value tiles with a ``fori_loop``, maintaining
the running max ``m``, normalizer ``l`` and accumulator ``acc`` — the
standard flash-attention recurrence, f32 throughout.

Causality is exploited structurally: query tile ``i`` only loops over KV
tiles up to ``ceil((i+1)*bq / bk)`` — the remaining tiles are never read
from VMEM (and on real TPU never DMA'd).

This kernel is the TPU-tiled version of models/attention.sdpa_chunked and is
cross-checked against it (and a naive softmax oracle) in the test sweeps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_call"]

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, seq_k, true_k,
                  causal, scale):
    i = pl.program_id(1)
    q = q_ref[...][0].astype(jnp.float32)  # (bq, d)
    d = q.shape[-1]
    q_idx = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)

    n_kv = seq_k // bk
    if causal:
        # last kv tile that intersects the causal triangle of this q tile
        upper = jnp.minimum(n_kv, (i * bq + bq + bk - 1) // bk)
    else:
        upper = n_kv

    def body(kb, carry):
        acc, m, l = carry
        k = k_ref[0, pl.dslice(kb * bk, bk), :].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, pl.dslice(kb * bk, bk), :].astype(jnp.float32)
        s = jax.lax.dot(q, k.T, precision=jax.lax.Precision.HIGHEST) * scale
        kv_idx = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kv_idx < true_k  # key-side padding masked out
        if causal:
            mask = mask & (q_idx >= kv_idx)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot(
            p, v, precision=jax.lax.Precision.HIGHEST)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((bq, d), jnp.float32)
    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, upper, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-30)[:, None]
    o_ref[...] = out[None].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bq", "bk", "causal", "interpret", "true_k"))
def flash_call(q: jax.Array, k: jax.Array, v: jax.Array, *, bq: int = 128,
               bk: int = 128, causal: bool = True, interpret: bool = True,
               true_k: int | None = None):
    """q (BH, Sq, d), k/v (BH, Sk, d) — padded to tile multiples by ops.py.
    true_k: un-padded key length (padding keys are masked)."""
    BH, Sq, d = q.shape
    Sk = k.shape[1]
    scale = 1.0 / (d ** 0.5)
    kern = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_k=Sk,
                             true_k=true_k if true_k is not None else Sk,
                             causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(BH, Sq // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Sk, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, Sk, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
