"""Naive softmax attention oracle for the flash kernel."""
import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True):
    """q (BH, Sq, d), k/v (BH, Sk, d) -> (BH, Sq, d)."""
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    d = q.shape[-1]
    s = jnp.einsum("bqd,btd->bqt", qf, kf) / jnp.sqrt(d)
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqt,btd->bqd", p, vf).astype(q.dtype)
