"""jit'd wrapper: GQA head expansion, padding, and (B, S, H, D) layout."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_call

__all__ = ["flash_attention"]


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool = True):
    """q (B, Sq, H, D); k, v (B, Sk, KV, D) with H % KV == 0 (GQA).

    Returns (B, Sq, H, D).  Sq/Sk padded to tile multiples internally; the
    key-side padding is masked inside the kernel via seq_k.
    """
    B, Sq, H, D = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    # (B, S, H, D) -> (B*H, S, D)
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kh = k.transpose(0, 2, 1, 3).reshape(B * H, Sq if False else k.shape[1], D)
    vh = v.transpose(0, 2, 1, 3).reshape(B * H, v.shape[1], D)

    bq_eff = min(bq, max(8, Sq))
    bk_eff = min(bk, max(8, kh.shape[1]))
    pad_q = (-Sq) % bq_eff
    pad_k = (-kh.shape[1]) % bk_eff
    qp = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    kp = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
    vp = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))
    out = flash_call(qp, kp, vp, bq=bq_eff, bk=bk_eff, causal=causal,
                     interpret=interpret, true_k=kh.shape[1])
    out = out[:, :Sq]
    return out.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
