"""Pure-jnp oracle for the tiled matmul."""
import jax.numpy as jnp


def block_matmul_ref(A, B):
    return (A.astype(jnp.float32) @ B.astype(jnp.float32)).astype(jnp.float32)


def coded_matvec_ref(C, theta):
    return (C.astype(jnp.float32) @ theta.astype(jnp.float32)).astype(jnp.float32)
