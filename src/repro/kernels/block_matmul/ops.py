"""jit'd wrappers: padding + tile-size selection for the matmul kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.padding import pad_axis_to
from repro.kernels.block_matmul.kernel import matmul_kernel_call

__all__ = ["block_matmul", "coded_matvec", "encode_gm"]


def _pad(x, m0, m1):
    return pad_axis_to(pad_axis_to(x, m0, 0), m1, 1)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def block_matmul(A, B, *, bm=128, bn=128, bk=128, interpret: bool = True):
    """General tiled A @ B with automatic padding to tile multiples."""
    M, N = A.shape[0], B.shape[1]
    bm = min(bm, max(8, M))
    bn = min(bn, max(8, N))
    bk = min(bk, max(8, A.shape[1]))
    Ap = _pad(A.astype(jnp.float32), bm, bk)
    Bp = _pad(B.astype(jnp.float32), bk, bn)
    out = matmul_kernel_call(Ap, Bp, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def coded_matvec(C, theta, *, interpret: bool = True):
    """Worker-side z = C @ theta (the per-step hot op of Scheme 2)."""
    return block_matmul(C, theta[:, None], interpret=interpret)[:, 0]


def encode_gm(G, M, *, interpret: bool = True):
    """Moment encode C = G @ M (one-time preprocessing at scale)."""
    return block_matmul(G, M, interpret=interpret)
