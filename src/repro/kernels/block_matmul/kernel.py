"""Tiled matmul kernel: C = A @ B with (bm, bn, bk) VMEM tiles.

Used for the paper's two dense hot spots:
  * one-time moment encode  C = G @ M        (N x K) @ (K x k)
  * per-step worker compute z = C_local @ θ  (rows x k) @ (k x 1-ish)

MXU notes: all three tile dims default to 128 (the MXU systolic shape);
accumulation is f32 regardless of input dtype; the k-loop is the innermost
grid dimension so each output tile stays resident in VMEM while A/B tiles
stream through.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["matmul_kernel_call"]


def _mm_kernel(a_ref, b_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot(
        a_ref[...], b_ref[...],
        preferred_element_type=jnp.float32,
        precision=jax.lax.Precision.HIGHEST,
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def matmul_kernel_call(A: jax.Array, B: jax.Array, *, bm: int = 128,
                       bn: int = 128, bk: int = 128, interpret: bool = True):
    """A (M, K) @ B (K, N) -> (M, N) f32. Dims must be tile multiples
    (ops.py pads)."""
    M, K = A.shape
    K2, N = B.shape
    assert K == K2
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(A, B)
