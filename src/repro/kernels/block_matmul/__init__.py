from repro.kernels.block_matmul.ops import block_matmul, coded_matvec, encode_gm

__all__ = ["block_matmul", "coded_matvec", "encode_gm"]
