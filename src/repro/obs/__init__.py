"""`repro.obs` — dependency-free observability: metrics, spans, export.

Three pieces:

* :mod:`repro.obs.metrics` — process-local :class:`MetricsRegistry`
  (counters / gauges / fixed-bin histograms / info facts) that every layer
  records into when enabled, with ``snapshot()`` and JSONL export.
* :mod:`repro.obs.trace` — host-side span tracer emitting Chrome/Perfetto
  ``trace_event`` JSON, with async-safe stamping for the pipelined driver.
* :mod:`repro.obs.report` — ``python -m repro.obs.report out.jsonl``
  renders a run summary from an exported JSONL.

:class:`ObsSession` is the one-liner the CLI surfaces use behind their
``--obs-out`` flags: it enables both sinks, and ``finish()`` writes
``<path>`` (metrics JSONL) plus ``<path stem>.trace.json`` (Chrome trace)
and restores the disabled state.  ``ObsSession.start(None)`` returns an
inert session, so callers never branch::

    session = ObsSession.start(args.obs_out)
    try:
        ...                      # instrumented run
    finally:
        session.finish()

Everything here is off-by-default free: no registry/tracer enabled means
instrumentation sites cost one attribute read and a None check, and jitted
programs see no new operands (recording only touches already-fetched host
values).
"""
from __future__ import annotations

import logging
import sys
from pathlib import Path

from . import metrics, trace
from .metrics import MetricsRegistry, active, disable, enable, recording
from .trace import (Tracer, active_tracer, disable_tracing, enable_tracing,
                    span, tracing)

__all__ = [
    "metrics", "trace", "MetricsRegistry", "Tracer", "ObsSession",
    "enable", "disable", "active", "recording",
    "enable_tracing", "disable_tracing", "active_tracer", "tracing", "span",
    "enable_default_logging",
]


class ObsSession:
    """Paired metrics registry + tracer with one-call JSONL/trace export."""

    def __init__(self, metrics_path, *, jax_annotations: bool = False):
        self.metrics_path = Path(metrics_path)
        self.trace_path = self.metrics_path.with_suffix(".trace.json")
        self.registry = metrics.enable()
        self.tracer = trace.enable_tracing(jax_annotations=jax_annotations)
        self.finished = False

    @classmethod
    def start(cls, metrics_path=None, **kw) -> "ObsSession | _NullSession":
        """Live session when a path is given, inert no-op otherwise."""
        if metrics_path is None:
            return _NullSession()
        return cls(metrics_path, **kw)

    def finish(self, *, quiet: bool = False) -> Path:
        """Export both files, disable the sinks, return the JSONL path.

        Status lines go to **stderr** so callers with machine-readable
        stdout (``selfcheck --json``) stay parseable.
        """
        if self.finished:
            return self.metrics_path
        self.finished = True
        if metrics.active() is self.registry:
            metrics.disable()
        if trace.active_tracer() is self.tracer:
            trace.disable_tracing()
        self.registry.export_jsonl(self.metrics_path)
        self.tracer.export(self.trace_path)
        if not quiet:
            print(f"[obs] metrics -> {self.metrics_path} "
                  f"({len(self.registry)} metrics); "
                  f"trace -> {self.trace_path} "
                  f"({len(self.tracer.events)} events)", file=sys.stderr)
        return self.metrics_path


class _NullSession:
    """Inert stand-in returned by ``ObsSession.start(None)``."""

    registry = None
    tracer = None
    metrics_path = None
    trace_path = None
    finished = True

    def finish(self, *, quiet: bool = False):
        return None


_DEFAULT_HANDLER: logging.Handler | None = None


def enable_default_logging(level: int = logging.DEBUG) -> logging.Logger:
    """Make ``repro`` loggers visible without hand-rolled logging config.

    Attaches one stderr handler to the ``"repro"`` logger (idempotent) so
    e.g. ``CodedComputeEngine``'s construction-time dispatch line —
    ``debug_info()``: resolved backend, seeded mode, VMEM estimate — shows
    up immediately.  Returns the configured logger.
    """
    global _DEFAULT_HANDLER
    logger = logging.getLogger("repro")
    if _DEFAULT_HANDLER is None or _DEFAULT_HANDLER not in logger.handlers:
        handler = logging.StreamHandler(sys.stderr)
        handler.setFormatter(logging.Formatter(
            "%(asctime)s %(name)s %(levelname)s: %(message)s"))
        logger.addHandler(handler)
        _DEFAULT_HANDLER = handler
    logger.setLevel(level)
    return logger
