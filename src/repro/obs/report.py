"""``python -m repro.obs.report out.jsonl`` — render a run summary.

Consumes the JSONL written by :meth:`MetricsRegistry.export_jsonl` (one
``meta`` header line, then one JSON object per metric) and prints the
questions the adaptivity stack exists to answer: how well the straggler
EMA tracked the observed erasure fraction, how much decode-budget headroom
the budget policy left, what the fold window recovered from late
stragglers, what serving admission looked like, and where host time went
per phase.  Sections whose metrics are absent are skipped silently, so the
same report runs on a sync-only, pipeline, serving, or dry-run export.

Optionally pass ``--trace run.trace.json`` to summarize a Chrome-trace
file directly (span count / total duration per name) when the metrics
JSONL was exported without an active registry feeding
``trace.span_seconds``.
"""
from __future__ import annotations

import argparse
import json
from collections import defaultdict
from pathlib import Path

__all__ = ["load_jsonl", "summarize", "main"]


def load_jsonl(path) -> tuple[dict, list[dict]]:
    """Returns ``(meta, entries)``; tolerates a missing meta header."""
    meta: dict = {}
    entries: list[dict] = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        obj = json.loads(line)
        if obj.get("kind") == "meta":
            meta = obj
        else:
            entries.append(obj)
    return meta, entries


def _by_name(entries: list[dict]) -> dict[str, list[dict]]:
    out: dict[str, list[dict]] = defaultdict(list)
    for e in entries:
        out[e.get("name", "?")].append(e)
    return out


def _hist_mean(e: dict) -> float:
    return e["sum"] / e["count"] if e.get("count") else float("nan")


def _fmt(x, nd: int = 3) -> str:
    if x is None:
        return "-"
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return str(x)


def _label(e: dict) -> str:
    labels = e.get("labels") or {}
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels)) or "-"


def summarize(meta: dict, entries: list[dict]) -> str:
    """Build the multi-section text report (what ``main`` prints)."""
    by = _by_name(entries)
    lines: list[str] = []
    add = lines.append

    add("== run ==")
    add(f"  metrics: {len(entries)}"
        + (f"  (exported_unix={meta['exported_unix']:.0f})"
           if "exported_unix" in meta else ""))
    for e in by.get("distributed.steps_total", []):
        add(f"  steps[{_label(e)}]: {int(e['value'])}")
    for e in by.get("serving.finished_total", []):
        add(f"  queries_finished[{_label(e)}]: {int(e['value'])}")

    if "engine.dispatch" in by or "decoder.resolve_total" in by:
        add("")
        add("== engine dispatch ==")
        for e in by.get("engine.dispatch", []):
            info = e.get("info", {})
            add(f"  [{_label(e)}] backend={info.get('backend')} -> "
                f"resolved={info.get('resolved_backend')} "
                f"seeded_mode={info.get('seeded_mode')} "
                f"vmem_est={info.get('vmem_bytes_estimate')}")
        for e in by.get("decoder.resolve_total", []):
            add(f"  resolve[{_label(e)}]: {int(e['value'])}")

    strag = by.get("distributed.straggler.tracking_error", [])
    if strag or "distributed.straggler.observed" in by:
        add("")
        add("== straggler tracking ==")
        for e in by.get("distributed.straggler.observed", []):
            add(f"  observed_fraction[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e))} "
                f"min={_fmt(e.get('min'))} max={_fmt(e.get('max'))}")
        for e in by.get("distributed.straggler.rate_estimate", []):
            add(f"  ema_estimate[{_label(e)}]:    mean={_fmt(_hist_mean(e))}")
        for e in strag:
            add(f"  tracking_error[{_label(e)}]:  mean={_fmt(_hist_mean(e))} "
                f"max={_fmt(e.get('max'))}  (|rate_ema - observed|)")
        for e in by.get("telemetry.straggler_estimator", []):
            info = e.get("info", {})
            add(f"  estimator[{_label(e)}]: rate={_fmt(info.get('rate'))} "
                f"steps={info.get('steps')}")

    budget = by.get("distributed.step.budget", [])
    if budget or "distributed.step.rounds" in by:
        add("")
        add("== decode budget headroom ==")
        for e in by.get("distributed.step.rounds", []):
            add(f"  rounds_used[{_label(e)}]: mean={_fmt(_hist_mean(e))} "
                f"max={_fmt(e.get('max'), 0)}")
        for e in budget:
            add(f"  budget[{_label(e)}]:      mean={_fmt(_hist_mean(e))}")
        for e in by.get("distributed.step.budget_headroom", []):
            add(f"  headroom[{_label(e)}]:    mean={_fmt(_hist_mean(e))} "
                f"min={_fmt(e.get('min'), 0)}  (budget - rounds_used)")
        for e in by.get("distributed.step.unresolved", []):
            add(f"  unresolved[{_label(e)}]:  mean={_fmt(_hist_mean(e))} "
                f"max={_fmt(e.get('max'), 0)}")
        for e in by.get("distributed.wait_for", []):
            add(f"  wait_for[{_label(e)}]:    mean={_fmt(_hist_mean(e))}")

    folds = by.get("pipeline.folds_total", [])
    if folds or "pipeline.arrival_lag" in by:
        add("")
        add("== fold efficacy (async pipeline) ==")
        for e in folds:
            add(f"  folds[{_label(e)}]: {int(e['value'])}")
        for e in by.get("pipeline.fold_rounds_total", []):
            add(f"  fold_rounds[{_label(e)}]: {int(e['value'])}")
        for e in by.get("pipeline.resolved_late_total", []):
            add(f"  late_coords_resolved[{_label(e)}]: {int(e['value'])}")
        for e in by.get("pipeline.arrival_lag", []):
            add(f"  arrival_lag[{_label(e)}]: mean={_fmt(_hist_mean(e))} "
                f"max={_fmt(e.get('max'), 0)}")
        for e in by.get("pipeline.staleness_window", []):
            add(f"  staleness_window[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e))}")
        for e in by.get("pipeline.staleness_weight", []):
            add(f"  staleness_weight[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e))}")

    if "serving.admission_wait_s" in by or "serving.submitted_total" in by:
        add("")
        add("== serving ==")
        for e in by.get("serving.submitted_total", []):
            add(f"  submitted[{_label(e)}]: {int(e['value'])}")
        for e in by.get("serving.admission_wait_s", []):
            add(f"  admission_wait_s[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e), 6)} max={_fmt(e.get('max'), 6)}")
        for e in by.get("serving.slot_occupancy", []):
            add(f"  slot_occupancy[{_label(e)}]: mean={_fmt(_hist_mean(e))}")
        for e in by.get("serving.query.launches", []):
            add(f"  launches_per_query[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e))}")
        for e in by.get("serving.query.rounds", []):
            add(f"  rounds_per_query[{_label(e)}]: "
                f"mean={_fmt(_hist_mean(e))}")

    spans = by.get("trace.span_seconds", [])
    if spans:
        add("")
        add("== per-phase host time ==")
        counts = {_label(e): e for e in by.get("trace.span_count", [])}
        total = sum(e["value"] for e in spans) or 1.0
        for e in sorted(spans, key=lambda e: -e["value"]):
            n = counts.get(_label(e))
            add(f"  {e['labels'].get('name', _label(e)):<24} "
                f"{e['value']:.4f}s  ({100 * e['value'] / total:5.1f}%)"
                + (f"  x{int(n['value'])}" if n else ""))

    if "aot.lower_s" in by or "aot.report" in by:
        add("")
        add("== AOT ==")
        for nm in ("aot.lower_s", "aot.compile_s"):
            for e in by.get(nm, []):
                add(f"  {nm}[{_label(e)}]: {_fmt(e.get('value'))}s")

    return "\n".join(lines)


def summarize_trace(path) -> str:
    """Per-span-name totals straight from a Chrome-trace JSON file."""
    doc = json.loads(Path(path).read_text())
    agg: dict[str, list[float]] = defaultdict(lambda: [0, 0.0])
    for ev in doc.get("traceEvents", []):
        if ev.get("ph") == "X":
            agg[ev["name"]][0] += 1
            agg[ev["name"]][1] += ev.get("dur", 0) * 1e-6
    lines = [f"== trace {path} =="]
    for name, (n, secs) in sorted(agg.items(), key=lambda kv: -kv[1][1]):
        lines.append(f"  {name:<24} {secs:.4f}s  x{n}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Summarize a repro obs JSONL metrics export.")
    ap.add_argument("jsonl", help="metrics JSONL written via --obs-out")
    ap.add_argument("--trace", default=None,
                    help="optional Chrome-trace JSON to summarize as well")
    args = ap.parse_args(argv)
    meta, entries = load_jsonl(args.jsonl)
    print(summarize(meta, entries))
    if args.trace:
        print()
        print(summarize_trace(args.trace))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
