"""Host-side span tracer emitting Chrome/Perfetto ``trace_event`` JSON.

Spans bracket HOST phases of the runtime — worker launch, master decode,
fold dispatch, serving waves, AOT lower/compile — and export as complete
(``"ph": "X"``) events that chrome://tracing and ui.perfetto.dev open
directly.  Two recording styles:

* :func:`span` — a context manager around synchronous host work::

      with span("master/decode", lane="master", step=t):
          ...

* :meth:`Tracer.complete` — async-safe stamping for the pipelined driver:
  the dispatch timestamp is taken when work is enqueued and the complete
  event is emitted later at queue-pull time, where the host is ALREADY
  blocking on fetched values.  No ``block_until_ready`` is ever added to
  measure a span; what is traced is host-observed dispatch→drain latency,
  not device execution.

Like :mod:`repro.obs.metrics`, tracing is off-by-default free: with no
tracer enabled, :func:`span` returns a shared null context manager and the
hot-path cost is one module-attribute read.  When a metrics registry is
also active, each finished span feeds ``trace.span_seconds`` /
``trace.span_count{name=...}`` counters so :mod:`repro.obs.report` can
render a per-phase time breakdown from the JSONL alone.

``Tracer(jax_annotations=True)`` additionally wraps synchronous spans in
``jax.profiler.TraceAnnotation`` so they nest inside real XLA profiler
traces on TPU; jax is imported lazily and only in that mode.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from pathlib import Path

from . import metrics as _metrics

__all__ = [
    "Tracer", "enable_tracing", "disable_tracing", "active_tracer",
    "tracing", "span", "now_us",
]


def now_us() -> int:
    """Monotonic microsecond clock shared by all span timestamps."""
    return time.perf_counter_ns() // 1000


_NULL_CM = contextlib.nullcontext()


class Tracer:
    """Collects ``trace_event`` dicts; :meth:`export` writes the Chrome
    JSON object format (``{"traceEvents": [...]}``).

    Lanes ("worker", "master", "serving", …) map to synthetic thread ids
    so phases stack in separate swimlanes in the viewer; thread-name
    metadata events are emitted at export.
    """

    def __init__(self, *, jax_annotations: bool = False, pid: int = 1):
        self.events: list[dict] = []
        self.pid = pid
        self.jax_annotations = jax_annotations
        self._lanes: dict[str, int] = {}
        self._lock = threading.Lock()
        self._annot = None
        if jax_annotations:
            from jax.profiler import TraceAnnotation  # lazy: CPU CI safe
            self._annot = TraceAnnotation

    def lane(self, name: str) -> int:
        tid = self._lanes.get(name)
        if tid is None:
            with self._lock:
                tid = self._lanes.setdefault(name, len(self._lanes) + 1)
        return tid

    def _feed_metrics(self, name: str, dur_us: float) -> None:
        reg = _metrics.active()
        if reg is not None:
            reg.counter("trace.span_seconds", name=name).inc(dur_us * 1e-6)
            reg.counter("trace.span_count", name=name).inc()

    @contextlib.contextmanager
    def span(self, name: str, lane: str = "main", **args):
        """Time a synchronous host block as one complete event."""
        annot = self._annot(name) if self._annot is not None else _NULL_CM
        t0 = now_us()
        try:
            with annot:
                yield self
        finally:
            self.complete(name, t0, now_us() - t0, lane=lane, **args)

    def complete(self, name: str, ts_us: int, dur_us: int,
                 lane: str = "main", **args) -> None:
        """Record a finished span from externally-captured timestamps —
        the async stamping entry point (zero synchronization here)."""
        ev = {"ph": "X", "name": name, "pid": self.pid,
              "tid": self.lane(lane), "ts": int(ts_us),
              "dur": max(int(dur_us), 0)}
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self.events.append(ev)
        self._feed_metrics(name, dur_us)

    def instant(self, name: str, lane: str = "main", **args) -> None:
        ev = {"ph": "i", "name": name, "pid": self.pid,
              "tid": self.lane(lane), "ts": now_us(), "s": "t"}
        if args:
            ev["args"] = {k: _jsonable(v) for k, v in args.items()}
        self.events.append(ev)

    def export(self, path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        meta = [{"ph": "M", "name": "process_name", "pid": self.pid,
                 "tid": 0, "args": {"name": "repro"}}]
        for lane_name, tid in sorted(self._lanes.items(), key=lambda kv: kv[1]):
            meta.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                         "tid": tid, "args": {"name": lane_name}})
        doc = {"traceEvents": meta + self.events, "displayTimeUnit": "ms"}
        path.write_text(json.dumps(doc))
        return path


def _jsonable(v):
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    try:
        return v.item()  # numpy / 0-d jax host scalars
    except AttributeError:
        return str(v)


# ----------------------------------------------------- process-local switch

_active: Tracer | None = None


def enable_tracing(tracer: Tracer | None = None, **kw) -> Tracer:
    """Install ``tracer`` (or ``Tracer(**kw)``) as the process-local tracer."""
    global _active
    _active = tracer if tracer is not None else Tracer(**kw)
    return _active


def disable_tracing() -> Tracer | None:
    global _active
    tr, _active = _active, None
    return tr


def active_tracer() -> Tracer | None:
    return _active


@contextlib.contextmanager
def tracing(tracer: Tracer | None = None, **kw):
    """Scope a tracer around a block, restoring the previous one after."""
    global _active
    prev = _active
    tr = tracer if tracer is not None else Tracer(**kw)
    _active = tr
    try:
        yield tr
    finally:
        _active = prev


def span(name: str, lane: str = "main", **args):
    """Module-level span: delegates to the active tracer, or returns a
    shared null context when tracing is off (the free path)."""
    tr = _active
    if tr is None:
        return _NULL_CM
    return tr.span(name, lane=lane, **args)
