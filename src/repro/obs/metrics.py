"""Process-local metrics registry: counters, gauges, fixed-bin histograms.

The adaptivity stack (EMA straggler telemetry → decode budgets →
wait-for/staleness policy → per-slot adaptive kernels) makes per-step
decisions all over the runtime; this module gives every layer one place to
record them.  Deliberately dependency-free (numpy only — no jax import, no
exporter daemons): a :class:`MetricsRegistry` is a plain in-process object
holding Prometheus-shaped metrics keyed on ``(name, labels)``, with
``snapshot()`` dicts for tests and a JSONL export for the ``--obs-out``
CLI surfaces and :mod:`repro.obs.report`.

Instrumentation sites follow one pattern so that observability is
OFF-BY-DEFAULT FREE::

    reg = metrics.active()
    if reg is not None:
        reg.counter("distributed.steps_total", driver="sync").inc()

With no registry enabled the cost is a module-attribute read and a None
check; nothing is allocated, nothing is traced — instrumented jitted
programs are bit-identical to uninstrumented ones because recording only
ever touches ALREADY-FETCHED host values.

Activation is process-local: :func:`enable` installs a registry,
:func:`disable` removes it, :func:`recording` scopes one around a block
(restoring whatever was active before).  Histograms use fixed bin edges
fixed at creation (numpy ``searchsorted`` buckets); the shared edge
constants below keep the same quantity comparable across layers.
"""
from __future__ import annotations

import contextlib
import json
import threading
import time
from pathlib import Path

import numpy as np

__all__ = [
    "Counter", "Gauge", "Histogram", "Info", "MetricsRegistry",
    "enable", "disable", "active", "recording",
    "ROUND_BINS", "FRACTION_BINS", "COUNT_BINS", "LAG_BINS", "LATENCY_BINS",
]

# Shared histogram edges so the same quantity buckets identically across
# layers: decode rounds / budgets / headroom; fractions in [0, 1] (rates,
# occupancy, tracking error); small counts (unresolved coords, wait-for,
# launches); arrival lags in step units; host latencies in seconds.
ROUND_BINS = (0, 1, 2, 4, 8, 16, 32, 64, 128)
FRACTION_BINS = tuple(np.round(np.linspace(0.0, 1.0, 11), 2))
COUNT_BINS = (0, 1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)
LAG_BINS = (0, 1, 2, 3, 4, 6, 8, 12, 16)
LATENCY_BINS = tuple(1e-6 * 4.0 ** i for i in range(13))  # 1 µs … ~17 s


class Counter:
    """Monotonically increasing float total."""

    kind = "counter"

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError(f"counters only go up; got {amount}")
        self.value += float(amount)

    def payload(self) -> dict:
        return {"value": self.value}


class Gauge:
    """Last-write-wins scalar."""

    kind = "gauge"

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.value = 0.0
        self.updated = False

    def set(self, value: float) -> None:
        self.value = float(value)
        self.updated = True

    def payload(self) -> dict:
        return {"value": self.value, "updated": self.updated}


class Histogram:
    """Fixed-bin histogram: ``E`` edges define ``E+1`` buckets
    ``(-inf, e0], (e0, e1], …, (e_{E-1}, inf)`` via ``searchsorted``."""

    kind = "histogram"

    def __init__(self, name: str, labels: dict, bins):
        edges = np.asarray(bins, float)
        if edges.ndim != 1 or edges.size < 2:
            raise ValueError(f"histogram {name!r} needs >= 2 bin edges")
        if not (np.diff(edges) > 0).all():
            raise ValueError(f"histogram {name!r} bin edges must increase")
        self.name, self.labels = name, labels
        self.bins = edges
        self.counts = np.zeros(edges.size + 1, np.int64)
        self.total = 0.0
        self.count = 0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        self.observe_many(np.asarray([value], float))

    def observe_many(self, values) -> None:
        v = np.asarray(values, float).ravel()
        if v.size == 0:
            return
        idx = np.searchsorted(self.bins, v, side="left")
        np.add.at(self.counts, idx, 1)
        self.total += float(v.sum())
        self.count += int(v.size)
        self.min = min(self.min, float(v.min()))
        self.max = max(self.max, float(v.max()))

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else float("nan")

    def payload(self) -> dict:
        return {
            "bins": [float(e) for e in self.bins],
            "counts": [int(c) for c in self.counts],
            "sum": self.total, "count": self.count,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }


class Info:
    """A structured one-shot fact (e.g. an engine's resolved dispatch, an
    estimator's :meth:`snapshot`), last write wins."""

    kind = "info"

    def __init__(self, name: str, labels: dict):
        self.name, self.labels = name, labels
        self.info: dict = {}

    def set(self, mapping: dict) -> None:
        self.info = dict(mapping)

    def payload(self) -> dict:
        return {"info": self.info}


def _render(name: str, labels: dict) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Get-or-create metric store keyed on ``(name, frozen labels)``.

    Label values are stringified into the key (and the rendered name), so
    ``histogram("x", driver="sync")`` and ``histogram("x",
    driver="pipeline")`` are distinct series of one metric family.
    Thread-safe creation; individual metric updates are plain Python ops
    (the driver loops are single-threaded hosts).
    """

    def __init__(self):
        self._metrics: dict[tuple, object] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: dict, **kw):
        labels = {k: str(v) for k, v in labels.items()}
        key = (name, tuple(sorted(labels.items())))
        m = self._metrics.get(key)
        if m is None:
            with self._lock:
                m = self._metrics.get(key)
                if m is None:
                    m = cls(name, labels, **kw)
                    self._metrics[key] = m
        if not isinstance(m, cls):
            raise ValueError(f"metric {_render(name, labels)!r} already "
                             f"registered as a {m.kind}, not a {cls.kind}")
        return m

    def counter(self, name: str, /, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, /, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, /, bins=None, **labels) -> Histogram:
        labels_s = {k: str(v) for k, v in labels.items()}
        key = (name, tuple(sorted(labels_s.items())))
        existing = self._metrics.get(key)
        if existing is not None:
            if not isinstance(existing, Histogram):
                raise ValueError(
                    f"metric {_render(name, labels_s)!r} already registered "
                    f"as a {existing.kind}, not a histogram")
            if bins is not None and not np.array_equal(
                    existing.bins, np.asarray(bins, float)):
                raise ValueError(f"histogram {_render(name, labels_s)!r} "
                                 "re-registered with different bin edges")
            return existing
        if bins is None:
            raise ValueError(f"histogram {_render(name, labels_s)!r} needs "
                             "bins= at first registration")
        return self._get(Histogram, name, labels, bins=bins)

    def info(self, name: str, /, mapping: dict | None = None, **labels) -> Info:
        m = self._get(Info, name, labels)
        if mapping is not None:
            m.set(mapping)
        return m

    def get(self, name: str, /, **labels):
        """Existing metric or None (never creates)."""
        labels = {k: str(v) for k, v in labels.items()}
        return self._metrics.get((name, tuple(sorted(labels.items()))))

    def __len__(self) -> int:
        return len(self._metrics)

    def snapshot(self) -> dict[str, dict]:
        """``{rendered_name: {"kind", "name", "labels", **payload}}`` —
        plain JSON-ready dicts, fully decoupled from the live metrics."""
        out = {}
        for (name, _), m in sorted(self._metrics.items(),
                                   key=lambda kv: _render(kv[0][0],
                                                          dict(kv[0][1]))):
            out[_render(name, m.labels)] = {
                "kind": m.kind, "name": name, "labels": dict(m.labels),
                **m.payload(),
            }
        return out

    def export_jsonl(self, path) -> Path:
        """One JSON object per line: a ``meta`` header, then every metric
        (the format :mod:`repro.obs.report` consumes)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        lines = [json.dumps({"kind": "meta", "schema": 1,
                             "exported_unix": time.time(),
                             "n_metrics": len(self._metrics)})]
        for entry in self.snapshot().values():
            lines.append(json.dumps(entry))
        path.write_text("\n".join(lines) + "\n")
        return path


# ----------------------------------------------------- process-local switch

_active: MetricsRegistry | None = None


def enable(registry: MetricsRegistry | None = None) -> MetricsRegistry:
    """Install ``registry`` (or a fresh one) as the process-local sink that
    every instrumentation site records into.  Returns it."""
    global _active
    _active = registry if registry is not None else MetricsRegistry()
    return _active


def disable() -> MetricsRegistry | None:
    """Remove the active registry (instrumentation goes back to free
    no-ops); returns the registry that was active, if any."""
    global _active
    reg, _active = _active, None
    return reg


def active() -> MetricsRegistry | None:
    """The currently-enabled registry, or None — THE hot-path check."""
    return _active


@contextlib.contextmanager
def recording(registry: MetricsRegistry | None = None):
    """Scope a registry around a block, restoring the previous one after."""
    global _active
    prev = _active
    reg = registry if registry is not None else MetricsRegistry()
    _active = reg
    try:
        yield reg
    finally:
        _active = prev
