"""The continuous-admission slot lifecycle, factored out ONCE.

Every continuous-admission decode driver shares the same slot-pool state
machine: a fixed pool of ``B`` decode slots, each slot owned by at most one
in-flight query; per launch, every occupied slot is granted at most a chunk
of its remaining round budget; after the launch, a slot retires when its
query converged (early exit or nothing left erased) or exhausted its total
budget, and free slots refill from a FIFO queue.  Until this module the
state machine was hand-kept in two places —
:class:`repro.serving.coded_queries.CodedQueryBatcher._step_continuous` and
``benchmarks/decoder_scaling._serve_continuous`` — with a "keep in sync"
comment; both now drive this one :class:`SlotPool` (as does the
distributed benchmark's master decode-stream driver), so the admission
order, budget chunking, and retire condition exist exactly once.

:class:`SlotPool` owns the HOST-side bookkeeping only (who occupies which
slot, rounds spent, per-slot chunk sizes); callers own the device-resident
decode state and the jitted launch functions, which is what keeps the
helper reusable across the batcher (gradient queries with encode/epilogue)
and the benchmarks (raw decode streams).

Per-slot chunk sizes support the priority scheduler: a query admitted with
``chunk=`` larger than the pool default gets proportionally more peeling
rounds per launch (see ``CodedQueryBatcher``'s priority-weighted chunking).
"""
from __future__ import annotations

from typing import Any, Iterator

import numpy as np

from repro.obs import metrics as _obs_metrics

__all__ = ["SlotPool"]


class SlotPool:
    """Host-side slot lifecycle for continuous-admission decode serving.

    ``n_slots`` decode slots, each query granted a total round ``budget``
    and at most its per-slot chunk (default ``rounds_per_launch``) of it
    per launch.  The caller loop is always::

        while pool.active or queue:
            for s in pool.free_slots():              # FIFO refill
                pool.admit(s, owner, chunk=...)      # caller stages state
            budgets = pool.launch_budgets()          # (B,) int32, 0 = inert
            ... one batched adaptive decode launch under ``budgets`` ...
            for s, owner in pool.account(rounds, unresolved):
                ... owner retired: pull its results, free slot ...

    Retire condition (the one previously hand-copied): a slot retires when
    its launch early-exited (``rounds < granted budget``), nothing is left
    erased (``unresolved == 0``), or its total budget is exhausted
    (``used >= budget``).  A slot whose fixpoint lands exactly on its chunk
    boundary is detected one launch later via a no-progress probe round —
    the same probe the sequential adaptive decode charges, keeping
    per-query rounds accounting parity-exact.
    """

    def __init__(self, n_slots: int, budget: int,
                 rounds_per_launch: int | None = None):
        if n_slots < 1:
            raise ValueError(f"need at least one slot; got {n_slots}")
        self.n_slots = int(n_slots)
        self.budget = int(budget)
        self.default_chunk = (self.budget if rounds_per_launch is None
                              else int(rounds_per_launch))
        if self.default_chunk < 1:
            raise ValueError("rounds_per_launch must be >= 1")
        self._owner: list[Any | None] = [None] * self.n_slots
        self._used = np.zeros(self.n_slots, np.int32)
        self._chunk = np.full(self.n_slots, self.default_chunk, np.int32)
        self._granted = np.zeros(self.n_slots, np.int32)

    # ------------------------------------------------------------- occupancy

    @property
    def occupied(self) -> np.ndarray:
        """(B,) bool — slots currently owned by an in-flight query."""
        return np.array([o is not None for o in self._owner])

    @property
    def active(self) -> bool:
        return any(o is not None for o in self._owner)

    def owner(self, s: int) -> Any | None:
        return self._owner[s]

    def owners(self) -> Iterator[tuple[int, Any]]:
        """(slot, owner) for every occupied slot, in slot order."""
        for s, o in enumerate(self._owner):
            if o is not None:
                yield s, o

    def free_slots(self) -> list[int]:
        return [s for s, o in enumerate(self._owner) if o is None]

    def rounds_spent(self, s: int) -> int:
        return int(self._used[s])

    # -------------------------------------------------------------- lifecycle

    def admit(self, s: int, owner: Any, *, chunk: int | None = None) -> None:
        """Seat ``owner`` in free slot ``s`` with a fresh budget; ``chunk``
        overrides the pool's per-launch default (priority scheduling)."""
        if self._owner[s] is not None:
            raise ValueError(f"slot {s} is occupied")
        if owner is None:
            raise ValueError("owner must not be None (None marks free slots)")
        self._owner[s] = owner
        self._used[s] = 0
        self._chunk[s] = self.default_chunk if chunk is None \
            else max(1, int(chunk))

    def launch_budgets(self) -> np.ndarray:
        """(B,) int32 per-slot round grants for the next launch: each
        occupied slot gets at most its chunk of its remaining budget; free
        slots get 0 (inert — the decode passes their rows through)."""
        grant = np.where(self.occupied,
                         np.minimum(self._chunk, self.budget - self._used),
                         0).astype(np.int32)
        self._granted = grant
        return grant

    def account(self, rounds: np.ndarray, unresolved: np.ndarray
                ) -> list[tuple[int, Any]]:
        """Fold one launch's per-slot stats back in; frees and returns the
        retired ``(slot, owner)`` pairs in slot order.

        ``rounds`` / ``unresolved`` are the launch's (B,) per-slot rounds
        spent and post-decode unresolved counts (free slots' entries are
        ignored).  Must follow a :meth:`launch_budgets` call — the retire
        test compares against the budgets actually granted.
        """
        rounds = np.asarray(rounds)
        unresolved = np.asarray(unresolved)
        retired: list[tuple[int, Any]] = []
        for s, owner in self.owners():
            self._used[s] += int(rounds[s])
            converged = (int(rounds[s]) < int(self._granted[s])
                         or int(unresolved[s]) == 0)
            if converged or int(self._used[s]) >= self.budget:
                retired.append((s, owner))
        reg = _obs_metrics.active()
        if reg is not None and retired:
            reg.counter("serving.slots_retired_total").inc(len(retired))
            h = reg.histogram("serving.slot_rounds",
                              bins=_obs_metrics.ROUND_BINS)
            for s, _ in retired:
                h.observe(int(self._used[s]))
        for s, _ in retired:
            self._owner[s] = None
        return retired
