"""KV-cache / recurrent-state construction for every mixer family.

Cache layouts:
  attn : {"k","v": (B, W, KV, Dh), "pos": (W,) int32, "length": ()}
         W = full max_len, or the sliding window for long-context decode
         (ring buffer; "pos" tracks the absolute position held in each slot,
          initialized to INT32_MAX = invalid).
  mla  : {"c_kv": (B, W, kv_lora), "k_rope": (B, W, qk_rope), "pos", "length"}
         — the absorbed-latent cache (576 dims/token for DeepSeek-V2).
  mamba: {"h": (B, d_in, d_state) f32, "conv": (B, d_conv-1, d_in)}
  rwkv : {"S": (B, H, Dh, Dh) f32, "last_x": (B, d)} (+ "cm_last_x" for the
         channel mix) — O(1) in sequence length.
  cross: {"k","v": (B, T_enc, KV, Dh), "pos": (T_enc,)} — read-only after
         prefill (whisper encoder keys/values).
"""
from __future__ import annotations

import jax.numpy as jnp

INVALID_POS = jnp.iinfo(jnp.int32).max

__all__ = ["INVALID_POS", "make_attn_cache", "make_mla_cache", "make_mamba_state",
           "make_rwkv_state", "make_cross_cache", "make_layer_cache"]


def make_attn_cache(B: int, window: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((B, window, n_kv, head_dim), dtype),
        "v": jnp.zeros((B, window, n_kv, head_dim), dtype),
        "pos": jnp.full((window,), INVALID_POS, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def make_mla_cache(B: int, window: int, kv_lora: int, qk_rope: int, dtype):
    return {
        "c_kv": jnp.zeros((B, window, kv_lora), dtype),
        "k_rope": jnp.zeros((B, window, qk_rope), dtype),
        "pos": jnp.full((window,), INVALID_POS, jnp.int32),
        "length": jnp.zeros((), jnp.int32),
    }


def make_mamba_state(B: int, d_model: int, spec, dtype):
    d_in = spec.expand * d_model
    return {
        "h": jnp.zeros((B, d_in, spec.d_state), jnp.float32),
        "conv": jnp.zeros((B, spec.d_conv - 1, d_in), dtype),
    }


def make_rwkv_state(B: int, d_model: int, spec, dtype):
    H = d_model // spec.head_dim
    return {
        "S": jnp.zeros((B, H, spec.head_dim, spec.head_dim), jnp.float32),
        "last_x": jnp.zeros((B, d_model), dtype),
        "cm_last_x": jnp.zeros((B, d_model), dtype),
    }


def make_cross_cache(B: int, enc_seq: int, n_kv: int, head_dim: int, dtype):
    return {
        "k": jnp.zeros((B, enc_seq, n_kv, head_dim), dtype),
        "v": jnp.zeros((B, enc_seq, n_kv, head_dim), dtype),
        "pos": jnp.arange(enc_seq, dtype=jnp.int32),
        "length": jnp.asarray(enc_seq, jnp.int32),
    }


def make_layer_cache(cfg, mixer: str, B: int, window: int, dtype):
    """Cache for one layer of the given mixer type (see ArchConfig)."""
    if mixer == "attn":
        c = make_attn_cache(B, window, cfg.n_kv_heads, cfg.hd, dtype)
        if cfg.enc_layers:  # enc-dec: self cache + (placeholder) cross cache
            c = {"self": c,
                 "cross": make_cross_cache(B, cfg.enc_seq, cfg.n_kv_heads, cfg.hd, dtype)}
        return c
    if mixer == "mla":
        return make_mla_cache(B, window, cfg.mla.kv_lora, cfg.mla.qk_rope, dtype)
    if mixer == "mamba":
        return make_mamba_state(B, cfg.d_model, cfg.mamba, dtype)
    if mixer == "rwkv":
        return make_rwkv_state(B, cfg.d_model, cfg.rwkv, dtype)
    raise ValueError(mixer)
