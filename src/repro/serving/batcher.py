"""Batched-request serving scheduler (wave/static batching with early exit).

A fixed pool of B decode slots advances in LOCKSTEP — one jit'd
``decode_step`` per tick for the whole batch, all slots at the same
position (so the shared KV cache layout stays exact).  Requests are
admitted in waves: up to B requests start together at position 0; slots
whose prompt is shorter switch to generation while others are still
feeding their prompt; slots that finish early idle (their writes land in
cache rows that their own queries never attend beyond, and their outputs
are ignored) until the wave drains, then the next wave is admitted.

This is the honest CPU-scale "serve a small model with batched requests"
driver (examples/serve_batched.py).  Per-slot *asynchronous* positions
(true continuous batching) would need a per-batch position vector through
the cache layer — noted as future work in DESIGN.md; the production-scale
single-wave decode path is exactly what decode_32k / long_500k lower.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # avoid circular import (models -> serving.kvcache -> here)
    from repro.models import Model

__all__ = ["Request", "WaveBatcher"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    eos: int | None = None
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class WaveBatcher:
    def __init__(self, model: "Model", params, *, n_slots: int = 4,
                 max_len: int = 128):
        cfg = model.cfg
        if cfg.family in ("audio", "vlm"):
            raise NotImplementedError("batcher demo covers text decoders")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.queue: deque[Request] = deque()
        self.finished: list[Request] = []
        self._step = jax.jit(model.decode_step)
        self.ticks = 0

    def submit(self, req: Request):
        self.queue.append(req)

    @property
    def active(self) -> bool:
        return bool(self.queue)

    def _run_wave(self, wave: list[Request], max_ticks: int):
        cache = self.model.init_cache(self.n_slots, self.max_len)
        pending = [list(r.prompt) for r in wave]
        live = [True] * len(wave)
        tokens = np.zeros((self.n_slots, 1), np.int32)
        for s, r in enumerate(wave):
            tokens[s, 0] = pending[s].pop(0)
        pos = 0
        while any(live) and pos < self.max_len - 1 and self.ticks < max_ticks:
            self.ticks += 1
            logits, cache = self._step(self.params, jnp.asarray(tokens),
                                       jnp.int32(pos), cache)
            ln = np.asarray(logits[:, 0], np.float32)
            pos += 1
            for s, r in enumerate(wave):
                if not live[s]:
                    continue
                if pending[s]:               # still feeding the prompt
                    tokens[s, 0] = pending[s].pop(0)
                    continue
                nxt = int(np.argmax(ln[s]))  # greedy generation
                r.out.append(nxt)
                tokens[s, 0] = nxt
                if (r.eos is not None and nxt == r.eos) or \
                        len(r.out) >= r.max_new:
                    r.done = True
                    live[s] = False
                    self.finished.append(r)
        for s, r in enumerate(wave):  # drain anything cut off by max_len
            if live[s]:
                r.done = True
                self.finished.append(r)

    def run(self, max_ticks: int = 100_000) -> list[Request]:
        while self.queue and self.ticks < max_ticks:
            wave = [self.queue.popleft()
                    for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave, max_ticks)
        return self.finished
