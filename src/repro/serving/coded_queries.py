"""Continuous-admission slot server for concurrent coded-compute queries.

The serving-side counterpart of :class:`repro.serving.batcher.WaveBatcher`,
for the paper's workload instead of token decoding: clients submit coded
matvec/gradient queries — each a ``(θ, straggler_mask)`` pair with its OWN
independent straggler realization — and the batcher serves them through
batched encode→erase→decode→epilogue launches over a fixed pool of ``B``
decode slots.  Two admission policies share the pool:

``mode="continuous"`` (default)
    Slots retire and refill INDEPENDENTLY between launches, mirroring
    WaveBatcher's slot model.  Every launch advances each in-flight slot by
    at most ``rounds_per_launch`` peeling rounds via the PER-SLOT adaptive
    batched decode (:meth:`repro.core.engine.CodedComputeEngine.decode_batch`
    with ``adaptive=True`` and a per-slot round-budget vector): a
    light-straggler query converges inside its first launch and its slot is
    refilled from the FIFO queue, while a heavy query keeps its slot across
    launches — light queries never wait on a heavy query's decode rounds.
    The slot lifecycle itself (admission, budget chunking, retirement) is
    the shared :class:`repro.serving.slot_lifecycle.SlotPool` state
    machine, and each query's ``priority`` hint scales its per-launch
    chunk (priority-weighted budget scheduling: urgent queries finish in
    fewer launches for the same total budget).
    Slot state (partial values, erasure mask, rounds spent) carries across
    launches; per-query accounting (``rounds``, ``launches``,
    ``admitted_launch`` / ``finished_launch``) makes the fairness and cost
    claims observable, and tested.  With ``backend="pallas"`` every launch
    is still ONE ``pallas_call`` (grid over slots, H resident in VMEM,
    budgets a traced operand — no recompiles as budgets vary).

``mode="lockstep"``
    The PR-2 wave policy, kept as the measured baseline: queries flush in
    waves of ``B`` through one fixed-budget batched launch
    (:meth:`repro.core.coded_step.Scheme2.gradient_batch`); the whole wave
    pays the worst-case round budget and refills only when it drains.

Both modes pad partial occupancy with inert slots (θ = 0, no stragglers,
round budget 0) so each jitted launch function compiles ONCE and is reused
for every launch.  ``launches`` counts the batched decode launches actually
issued — the efficiency claims (B queries per launch; per-query decode cost
tracking realized stragglers) are observable, and tested.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.decoder import DecodeResult
from repro.core.schedule_cache import ScheduleCache
from repro.obs import metrics as _obs_metrics
from repro.obs.trace import span as _span
from repro.serving.slot_lifecycle import SlotPool

__all__ = ["CodedQuery", "CodedQueryBatcher"]

MODES = ("continuous", "lockstep")


@dataclasses.dataclass
class CodedQuery:
    """One coded gradient query: evaluate ∇L̂(θ) under a straggler mask."""

    qid: int
    theta: np.ndarray            # (k,)
    straggler_mask: np.ndarray   # (N,) bool — this query's erasure pattern
    # Priority/deadline hint: 1.0 = normal; >1 = more urgent (deadline
    # near).  Continuous mode grants the slot ``priority ×`` the pool's
    # per-launch round chunk, so urgent queries burn through their decode
    # budget in fewer launches (priority-weighted chunking — the minimal
    # budget scheduler; total budget is unchanged, so results are too).
    priority: float = 1.0
    gradient: np.ndarray | None = None
    unresolved: int = -1
    done: bool = False
    # per-query serving stats (filled by the batcher):
    rounds: int = 0              # decode rounds charged to this query
    #                              (-1: lockstep wave of an adaptive scheme —
    #                               per-slot rounds unknown at this layer)
    launches: int = 0            # batched launches this query rode in
    admitted_launch: int = -1    # launch index at slot admission
    finished_launch: int = -1    # launch index at retirement
    submitted_s: float = -1.0    # host clock at submit() (-1: never queued)


class CodedQueryBatcher:
    """Slot-pool serving of coded queries over one shared scheme.

    ``scheme`` is any engine-backed scheme exposing
    ``gradient_batch(theta_B, mask_B)`` (e.g.
    :class:`repro.core.coded_step.Scheme2`); continuous mode additionally
    drives the scheme's engine stages directly (``C`` / ``b`` / ``engine``)
    so partial decode state can live across launches.  All queries share the
    scheme's code and encoded operator; each brings its own straggler
    realization.  ``scheme.decode_iters`` is the per-query total round
    budget in both modes; ``rounds_per_launch`` (continuous only, default
    the full budget) caps how many rounds one launch may spend per slot —
    smaller chunks retire/refill slots more often, bounding how long a
    light query can be stuck behind a heavy one.
    """

    def __init__(self, scheme, *, n_slots: int = 8, mode: str = "continuous",
                 rounds_per_launch: int | None = None):
        if mode not in MODES:
            raise ValueError(f"unknown mode {mode!r}; want one of {MODES}")
        if not hasattr(scheme, "gradient_batch"):
            raise TypeError(
                f"{type(scheme).__name__} has no gradient_batch; the coded "
                "batcher needs an engine-backed scheme (e.g. Scheme2)")
        if mode == "continuous" and not all(
                hasattr(scheme, a)
                for a in ("engine", "C", "finish_gradient",
                          "worker_mask_to_erasure")):
            raise TypeError(
                f"{type(scheme).__name__} does not expose engine/C/"
                "finish_gradient/worker_mask_to_erasure; continuous "
                "admission needs the engine stages directly")
        self.scheme = scheme
        self.mode = mode
        self.n_slots = n_slots
        self.budget = int(scheme.decode_iters)
        self.rounds_per_launch = (self.budget if rounds_per_launch is None
                                  else int(rounds_per_launch))
        if self.mode == "continuous" and self.rounds_per_launch < 1:
            raise ValueError("rounds_per_launch must be >= 1")
        # Replay serving: each slot's decode is the straight-line replay of
        # its pattern's compiled schedule — there is no round loop to chunk,
        # and carrying partially-peeled state across launches would key the
        # schedule cache on transient partial masks (correct, but every
        # lookup a miss).  Grant the full budget per launch so every slot
        # retires in its admission launch and the cache keys stay the
        # admission-time straggler patterns.
        self._replay = (mode == "continuous"
                        and getattr(scheme, "decode_backend", "") == "replay")
        if self._replay and self.rounds_per_launch < self.budget:
            raise ValueError(
                "backend='replay' serving is straight-line schedule replay: "
                f"rounds_per_launch ({self.rounds_per_launch}) must cover "
                f"the full budget ({self.budget}) so slots never carry "
                "partial decode state across launches")
        self.queue: deque[CodedQuery] = deque()
        self.finished: list[CodedQuery] = []
        self.launches = 0   # batched decode launches issued
        self.traces = 0     # jit traces of the launch fn (1 == compiled once)
        self._k = int(scheme.C.shape[1])
        self._N = int(scheme.w)
        if mode == "lockstep":
            self._flush = self._make_lockstep_flush()
        else:
            self._init, self._launch = self._make_continuous_fns()
            B = n_slots
            # slot lifecycle (admission, budget chunking, retirement) is
            # the SHARED state machine — serving/slot_lifecycle.SlotPool —
            # also driven by the benchmarks' decode-stream servers.
            self.pool = SlotPool(B, self.budget, self.rounds_per_launch)
            self._theta = np.zeros((B, self._k), np.float32)
            self._mask = np.zeros((B, self._N), bool)
            # decode state is DEVICE-RESIDENT across launches (inert slots
            # get budget 0, so launch outputs pass their rows through);
            # the host pulls only (B,) stats and retired slots' gradients.
            self._vals = jnp.zeros((B, self._N), jnp.float32)
            self._erased = jnp.zeros((B, self._N), bool)
            self._fresh = np.zeros((B,), bool)

    # ------------------------------------------------------- jitted launches

    def _make_lockstep_flush(self):
        scheme = self.scheme

        def flush(th, m):
            self.traces += 1  # trace-time side effect: counts compilations
            return scheme.gradient_batch(th, m)

        return jax.jit(flush)

    def _make_continuous_fns(self):
        scheme = self.scheme
        eng = scheme.engine
        if self._replay and eng.schedule_cache is None:
            # the scheme did not bring a cache: give the batcher its own,
            # so per-slot patterns still hit across admissions
            eng = dataclasses.replace(eng, schedule_cache=ScheduleCache())
        self.schedule_cache = eng.schedule_cache if self._replay else None
        C = jnp.asarray(scheme.C)

        def init(theta_B, mask_B, vals_B, erased_B, fresh_B):
            # Admission-time encode: fresh slots start from their worker
            # products (erased through the scheme's mask→erasure hook, as
            # gradient_batch does); in-flight slots keep their carried
            # partial decode state.  Called only on launches that admitted
            # — heavy queries' tail launches skip the (B, k) @ (k, N)
            # matvec.
            Z = theta_B @ C.T                               # (B, N)
            erased_new = jax.vmap(scheme.worker_mask_to_erasure)(mask_B)
            vals = jnp.where(fresh_B[:, None],
                             eng.erase(Z, erased_new), vals_B)
            er = jnp.where(fresh_B[:, None], erased_new, erased_B)
            return vals, er

        def launch(vals, er, budgets_B):
            self.traces += 1  # trace-time side effect: counts compilations
            dec = eng.decode_batch(vals, er, adaptive=True,
                                   budgets=budgets_B)
            c_hat, unresolved = eng.systematic(dec)
            # the scheme's own epilogue (zero-filled b̂ + debias) — shared
            # with gradient / gradient_batch, so the rules cannot diverge
            g, n_unres = scheme.finish_gradient(c_hat, unresolved)
            return (dec.values, dec.erased, dec.rounds_used, g, n_unres,
                    dec.erased.sum(axis=1))

        if not self._replay:
            return jax.jit(init), jax.jit(launch)

        # Replay dispatch needs the CONCRETE per-slot masks (the schedule
        # cache keys on the packed pattern), so the launch stays eager at
        # this level: the engine looks each slot's schedule up (hit → no
        # solve) and the replay executors jit internally keyed on the
        # schedules' segment shapes.  Only the value-level epilogue is
        # jitted here.
        @jax.jit
        def epilogue(values, erased, rounds_used):
            c_hat, unresolved = eng.systematic(
                DecodeResult(values, erased, rounds_used))
            g, n_unres = scheme.finish_gradient(c_hat, unresolved)
            return g, n_unres

        def replay_launch(vals, er, budgets_B):
            dec = eng.decode_batch(vals, er, adaptive=True,
                                   budgets=budgets_B)
            g, n_unres = epilogue(dec.values, dec.erased, dec.rounds_used)
            return (dec.values, dec.erased, dec.rounds_used, g, n_unres,
                    dec.erased.sum(axis=1))

        return jax.jit(init), replay_launch

    # ---------------------------------------------------------------- intake

    def submit(self, query: CodedQuery) -> None:
        if query.theta.shape != (self._k,):
            raise ValueError(f"theta must be ({self._k},); got {query.theta.shape}")
        if query.straggler_mask.shape != (self._N,):
            raise ValueError(
                f"straggler_mask must be ({self._N},); got {query.straggler_mask.shape}")
        query.submitted_s = time.perf_counter()
        reg = _obs_metrics.active()
        if reg is not None:
            reg.counter("serving.submitted_total", mode=self.mode).inc()
        self.queue.append(query)

    def _record_finished(self, queries) -> None:
        """Retirement-time accounting (host data only: the per-query stats
        were already pulled to fill the CodedQuery fields)."""
        reg = _obs_metrics.active()
        if reg is None:
            return
        reg.counter("serving.finished_total",
                    mode=self.mode).inc(len(queries))
        h_launch = reg.histogram("serving.query.launches",
                                 bins=_obs_metrics.COUNT_BINS, mode=self.mode)
        h_rounds = reg.histogram("serving.query.rounds",
                                 bins=_obs_metrics.ROUND_BINS, mode=self.mode)
        for q in queries:
            h_launch.observe(q.launches)
            if q.rounds >= 0:   # -1: adaptive lockstep, per-slot unknown
                h_rounds.observe(q.rounds)

    def _record_admitted(self, queries) -> None:
        """Queue→slot admission latency (host wall-clock since submit)."""
        reg = _obs_metrics.active()
        if reg is None:
            return
        now = time.perf_counter()
        h = reg.histogram("serving.admission_wait_s",
                          bins=_obs_metrics.LATENCY_BINS, mode=self.mode)
        for q in queries:
            if q.submitted_s >= 0.0:
                h.observe(now - q.submitted_s)

    @property
    def active(self) -> bool:
        if self.mode == "continuous" and self.pool.active:
            return True
        return bool(self.queue)

    # ------------------------------------------------------------- lockstep

    def _run_wave(self, wave: list[CodedQuery]) -> None:
        B = self.n_slots
        theta_B = np.zeros((B, self._k), np.float32)
        mask_B = np.zeros((B, self._N), bool)  # padding slots: no stragglers
        for s, q in enumerate(wave):
            theta_B[s] = q.theta
            mask_B[s] = q.straggler_mask
        self._record_admitted(wave)
        with _span("serving/launch", lane="serving", mode="lockstep"):
            grads, unresolved = self._flush(jnp.asarray(theta_B),
                                            jnp.asarray(mask_B))
        # Fixed-budget waves charge every query the full budget; a scheme
        # built with adaptive=True early-exits per slot inside the flush,
        # so the actual per-slot rounds are unknown at this layer (-1).
        wave_rounds = (-1 if getattr(self.scheme, "adaptive", False)
                       else self.budget)
        for s, q in enumerate(wave):
            q.admitted_launch = self.launches
            q.finished_launch = self.launches
            q.launches = 1
            q.rounds = wave_rounds
        self.launches += 1
        grads = np.asarray(grads)
        unresolved = np.asarray(unresolved)
        for s, q in enumerate(wave):
            q.gradient = grads[s]
            q.unresolved = int(unresolved[s])
            q.done = True
            self.finished.append(q)
        self._record_finished(wave)

    # ----------------------------------------------------------- continuous

    def _admit(self) -> None:
        """FIFO: fill every free slot from the head of the queue.

        A query's priority hint scales its per-launch round chunk
        (``priority × rounds_per_launch``, at least 1): urgent queries
        spend their budget in fewer launches, everyone's TOTAL budget is
        the same.
        """
        admitted = []
        for s in self.pool.free_slots():
            if not self.queue:
                break
            q = self.queue.popleft()
            self.pool.admit(
                s, q, chunk=round(self.rounds_per_launch * q.priority))
            self._theta[s] = q.theta
            self._mask[s] = q.straggler_mask
            self._fresh[s] = True
            q.admitted_launch = self.launches
            admitted.append(q)
        if admitted:
            self._record_admitted(admitted)

    def _step_continuous(self) -> None:
        budgets = self.pool.launch_budgets()
        reg = _obs_metrics.active()
        if reg is not None:
            reg.histogram("serving.slot_occupancy",
                          bins=_obs_metrics.FRACTION_BINS,
                          mode=self.mode).observe(
                              float(self.pool.occupied.mean()))
        with _span("serving/launch", lane="serving", mode="continuous"):
            if self._fresh.any():   # encode newly admitted slots' products
                self._vals, self._erased = self._init(
                    jnp.asarray(self._theta), jnp.asarray(self._mask),
                    self._vals, self._erased, jnp.asarray(self._fresh))
            self._vals, self._erased, rounds_d, g, unres_d, ecnt_d = \
                self._launch(self._vals, self._erased, jnp.asarray(budgets))
            launch_idx = self.launches
            self.launches += 1
            rounds, unres, ecnt = (np.asarray(rounds_d), np.asarray(unres_d),
                                   np.asarray(ecnt_d))
        self._fresh[:] = False
        for s, q in self.pool.owners():
            q.launches += 1
            q.rounds += int(rounds[s])
        # The pool applies THE retire rule (early exit / fully resolved /
        # budget exhausted — see SlotPool.account, incl. the chunk-boundary
        # probe-round note); retired slots' rows are the only device pulls.
        retired_q = []
        for s, q in self.pool.account(rounds, ecnt):
            q.gradient = np.asarray(g[s])
            q.unresolved = int(unres[s])
            q.finished_launch = launch_idx
            q.done = True
            self.finished.append(q)
            retired_q.append(q)
        if retired_q:
            self._record_finished(retired_q)

    # ------------------------------------------------------------------ run

    def run(self) -> list[CodedQuery]:
        """Serve until the queue and all slots drain; returns finished
        queries (continuous mode: in completion order, which is FIFO up to
        heavy queries finishing later)."""
        if self.mode == "lockstep":
            while self.queue:
                wave = [self.queue.popleft()
                        for _ in range(min(self.n_slots, len(self.queue)))]
                self._run_wave(wave)
            return self.finished
        while self.active:
            self._admit()
            self._step_continuous()
        return self.finished
