"""Lockstep batcher for concurrent coded-compute queries.

The serving-side counterpart of :class:`repro.serving.batcher.WaveBatcher`,
for the paper's workload instead of token decoding: clients submit coded
matvec/gradient queries — each a ``(θ, straggler_mask)`` pair with its OWN
independent straggler realization — and the batcher accumulates them into
waves of ``B`` slots that flush through ONE batched
encode→erase→decode→epilogue launch
(:meth:`repro.core.coded_step.Scheme2.gradient_batch`, backed by
:meth:`repro.core.engine.CodedComputeEngine.decode_batch`).

Lockstep means every wave has the same static shape: a partial final wave is
padded with no-op queries (θ = 0, no stragglers) so the jitted flush
compiles once and is reused for every wave.  ``launches`` counts the batched
decode launches actually issued — the efficiency claim (B queries per
launch) is observable, and tested.

This is the honest CPU-scale "serve many concurrent coded queries" driver;
per-query asynchronous admission (continuous batching) would need a
per-slot round-budget vector through the decode loop — noted as future work
alongside WaveBatcher's equivalent limitation.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CodedQuery", "CodedQueryBatcher"]


@dataclasses.dataclass
class CodedQuery:
    """One coded gradient query: evaluate ∇L̂(θ) under a straggler mask."""

    qid: int
    theta: np.ndarray            # (k,)
    straggler_mask: np.ndarray   # (N,) bool — this query's erasure pattern
    gradient: np.ndarray | None = None
    unresolved: int = -1
    done: bool = False


class CodedQueryBatcher:
    """Wave/static batching of coded queries over one shared scheme.

    ``scheme`` is any engine-backed scheme exposing
    ``gradient_batch(theta_B, mask_B)`` (e.g.
    :class:`repro.core.coded_step.Scheme2`).  All queries share the scheme's
    code and encoded operator; each brings its own straggler realization.
    """

    def __init__(self, scheme, *, n_slots: int = 8):
        if not hasattr(scheme, "gradient_batch"):
            raise TypeError(
                f"{type(scheme).__name__} has no gradient_batch; the coded "
                "batcher needs an engine-backed scheme (e.g. Scheme2)")
        self.scheme = scheme
        self.n_slots = n_slots
        self.queue: deque[CodedQuery] = deque()
        self.finished: list[CodedQuery] = []
        self.launches = 0  # batched decode launches issued
        self._k = int(scheme.C.shape[1])
        self._N = int(scheme.w)
        self._flush = jax.jit(
            lambda th, m: scheme.gradient_batch(th, m))

    def submit(self, query: CodedQuery) -> None:
        if query.theta.shape != (self._k,):
            raise ValueError(f"theta must be ({self._k},); got {query.theta.shape}")
        if query.straggler_mask.shape != (self._N,):
            raise ValueError(
                f"straggler_mask must be ({self._N},); got {query.straggler_mask.shape}")
        self.queue.append(query)

    @property
    def active(self) -> bool:
        return bool(self.queue)

    def _run_wave(self, wave: list[CodedQuery]) -> None:
        B = self.n_slots
        theta_B = np.zeros((B, self._k), np.float32)
        mask_B = np.zeros((B, self._N), bool)  # padding slots: no stragglers
        for s, q in enumerate(wave):
            theta_B[s] = q.theta
            mask_B[s] = q.straggler_mask
        grads, unresolved = self._flush(jnp.asarray(theta_B),
                                        jnp.asarray(mask_B))
        self.launches += 1
        grads = np.asarray(grads)
        unresolved = np.asarray(unresolved)
        for s, q in enumerate(wave):
            q.gradient = grads[s]
            q.unresolved = int(unresolved[s])
            q.done = True
            self.finished.append(q)

    def run(self) -> list[CodedQuery]:
        """Drain the queue in lockstep waves; returns the finished queries."""
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.n_slots, len(self.queue)))]
            self._run_wave(wave)
        return self.finished
