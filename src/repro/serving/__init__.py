from repro.serving import kvcache
from repro.serving.batcher import Request, WaveBatcher

__all__ = ["kvcache", "Request", "WaveBatcher"]
