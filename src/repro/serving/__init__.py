from repro.serving import kvcache
from repro.serving.batcher import Request, WaveBatcher
from repro.serving.coded_queries import CodedQuery, CodedQueryBatcher

__all__ = ["kvcache", "Request", "WaveBatcher",
           "CodedQuery", "CodedQueryBatcher"]
