from repro.serving import kvcache
from repro.serving.batcher import Request, WaveBatcher
from repro.serving.coded_queries import CodedQuery, CodedQueryBatcher
from repro.serving.slot_lifecycle import SlotPool

__all__ = ["kvcache", "Request", "WaveBatcher",
           "CodedQuery", "CodedQueryBatcher", "SlotPool"]
