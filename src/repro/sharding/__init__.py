from repro.sharding.specs import (
    batch_sharding,
    cache_sharding,
    dp_axes,
    make_param_shardings,
    opt_state_shardings,
)

__all__ = ["make_param_shardings", "opt_state_shardings", "batch_sharding",
           "cache_sharding", "dp_axes"]
