"""Sharding rules for the architecture zoo on the production meshes.

Mesh axes: ``("data", "model")`` single-pod, ``("pod", "data", "model")``
multi-pod — "pod" joins the data-parallel group.

Policy (Megatron-style tensor parallel, divisibility-aware):
  * embeddings / unembedding: vocab over "model" (when divisible);
  * attention: q-heads over "model" when n_heads divides, K/V heads likewise
    (GQA configs with few KV heads replicate K/V weights — cheap);
  * FFN: column-parallel in, row-parallel out (all assigned d_ff divide 16);
  * MoE: experts over "model" (all assigned expert counts divide 16),
    capacity dim of the dispatched activations over the data axes;
  * Mamba: channel-parallel (d_inner over "model");
  * RWKV: time-mix replicated (40 heads don't divide 16 — noted in
    DESIGN.md), channel-mix FFN sharded;
  * norms / biases / small LoRA-ish factors: replicated;
  * batch dims of activations/caches over ("pod","data").

Optimizer state additionally shards the largest replicated dimension over
the data axes (ZeRO-2-ish) — see ``opt_state_shardings``.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

__all__ = ["dp_axes", "make_param_shardings", "opt_state_shardings",
           "batch_sharding", "cache_sharding"]


def dp_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _msize(mesh: Mesh) -> int:
    return mesh.shape["model"]


def _div(n: int, k: int) -> bool:
    return n % k == 0


def _path_names(path) -> list[str]:
    return [str(getattr(k, "key", getattr(k, "name", k))) for k in path]


def _spec_for(cfg: ArchConfig, names: list[str], shape: tuple[int, ...],
              msize: int, dax=("data",), dsize: int = 1) -> P:
    """PartitionSpec for one parameter, from its tree path + shape."""
    name = names[-1] if names else ""
    nd = len(shape)

    def model_if(dim_size):
        return "model" if _div(dim_size, msize) else None

    # --- embeddings ---------------------------------------------------
    if "embed" in names and name == "table" or ("unembed" in names and name == "w"):
        return P(model_if(shape[0]), *([None] * (nd - 1)))
    # --- MoE ----------------------------------------------------------
    if "experts" in names:
        # (E, d, f) stacked expert weights: experts over "model"; the expert
        # weight tensors dominate the 236B/398B/1T configs, so they are
        # additionally FSDP-sharded over the data axes on dim 1 (XLA inserts
        # the all-gather — ZeRO-3 semantics for exactly these tensors).
        dspec = (dax if len(dax) > 1 else dax[0]) if (nd >= 2 and _div(shape[1], dsize)) else None
        return P(model_if(shape[0]), dspec, *([None] * (nd - 2)))
    if "router" in names:
        return P(*([None] * nd))
    # --- attention ----------------------------------------------------
    if "mixer" in names or "cross" in names:
        H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
        if name == "w" and names[-2] == "wq":
            return P(None, "model") if _div(H, msize) else P(None, None)
        if name == "w" and names[-2] in ("wk", "wv"):
            return P(None, "model") if _div(KV, msize) else P(None, None)
        if name == "w" and names[-2] == "wo":
            return P("model", None) if _div(H, msize) else P(None, None)
        if name == "b" and names[-2] == "wq":
            return P("model") if _div(H, msize) else P(None)
        if name == "b" and names[-2] in ("wk", "wv"):
            return P("model") if _div(KV, msize) else P(None)
        # MLA pieces
        if names[-2] == "w_uq" and name == "w":
            return P(None, "model") if _div(H, msize) else P(None, None)
        if name in ("w_uk", "w_uv"):  # (kv_lora, H, dh)
            return P(None, "model", None) if _div(H, msize) else P(None, None, None)
        # mamba pieces (channel parallel over d_inner)
        if names[-2] == "in_proj" and name == "w":
            d_in = shape[1] // 2
            return P(None, "model") if _div(d_in, msize) else P(None, None)
        if name in ("conv_w", "conv_b", "A_log", "D"):
            return P("model", *([None] * (nd - 1))) if _div(shape[0], msize) \
                else P(*([None] * nd))
        if names[-2] == "x_proj" and name == "w":
            return P("model", None) if _div(shape[0], msize) else P(None, None)
        if names[-2] == "out_proj" and name == "w":
            return P("model", None) if _div(shape[0], msize) else P(None, None)
        # rwkv time-mix: replicated (head count does not divide the mesh)
        return P(*([None] * nd))
    # --- dense FFN / rwkv channel mix / shared experts ------------------
    if "ffn" in names or "shared" in names:
        if name == "w" and names[-2] in ("gate", "up", "wk"):
            return P(None, "model") if _div(shape[1], msize) else P(None, None)
        if name == "w" and names[-2] in ("down", "wv"):
            return P("model", None) if _div(shape[0], msize) else P(None, None)
        if name == "b" and names[-2] in ("gate", "up", "wk"):
            return P("model") if _div(shape[0], msize) else P(None)
        return P(*([None] * nd))
    # --- everything else (norms, scalars) -------------------------------
    return P(*([None] * nd))


def _stacked(names: list[str]) -> bool:
    """Leaves under 'blocks'/'encoder' carry a leading n_blocks scan dim."""
    return "blocks" in names or ("encoder" in names and "layers" in names)


def make_param_shardings(cfg: ArchConfig, params_shapes: Any, mesh: Mesh):
    msize = _msize(mesh)
    dax = dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))

    def assign(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        if _stacked(names):
            spec = _spec_for(cfg, names, shape[1:], msize, dax, dsize)
            spec = P(None, *spec)
        else:
            spec = _spec_for(cfg, names, shape, msize, dax, dsize)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(assign, params_shapes)


def opt_state_shardings(cfg: ArchConfig, params_shapes: Any, mesh: Mesh):
    """AdamW (m, v) shardings: param spec + shard the largest still-
    replicated dim over the data axes (ZeRO-2-ish), when divisible."""
    msize = _msize(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))
    dax = dp_axes(mesh)

    def assign(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        stacked = _stacked(names)
        core = shape[1:] if stacked else shape
        spec = _spec_for(cfg, names, core, msize, dax, dsize)
        parts = list(spec)
        parts += [None] * (len(core) - len(parts))
        # skip if the data axes are already used (e.g. FSDP expert weights)
        used = set()
        for pt in parts:
            for a in (pt if isinstance(pt, tuple) else (pt,)):
                used.add(a)
        if not any(a in used for a in dax):
            best, best_dim = -1, -1
            for i, (pt, sz) in enumerate(zip(parts, core)):
                if pt is None and sz % dsize == 0 and sz > best:
                    best, best_dim = sz, i
            if best_dim >= 0:
                parts[best_dim] = dax if len(dax) > 1 else dax[0]
        if stacked:
            parts = [None] + parts
        return NamedSharding(mesh, P(*parts))

    m = jax.tree_util.tree_map_with_path(assign, params_shapes)
    import jax.numpy as jnp
    from repro.optim.adamw import AdamWState
    step_sh = NamedSharding(mesh, P())
    return AdamWState(step=step_sh, m=m, v=jax.tree.map(lambda s: s, m))


def batch_sharding(cfg: ArchConfig, mesh: Mesh, batch_tree: Any):
    """Shard every batch leaf's leading (batch) dim over the data axes."""
    dax = dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    spec_b = dax if len(dax) > 1 else dax[0]

    def assign(leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dsize != 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(spec_b, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(assign, batch_tree)


def cache_sharding(cfg: ArchConfig, mesh: Mesh, cache_tree: Any,
                   *, seq_shard_kv: bool = False):
    """Caches: batch dim over data axes (when divisible), KV-head / head dims
    over "model" when divisible.  Leading n_blocks stacking dim is skipped.

    seq_shard_kv=True (§Perf H1): when the KV-head dim does NOT divide the
    model axis (GQA kv=8 on a 16-wide axis), shard the cache SEQUENCE dim
    over "model" instead of replicating — decode attention then runs on
    per-chip KV shards with small softmax-stat collectives instead of
    all-gathering the whole cache every step.

    Layouts handled (possibly with a leading blocks dim):
      k/v        (B, W, KV, Dh)
      c_kv       (B, W, kv_lora) / k_rope (B, W, rope)
      h          (B, d_in, N)   conv (B, K-1, d_in)
      S          (B, H, Dh, Dh) last_x (B, d)
      pos        (W,)           length ()
    """
    dax = dp_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in dax]))
    msize = _msize(mesh)
    spec_b = dax if len(dax) > 1 else dax[0]

    def assign(path, leaf):
        names = _path_names(path)
        name = names[-1]
        shape = tuple(leaf.shape)
        stacked = "blocks" in names  # leading n_blocks dim from the scan stack
        lead = (None,) if stacked else ()
        core = shape[1:] if stacked else shape
        nd = len(core)
        if name in ("pos", "length") or nd == 0:
            return NamedSharding(mesh, P())
        bspec = spec_b if core[0] % dsize == 0 else None
        rest = [None] * (nd - 1)
        if name in ("k", "v") and nd == 4:
            if core[2] % msize == 0:
                rest[1] = "model"
            elif seq_shard_kv and core[1] % msize == 0:
                rest[0] = "model"  # shard the sequence/window dim instead
        if name in ("c_kv", "k_rope") and nd == 3 and seq_shard_kv \
                and core[1] % msize == 0:
            rest[0] = "model"  # MLA latent cache: shard sequence dim
        if name == "h" and nd == 3 and core[1] % msize == 0:
            rest[0] = "model"
        if name == "conv" and nd == 3 and core[2] % msize == 0:
            rest[1] = "model"
        return NamedSharding(mesh, P(*lead, bspec, *rest))

    return jax.tree_util.tree_map_with_path(assign, cache_tree)
