"""Learning-rate schedules.

The paper's Theorem 1 uses the classic fixed η = R/(B√T); transformer
training uses warmup+cosine.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["constant", "theorem1_lr", "warmup_cosine"]


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def theorem1_lr(R: float, B: float, T: int):
    """η = R / (B √T) — the setting of Theorem 1."""
    return constant(R / (B * (T ** 0.5)))


def warmup_cosine(peak: float, warmup: int, total: int, floor: float = 0.0):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + 0.5 * (peak - floor) * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)

    return sched
