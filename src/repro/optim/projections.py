"""Projection operators P_Θ for constrained PGD (all non-expansive).

The paper's experiments use: identity (plain least squares) and the
hard-thresholding operator H_u (IHT for sparse recovery, Garg & Khandekar).
L2-ball and L1-ball projections cover the R(θ) <= R formulation of (1).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["identity", "l2_ball", "l1_ball", "hard_threshold", "box"]


def identity(theta: jax.Array) -> jax.Array:
    return theta


def l2_ball(radius: float):
    def proj(theta: jax.Array) -> jax.Array:
        nrm = jnp.linalg.norm(theta)
        scale = jnp.minimum(1.0, radius / jnp.maximum(nrm, 1e-30))
        return theta * scale

    return proj


def l1_ball(radius: float):
    """Euclidean projection onto {||x||_1 <= r} (Duchi et al. 2008)."""

    def proj(theta: jax.Array) -> jax.Array:
        a = jnp.abs(theta)

        def project():
            u = jnp.sort(a)[::-1]
            css = jnp.cumsum(u)
            ks = jnp.arange(1, a.size + 1)
            cond = u * ks > (css - radius)
            rho = jnp.max(jnp.where(cond, ks, 0))
            lam = (jnp.take(css, rho - 1) - radius) / rho
            return jnp.sign(theta) * jnp.maximum(a - lam, 0.0)

        return jax.lax.cond(jnp.sum(a) <= radius, lambda: theta, project)

    return proj


def hard_threshold(u: int):
    """H_u: keep the u largest-magnitude coordinates, zero the rest (IHT)."""

    def proj(theta: jax.Array) -> jax.Array:
        if u >= theta.size:
            return theta
        # top_k indices break ties deterministically -> exactly <= u nonzeros
        _, idx = jax.lax.top_k(jnp.abs(theta), u)
        mask = jnp.zeros(theta.shape, bool).at[idx].set(True)
        return jnp.where(mask, theta, 0.0)

    return proj


def box(lo: float, hi: float):
    def proj(theta: jax.Array) -> jax.Array:
        return jnp.clip(theta, lo, hi)

    return proj
