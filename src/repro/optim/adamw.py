"""Minimal sharding-friendly AdamW (pytree-based, no external deps).

State tensors mirror the parameter pytree, so whatever PartitionSpec the
parameters carry applies to (m, v) — with the launcher additionally sharding
optimizer state over the data axis (ZeRO-style) via out_shardings.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "AdamWState", "adamw_init", "adamw_update"]


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, cfg: AdamWConfig):
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g32
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g32)
        update = (m2 / bc1) / (jnp.sqrt(v2 / bc2) + cfg.eps)
        p2 = p.astype(jnp.float32) - cfg.lr * (update + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v)
