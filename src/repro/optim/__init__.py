from repro.optim import projections, schedules
from repro.optim.adamw import adamw_init, adamw_update, AdamWState, AdamWConfig

__all__ = ["projections", "schedules", "adamw_init", "adamw_update", "AdamWState", "AdamWConfig"]
