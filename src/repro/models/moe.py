"""Mixture-of-Experts with capacity-based top-k token dispatch.

Dispatch is gather/scatter based (argsort packing), never a one-hot
(T, E, C) tensor — at DeepSeek-V2/Kimi-K2 scale the one-hot would be
terabytes.  Under the production mesh the expert dimension is sharded over
"model" and the capacity dimension over "data", so the dispatch gathers
lower to the expert-parallel all-to-all-style collectives on TPU.

A standard auxiliary load-balance loss (Switch/DeepSeek style) is returned
alongside the outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, mlp, mlp_init

__all__ = ["init_moe", "moe_forward", "capacity_for"]


def capacity_for(n_tokens: int, n_experts: int, top_k: int,
                 capacity_factor: float = 1.25, multiple: int = 8) -> int:
    c = int(n_tokens * top_k * capacity_factor / n_experts) + 1
    return max(multiple, c + (-c) % multiple)


def init_moe(key, d: int, d_ff_expert: int, n_experts: int, *, n_shared: int = 0,
             act: str = "swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 5)

    def stack_init(k):
        keys = jax.random.split(k, n_experts)
        return jax.vmap(lambda kk: mlp_init(kk, d, d_ff_expert, act=act, dtype=dtype))(keys)

    p = {
        "router": dense_init(ks[0], d, n_experts, dtype=jnp.float32),
        "experts": stack_init(ks[1]),
    }
    if n_shared:
        p["shared"] = mlp_init(ks[2], d, n_shared * d_ff_expert, act=act, dtype=dtype)
    return p


def _expert_mlp(experts, xe, act: str):
    """xe: (E, C, d) -> (E, C, d) via per-expert MLP (batched einsum)."""
    if act == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xe, experts["gate"]["w"])
        u = jnp.einsum("ecd,edf->ecf", xe, experts["up"]["w"])
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, experts["up"]["w"]))
    return jnp.einsum("ecf,efd->ecd", h, experts["down"]["w"])


def moe_forward(p, x, *, n_experts: int, top_k: int, capacity_factor: float = 1.25,
                act: str = "swiglu", router_noise: float = 0.0, key=None,
                groups: int = 1):
    """x: (B, S, d) -> (y, aux_loss).

    Tokens are routed to their top-k experts; each expert processes at most C
    tokens (overflow dropped — standard capacity-based MoE).

    groups > 1 (§Perf H2): routing/dispatch/combine run independently per
    token group, with the group dim aligned to the data-parallel batch
    sharding.  Every (assignments x d) gather/scatter then carries a
    data-sharded leading dim instead of living in the global token space, so
    the SPMD partitioner emits per-shard transfers instead of all-reducing
    the full combine matrix across the mesh (measured 137 GB/chip -> per-
    shard GBs on jamba prefill_32k).  groups must divide B; capacity is per
    group, so routing quality is per-shard (standard EP semantics).
    """
    B, S, d = x.shape
    if groups > 1 and B % groups == 0:
        xg = x.reshape(groups, (B // groups) * S, d)
        out, aux = jax.vmap(
            lambda xt: _moe_tokens(p, xt, n_experts=n_experts, top_k=top_k,
                                   capacity_factor=capacity_factor, act=act)
        )(xg)
        return out.reshape(B, S, d), aux.mean()
    out, aux = _moe_tokens(p, x.reshape(B * S, d), n_experts=n_experts,
                           top_k=top_k, capacity_factor=capacity_factor,
                           act=act, key=key, router_noise=router_noise)
    return out.reshape(B, S, d), aux


def _moe_tokens(p, xt, *, n_experts: int, top_k: int, capacity_factor: float,
                act: str, router_noise: float = 0.0, key=None):
    """Core routed-expert computation over a flat token list (T, d)."""
    T, d = xt.shape
    logits = (xt.astype(jnp.float32) @ p["router"]["w"])  # (T, E)
    if router_noise and key is not None:
        logits = logits + router_noise * jax.random.normal(key, logits.shape)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, experts_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = capacity_for(T, n_experts, top_k, capacity_factor)
    A = T * top_k  # total assignments
    flat_e = experts_idx.reshape(A)
    flat_w = gate_vals.reshape(A)
    order = jnp.argsort(flat_e, stable=True)  # assignments grouped by expert
    sorted_e = flat_e[order]
    token_of = order // top_k  # original token per sorted assignment
    # position within the expert's group
    starts = jnp.searchsorted(sorted_e, jnp.arange(n_experts))  # (E,)
    pos_in_group = jnp.arange(A) - starts[sorted_e]
    keep = pos_in_group < C
    slot = sorted_e * C + pos_in_group  # (A,) target slot in (E*C) buffer
    slot_safe = jnp.where(keep, slot, n_experts * C)  # OOB -> dropped

    # dispatch: (E*C,) token indices. Empty slots point at token 0 (NOT a
    # concatenated pad row — appending a row reshards the token array and
    # costs a cross-shard all-reduce of the dispatched tensor, §Perf H2 it-3);
    # their combine weight is 0 so the garbage compute is ignored.
    disp_idx = jnp.zeros((n_experts * C,), jnp.int32)
    disp_idx = disp_idx.at[slot_safe].set(token_of.astype(jnp.int32), mode="drop")
    xe = xt[disp_idx].reshape(n_experts, C, d)

    ye = _expert_mlp(p["experts"], xe, act)  # (E, C, d)

    # combine in SLOT space (§Perf H2 it-4): scatter-add straight from the
    # expert-sharded (E*C, d) outputs into token space. The assignment-space
    # gather ye_flat[slot] would materialize a (T*top_k, d) tensor that the
    # partitioner all-reduces across the expert axis; the slot-space scatter
    # keeps updates expert-sharded and reduces only the (T, d) output.
    # Weights stay in the activation dtype (f32 promotion doubles the
    # collective — §Perf H2 it-1).
    w_kept = jnp.where(keep, flat_w[order], 0.0).astype(ye.dtype)
    w_slot = jnp.zeros((n_experts * C,), ye.dtype)
    w_slot = w_slot.at[slot_safe].set(w_kept, mode="drop")
    contrib_slots = ye.reshape(n_experts * C, d) * w_slot[:, None]
    out = jnp.zeros((T, d), ye.dtype).at[disp_idx].add(contrib_slots)

    if "shared" in p:
        out = out + mlp(p["shared"], xt, act=act)

    # Switch-style load-balance loss: E * sum_e f_e * p_e
    assign_frac = jnp.zeros((n_experts,), jnp.float32).at[flat_e].add(1.0) / A
    prob_frac = probs.mean(axis=0)
    aux = n_experts * jnp.sum(assign_frac * prob_frac)
    return out.astype(xt.dtype), aux
