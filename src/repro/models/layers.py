"""Layer primitives shared by the architecture zoo (pure-functional JAX).

Parameters are plain nested dicts of jnp arrays; every module is a pair of
``init_*`` / ``apply`` functions so stacks can be built with ``lax.scan``
over stacked parameter pytrees.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm", "layernorm_init", "layernorm",
    "mlp_init", "mlp", "rope_freqs", "apply_rope", "embed_init",
    "cross_entropy_loss",
]


def _he(key, shape, dtype, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    return (jax.random.normal(key, shape) / jnp.sqrt(fan_in)).astype(dtype)


def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32):
    p = {"w": _he(key, (d_in, d_out), dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def mlp_init(key, d: int, d_ff: int, *, act: str = "swiglu", bias: bool = False,
             dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "gate": dense_init(k1, d, d_ff, bias=bias, dtype=dtype),
            "up": dense_init(k2, d, d_ff, bias=bias, dtype=dtype),
            "down": dense_init(k3, d_ff, d, bias=bias, dtype=dtype),
        }
    return {  # gelu / relu2 two-matrix MLP
        "up": dense_init(k1, d, d_ff, bias=bias, dtype=dtype),
        "down": dense_init(k2, d_ff, d, bias=bias, dtype=dtype),
    }


def mlp(p, x, *, act: str = "swiglu"):
    if act == "swiglu":
        return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))
    if act == "gelu":
        return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))
    if act == "relu2":
        return dense(p["down"], jnp.square(jax.nn.relu(dense(p["up"], x))))
    raise ValueError(act)


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S) or (S,)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, dh/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, dh/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)}


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: jax.Array | None = None) -> jax.Array:
    """Mean next-token cross entropy. logits (B,S,V), labels (B,S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
