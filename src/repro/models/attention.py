"""Attention variants for the zoo: GQA (qk-norm / QKV-bias / sliding-window)
and DeepSeek-style MLA (naive train path + absorbed-latent decode path).

The softmax attention core is chunked over query blocks (``lax.scan``) so the
(S x S) score matrix never materializes for a full sequence — the pure-JAX
analogue of flash attention (the Pallas kernel in kernels/flash_attention is
the TPU-tiled version of the same computation).

Caches (see repro.serving.kvcache) are dicts of preallocated arrays with a
ring-buffer variant for the sliding-window long-context decode shape.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = [
    "sdpa_chunked", "init_gqa", "gqa_forward", "gqa_decode",
    "init_mla", "mla_forward", "mla_decode",
]

NEG_INF = -1e30


def sdpa_chunked(q, k, v, q_pos, kv_pos, *, causal=True, chunk=512,
                 kv_valid=None, unroll=False) -> jax.Array:
    """Chunked scaled-dot-product attention.

    q: (B, Sq, KV, G, Dh) — query heads grouped per KV head (GQA).
    k, v: (B, T, KV, Dh).
    q_pos: (Sq,) absolute positions of queries; kv_pos: (T,) of keys.
    kv_valid: optional (T,) bool — e.g. ring-buffer slots actually filled.
    """
    B, Sq, KV, G, Dh = q.shape
    scale = 1.0 / math.sqrt(Dh)

    def block(qc, qp):
        s = jnp.einsum("bqkgd,btkd->bkgqt", qc.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale
        mask = jnp.ones(s.shape[-2:], bool)
        if causal:
            mask = qp[:, None] >= kv_pos[None, :]
        if kv_valid is not None:
            mask = mask & kv_valid[None, :]
        s = jnp.where(mask, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bkgqt,btkd->bqkgd", p, v.astype(jnp.float32)).astype(v.dtype)

    cq = min(chunk, Sq)
    if Sq % cq != 0 or Sq == cq:
        return block(q, q_pos)
    nc = Sq // cq
    qr = jnp.moveaxis(q.reshape(B, nc, cq, KV, G, Dh), 1, 0)
    qpr = q_pos.reshape(nc, cq)
    if unroll:
        # python loop: every chunk's flops visible to cost_analysis (the
        # dry-run path; lax.scan bodies are counted once by XLA's analysis)
        outs = jnp.stack([block(qr[i], qpr[i]) for i in range(nc)])
    else:
        _, outs = jax.lax.scan(lambda c, xs: (c, block(*xs)), None, (qr, qpr))
    Dv = v.shape[-1]  # may differ from Dh (MLA: v_head != qk dims)
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, KV, G, Dv)


# ---------------------------------------------------------------- GQA ------


def init_gqa(key, d_model: int, n_heads: int, n_kv: int, head_dim: int, *,
             qk_norm: bool = False, bias: bool = False, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, bias=bias, dtype=dtype),
        "wk": dense_init(ks[1], d_model, n_kv * head_dim, bias=bias, dtype=dtype),
        "wv": dense_init(ks[2], d_model, n_kv * head_dim, bias=bias, dtype=dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, bias=False, dtype=dtype),
    }
    if qk_norm:
        p["q_norm"] = rmsnorm_init(head_dim, dtype)
        p["k_norm"] = rmsnorm_init(head_dim, dtype)
    return p


def _qkv(p, x, n_heads, n_kv, head_dim, positions, rope_theta, use_rope=True):
    B, S, _ = x.shape
    q = dense(p["wq"], x).reshape(B, S, n_heads, head_dim)
    k = dense(p["wk"], x).reshape(B, S, n_kv, head_dim)
    v = dense(p["wv"], x).reshape(B, S, n_kv, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def gqa_forward(p, x, *, n_heads, n_kv, head_dim, positions, rope_theta=1e6,
                causal=True, chunk=512, cache=None, use_rope=True,
                kv_source=None, unroll=False):
    """Training / prefill / encoder attention over a full sequence.

    kv_source: if given (B, T, d) — cross-attention (keys/values from it,
    non-causal).  cache: if given, K/V are written into it (prefill).
    Returns (y, cache).
    """
    B, S, _ = x.shape
    G = n_heads // n_kv
    if kv_source is None:
        q, k, v = _qkv(p, x, n_heads, n_kv, head_dim, positions, rope_theta, use_rope)
        kv_pos = positions
    else:
        T = kv_source.shape[1]
        q = dense(p["wq"], x).reshape(B, S, n_heads, head_dim)
        k = dense(p["wk"], kv_source).reshape(B, T, n_kv, head_dim)
        v = dense(p["wv"], kv_source).reshape(B, T, n_kv, head_dim)
        kv_pos = jnp.arange(T)
        causal = False
    if cache is not None:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
        cache["pos"] = cache["pos"] * 0 + jnp.arange(cache["pos"].shape[0])
        cache["length"] = jnp.asarray(S, jnp.int32)
    o = sdpa_chunked(q.reshape(B, S, n_kv, G, head_dim), k, v, positions, kv_pos,
                     causal=causal, chunk=chunk, unroll=unroll)
    y = dense(p["wo"], o.reshape(B, S, n_heads * head_dim))
    return y, cache


def gqa_decode(p, x, *, n_heads, n_kv, head_dim, pos, cache, rope_theta=1e6,
               use_rope=True, cross=False):
    """Single-token decode. x: (B, 1, d); cache holds K/V (+ slot positions).

    Supports both a full cache (slot == pos) and a ring-buffer window cache
    (slot == pos % W, validity tracked via per-slot positions).
    cross: cross-attention decode — read-only cache of encoder K/V.
    """
    B = x.shape[0]
    q = dense(p["wq"], x).reshape(B, 1, n_heads, head_dim)
    if cross:
        if "q_norm" in p:
            q = rmsnorm(p["q_norm"], q)
        k, v = cache["k"], cache["v"]
        kv_valid = None
        kv_pos = cache["pos"]
        o = sdpa_chunked(q.reshape(B, 1, n_kv, n_heads // n_kv, head_dim), k, v,
                         jnp.full((1,), pos), kv_pos, causal=False)
        return dense(p["wo"], o.reshape(B, 1, n_heads * head_dim)), cache

    k = dense(p["wk"], x).reshape(B, 1, n_kv, head_dim)
    v = dense(p["wv"], x).reshape(B, 1, n_kv, head_dim)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        posv = jnp.full((1,), pos)
        q = apply_rope(q, posv, rope_theta)
        k = apply_rope(k, posv, rope_theta)
    W = cache["k"].shape[1]
    slot = pos % W
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, slot, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, slot, 0, 0))
    cache["pos"] = cache["pos"].at[slot].set(pos)
    cache["length"] = jnp.maximum(cache["length"], pos + 1)
    kv_valid = cache["pos"] <= pos  # unfilled slots are initialized to INT_MAX
    o = sdpa_chunked(q.reshape(B, 1, n_kv, n_heads // n_kv, head_dim),
                     cache["k"], cache["v"], jnp.full((1,), pos), cache["pos"],
                     causal=True, kv_valid=kv_valid)
    return dense(p["wo"], o.reshape(B, 1, n_heads * head_dim)), cache


# ---------------------------------------------------------------- MLA ------


def init_mla(key, d_model: int, n_heads: int, *, kv_lora: int, q_lora: int = 0,
             qk_nope: int = 128, qk_rope: int = 64, v_head: int = 128,
             dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    qdim = n_heads * (qk_nope + qk_rope)
    p = {
        "w_dkv": dense_init(ks[0], d_model, kv_lora, dtype=dtype),
        "kv_norm": rmsnorm_init(kv_lora, dtype),
        "w_kr": dense_init(ks[1], d_model, qk_rope, dtype=dtype),
        "w_uk": (jax.random.normal(ks[2], (kv_lora, n_heads, qk_nope)) /
                 math.sqrt(kv_lora)).astype(dtype),
        "w_uv": (jax.random.normal(ks[3], (kv_lora, n_heads, v_head)) /
                 math.sqrt(kv_lora)).astype(dtype),
        "wo": dense_init(ks[4], n_heads * v_head, d_model, dtype=dtype),
    }
    if q_lora:
        p["w_dq"] = dense_init(ks[5], d_model, q_lora, dtype=dtype)
        p["q_norm"] = rmsnorm_init(q_lora, dtype)
        p["w_uq"] = dense_init(ks[6], q_lora, qdim, dtype=dtype)
    else:
        p["w_uq"] = dense_init(ks[6], d_model, qdim, dtype=dtype)
    return p


def _mla_q(p, x, n_heads, qk_nope, qk_rope, positions, rope_theta):
    B, S, _ = x.shape
    h = x
    if "w_dq" in p:
        h = rmsnorm(p["q_norm"], dense(p["w_dq"], x))
    q = dense(p["w_uq"], h).reshape(B, S, n_heads, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, rope_theta)
    return q_nope, q_rope


def mla_forward(p, x, *, n_heads, kv_lora, qk_nope=128, qk_rope=64, v_head=128,
                positions, rope_theta=1e6, chunk=512, cache=None, unroll=False):
    """Naive (non-absorbed) MLA for training/prefill: materialize per-head K/V."""
    B, S, _ = x.shape
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, positions, rope_theta)
    c_kv = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x))  # (B,S,kv_lora)
    k_rope = apply_rope(dense(p["w_kr"], x).reshape(B, S, 1, qk_rope), positions,
                        rope_theta)
    k_nope = jnp.einsum("bsl,lhd->bshd", c_kv, p["w_uk"])
    v = jnp.einsum("bsl,lhd->bshd", c_kv, p["w_uv"])
    if cache is not None:
        cache = dict(cache)
        cache["c_kv"] = jax.lax.dynamic_update_slice(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), (0, 0, 0))
        cache["k_rope"] = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope[:, :, 0, :].astype(cache["k_rope"].dtype), (0, 0, 0))
        cache["pos"] = cache["pos"] * 0 + jnp.arange(cache["pos"].shape[0])
        cache["length"] = jnp.asarray(S, jnp.int32)
    # fold rope part in as extra key dims: K = [k_nope ; k_rope broadcast]
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, n_heads, qk_rope))], axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    # MLA has per-head K (no grouping): KV = n_heads, G = 1.
    o = sdpa_chunked(q_full[:, :, :, None, :], k_full, v, positions, positions,
                     causal=True, chunk=chunk, unroll=unroll)
    y = dense(p["wo"], o.reshape(B, S, n_heads * v_head))
    return y, cache


def mla_decode(p, x, *, n_heads, kv_lora, qk_nope=128, qk_rope=64, v_head=128,
               pos, cache, rope_theta=1e6):
    """Absorbed-latent MLA decode: attention runs in the kv_lora latent space,
    the cache holds only (c_kv, k_rope) — 576 dims/token for DeepSeek-V2
    instead of n_heads*(nope+v) = 32K dims. This is the paper-table MLA win."""
    B = x.shape[0]
    posv = jnp.full((1,), pos)
    q_nope, q_rope = _mla_q(p, x, n_heads, qk_nope, qk_rope, posv, rope_theta)
    c_kv_t = rmsnorm(p["kv_norm"], dense(p["w_dkv"], x))  # (B,1,kv_lora)
    k_rope_t = apply_rope(dense(p["w_kr"], x).reshape(B, 1, 1, qk_rope), posv,
                          rope_theta)[:, :, 0, :]
    W = cache["c_kv"].shape[1]
    slot = pos % W
    cache = dict(cache)
    cache["c_kv"] = jax.lax.dynamic_update_slice(
        cache["c_kv"], c_kv_t.astype(cache["c_kv"].dtype), (0, slot, 0))
    cache["k_rope"] = jax.lax.dynamic_update_slice(
        cache["k_rope"], k_rope_t.astype(cache["k_rope"].dtype), (0, slot, 0))
    cache["pos"] = cache["pos"].at[slot].set(pos)
    cache["length"] = jnp.maximum(cache["length"], pos + 1)
    kv_valid = cache["pos"] <= pos
    # absorb W_uk into the query: q_lat = q_nope @ W_uk  -> latent-space dot
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, p["w_uk"])  # (B,1,H,kv_lora)
    scale = 1.0 / math.sqrt(qk_nope + qk_rope)
    s = (jnp.einsum("bshl,btl->bhst", q_lat.astype(jnp.float32),
                    cache["c_kv"].astype(jnp.float32))
         + jnp.einsum("bshd,btd->bhst", q_rope.astype(jnp.float32),
                      cache["k_rope"].astype(jnp.float32))) * scale
    mask = (cache["pos"] <= pos) & kv_valid
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhst,btl->bshl", attn,
                         cache["c_kv"].astype(jnp.float32))  # (B,1,H,kv_lora)
    o = jnp.einsum("bshl,lhd->bshd", ctx_lat.astype(x.dtype), p["w_uv"])
    y = dense(p["wo"], o.reshape(B, 1, n_heads * v_head))
    return y, cache
