"""Unified model assembly for the architecture zoo.

One generic decoder(-encoder) implementation is specialized entirely by
``ArchConfig``: mixer per layer (GQA / MLA / Mamba / RWKV-6), FFN per layer
(dense / MoE / RWKV channel-mix), optional encoder stack (whisper) and
modality stubs (VLM patch embeddings, audio frame embeddings).

Layer stacking uses ``stack_plan()``: an unrolled prefix (e.g. the single
dense layer of DeepSeek-V2/Kimi) plus a ``lax.scan`` over parameter-stacked
period blocks (period 8 for Jamba's 1-attention:7-mamba interleave) — this
keeps HLO size and compile time flat in depth, which matters for the 40x2
dry-run matrix.

Three entry points per model (the shapes of the assignment):
  ``loss_fn / forward``  — training forward (train_4k)
  ``prefill``            — full-sequence cache build (prefill_32k)
  ``decode_step``        — single-token with cache (decode_32k, long_500k)
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models import ssm as S
from repro.models.layers import (
    cross_entropy_loss,
    dense,
    embed_init,
    layernorm,
    layernorm_init,
    mlp,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
)
from repro.serving import kvcache as KV

__all__ = ["Model"]


def _sinusoidal(S: int, d: int, offset=0) -> jax.Array:
    pos = (jnp.arange(S) + offset)[:, None].astype(jnp.float32)
    div = jnp.exp(jnp.arange(0, d, 2, dtype=jnp.float32) * (-jnp.log(10000.0) / d))
    pe = jnp.zeros((S, d))
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


class Model:
    def __init__(self, cfg: ArchConfig, *, remat: bool = True,
                 moe_aux_coef: float = 0.01, attn_chunk: int = 512,
                 unroll: bool = False, moe_groups: int = 1,
                 mamba_chunk: int | None = None):
        # unroll=True: python loops instead of lax.scan over layer blocks and
        # attention chunks, so compiled.cost_analysis() counts every
        # iteration's flops (XLA counts while-loop bodies once). Used by the
        # dry-run; training/serving keep scan for compact HLO.
        self.cfg = cfg
        self.remat = remat
        self.moe_aux_coef = moe_aux_coef
        self.attn_chunk = attn_chunk
        self.unroll = unroll
        self.moe_groups = moe_groups  # §Perf H2: data-aligned MoE routing groups
        self.mamba_chunk = mamba_chunk  # chunked parallel-in-time SSM prefill
        self.prefix_len, self.period = cfg.stack_plan()
        self.n_blocks = (cfg.n_layers - self.prefix_len) // self.period
        self.specs = cfg.layer_specs()

    # ------------------------------------------------------------ norms ---
    def _norm_init(self, d=None):
        d = d or self.cfg.d_model
        return (rmsnorm_init if self.cfg.norm == "rmsnorm" else layernorm_init)(
            d, self.cfg.jdtype)

    def _norm(self, p, x):
        if self.cfg.norm == "rmsnorm":
            return rmsnorm(p, x, self.cfg.norm_eps)
        return layernorm(p, x, self.cfg.norm_eps)

    # ------------------------------------------------------------- init ---
    def _init_layer(self, key, spec, *, decoder: bool) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        mixer, ffn = spec
        ks = jax.random.split(key, 5)
        p: dict = {"ln1": self._norm_init()}
        if mixer == "attn":
            p["mixer"] = A.init_gqa(ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.hd, qk_norm=cfg.qk_norm, bias=cfg.qkv_bias,
                                    dtype=dt)
            if cfg.enc_layers and decoder:
                p["ln_x"] = self._norm_init()
                p["cross"] = A.init_gqa(ks[1], cfg.d_model, cfg.n_heads,
                                        cfg.n_kv_heads, cfg.hd, dtype=dt)
        elif mixer == "mla":
            m = cfg.mla
            p["mixer"] = A.init_mla(ks[0], cfg.d_model, cfg.n_heads,
                                    kv_lora=m.kv_lora, q_lora=m.q_lora,
                                    qk_nope=m.qk_nope, qk_rope=m.qk_rope,
                                    v_head=m.v_head, dtype=dt)
        elif mixer == "mamba":
            p["mixer"] = S.init_mamba(ks[0], cfg.d_model, d_state=cfg.mamba.d_state,
                                      d_conv=cfg.mamba.d_conv,
                                      expand=cfg.mamba.expand, dtype=dt)
        elif mixer == "rwkv":
            p["mixer"] = S.init_rwkv_time(ks[0], cfg.d_model,
                                          head_dim=cfg.rwkv.head_dim,
                                          decay_lora=cfg.rwkv.decay_lora, dtype=dt)
        p["ln2"] = self._norm_init()
        if ffn == "dense":
            p["ffn"] = mlp_init(ks[2], cfg.d_model, cfg.d_ff, act=cfg.act, dtype=dt)
        elif ffn == "moe":
            p["ffn"] = MOE.init_moe(ks[2], cfg.d_model, cfg.moe.d_ff_expert,
                                    cfg.moe.n_experts, n_shared=cfg.moe.n_shared,
                                    act=cfg.act if cfg.act != "relu2" else "swiglu",
                                    dtype=dt)
        elif ffn == "rwkv_cm":
            p["ffn"] = S.init_rwkv_channel(ks[2], cfg.d_model, cfg.d_ff, dtype=dt)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        dt = cfg.jdtype
        keys = jax.random.split(key, cfg.n_layers + 8)
        params: dict = {
            "embed": embed_init(keys[0], cfg.vocab, cfg.d_model, dt),
            "final_norm": self._norm_init(),
            "unembed": {"w": (jax.random.normal(keys[1], (cfg.vocab, cfg.d_model))
                              * 0.02).astype(dt)},
        }
        # unrolled prefix layers
        params["prefix"] = {
            str(i): self._init_layer(keys[2 + i], self.specs[i], decoder=True)
            for i in range(self.prefix_len)
        }
        # scanned body: one stacked subtree per position-in-period
        body: dict = {}
        for j in range(self.period):
            spec = self.specs[self.prefix_len + j]
            bkeys = jax.random.split(
                jax.random.fold_in(keys[2 + cfg.n_layers], j), self.n_blocks)
            body[f"sub{j}"] = jax.vmap(
                lambda k: self._init_layer(k, spec, decoder=True))(bkeys)
        params["blocks"] = body
        if cfg.enc_layers:
            ekeys = jax.random.split(keys[3 + cfg.n_layers], cfg.enc_layers)
            params["encoder"] = {
                "layers": jax.vmap(
                    lambda k: self._init_layer(k, ("attn", "dense"), decoder=False)
                )(ekeys),
                "final_norm": self._norm_init(),
            }
        return params

    # ------------------------------------------------------- layer apply ---
    def _apply_layer(self, p, spec, x, *, positions, mode, cache=None, pos=None,
                     enc_out=None, rng=None):
        """Returns (x, new_cache, aux_loss)."""
        cfg = self.cfg
        mixer, ffn = spec
        aux = jnp.zeros((), jnp.float32)
        h = self._norm(p["ln1"], x)
        new_cache = cache
        use_rope = cfg.pos == "rope"

        if mixer == "attn":
            self_cache = cache["self"] if (cache is not None and cfg.enc_layers) else cache
            if mode in ("train", "prefill", "encode"):
                y, self_cache = A.gqa_forward(
                    p["mixer"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.hd, positions=positions, rope_theta=cfg.rope_theta,
                    causal=(mode != "encode"), chunk=self.attn_chunk,
                    cache=self_cache if mode == "prefill" else None,
                    use_rope=use_rope and mode != "encode", unroll=self.unroll)
            else:
                y, self_cache = A.gqa_decode(
                    p["mixer"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                    head_dim=cfg.hd, pos=pos, cache=self_cache,
                    rope_theta=cfg.rope_theta, use_rope=use_rope)
            x = x + y
            cross_cache = cache["cross"] if (cache is not None and cfg.enc_layers) else None
            if "cross" in p and (enc_out is not None or cross_cache is not None):
                h2 = self._norm(p["ln_x"], x)
                if mode in ("train", "prefill"):
                    y2, cross_cache = A.gqa_forward(
                        p["cross"], h2, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.hd, positions=positions, chunk=self.attn_chunk,
                        kv_source=enc_out, unroll=self.unroll,
                        cache=cross_cache if mode == "prefill" else None)
                else:
                    y2, cross_cache = A.gqa_decode(
                        p["cross"], h2, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                        head_dim=cfg.hd, pos=pos, cache=cross_cache, cross=True)
                x = x + y2
            if cache is not None and cfg.enc_layers:
                new_cache = {"self": self_cache, "cross": cross_cache}
            else:
                new_cache = self_cache
        elif mixer == "mla":
            m = cfg.mla
            kw = dict(n_heads=cfg.n_heads, kv_lora=m.kv_lora, qk_nope=m.qk_nope,
                      qk_rope=m.qk_rope, v_head=m.v_head, rope_theta=cfg.rope_theta)
            if mode in ("train", "prefill"):
                y, new_cache = A.mla_forward(
                    p["mixer"], h, positions=positions, chunk=self.attn_chunk,
                    unroll=self.unroll,
                    cache=cache if mode == "prefill" else None, **kw)
            else:
                y, new_cache = A.mla_decode(p["mixer"], h, pos=pos, cache=cache, **kw)
            x = x + y
        elif mixer == "mamba":
            if mode in ("train", "prefill"):
                if mode == "prefill":
                    y, new_cache = S.mamba_forward(p["mixer"], h,
                                                   d_state=cfg.mamba.d_state,
                                                   return_state=True,
                                                   chunk=self.mamba_chunk)
                else:
                    y = S.mamba_forward(p["mixer"], h, d_state=cfg.mamba.d_state,
                                        chunk=self.mamba_chunk)
            else:
                y, new_cache = S.mamba_decode(p["mixer"], h, cache,
                                              d_state=cfg.mamba.d_state)
            x = x + y
        elif mixer == "rwkv":
            if mode in ("train", "prefill"):
                if mode == "prefill":
                    y, st = S.rwkv_time_forward(p["mixer"], h,
                                                head_dim=cfg.rwkv.head_dim,
                                                return_state=True)
                    new_cache = dict(cache) if cache else {}
                    new_cache.update(st)
                else:
                    y = S.rwkv_time_forward(p["mixer"], h, head_dim=cfg.rwkv.head_dim)
            else:
                y, st = S.rwkv_time_decode(p["mixer"], h,
                                           {"S": cache["S"], "last_x": cache["last_x"]},
                                           head_dim=cfg.rwkv.head_dim)
                new_cache = dict(cache)
                new_cache.update(st)
            x = x + y
        else:
            raise ValueError(mixer)

        h = self._norm(p["ln2"], x)
        if ffn == "dense":
            x = x + mlp(p["ffn"], h, act=cfg.act)
        elif ffn == "moe":
            y, aux = MOE.moe_forward(p["ffn"], h, n_experts=cfg.moe.n_experts,
                                     top_k=cfg.moe.top_k,
                                     capacity_factor=cfg.moe.capacity_factor,
                                     act=cfg.act if cfg.act != "relu2" else "swiglu",
                                     key=rng, groups=self.moe_groups)
            x = x + y
        elif ffn == "rwkv_cm":
            if mode in ("train", "prefill", "encode"):
                x = x + S.rwkv_channel_forward(p["ffn"], h)
                if mode == "prefill":
                    new_cache = dict(new_cache)
                    new_cache["cm_last_x"] = h[:, -1]
            else:
                y, st = S.rwkv_channel_decode(p["ffn"], h, {"last_x": cache["cm_last_x"]})
                x = x + y
                new_cache = dict(new_cache)
                new_cache["cm_last_x"] = st["last_x"]
        return x, new_cache, aux

    # ---------------------------------------------------------- encoder ---
    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames + _sinusoidal(frames.shape[1], cfg.d_model).astype(frames.dtype)
        positions = jnp.arange(frames.shape[1])

        def body(carry, lp):
            h, _ = carry
            h, _, _ = self._apply_layer(lp, ("attn", "dense"), h,
                                        positions=positions, mode="encode")
            return (h, 0.0), None

        (x, _), _ = jax.lax.scan(body, (x, 0.0), params["encoder"]["layers"])
        return self._norm(params["encoder"]["final_norm"], x)

    # ---------------------------------------------------------- forward ---
    def _embed_inputs(self, params, batch, *, offset: int = 0):
        """Returns (x, positions, enc_out)."""
        cfg = self.cfg
        toks = batch["tokens"]
        x = params["embed"]["table"][toks]
        enc_out = None
        if cfg.family == "vlm" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        if cfg.family == "audio":
            enc_out = self._encode(params, batch["frames"])
        S_tot = x.shape[1]
        positions = jnp.arange(S_tot) + offset
        if cfg.pos == "sinusoidal":
            x = x + _sinusoidal(S_tot, cfg.d_model, offset).astype(x.dtype)
        return x, positions, enc_out

    def forward(self, params, batch, *, rng=None):
        """Full-sequence training forward. Returns (logits, aux_loss)."""
        x, positions, enc_out = self._embed_inputs(params, batch)
        aux_total = jnp.zeros((), jnp.float32)
        for i in range(self.prefix_len):
            x, _, aux = self._apply_layer(params["prefix"][str(i)], self.specs[i], x,
                                          positions=positions, mode="train",
                                          enc_out=enc_out, rng=rng)
            aux_total = aux_total + aux

        body_specs = [self.specs[self.prefix_len + j] for j in range(self.period)]

        def block_fn(carry, bp):
            h, aux_c = carry
            for j in range(self.period):
                h, _, a = self._apply_layer(bp[f"sub{j}"], body_specs[j], h,
                                            positions=positions, mode="train",
                                            enc_out=enc_out, rng=rng)
                aux_c = aux_c + a
            return (h, aux_c), None

        fn = jax.checkpoint(block_fn) if self.remat else block_fn
        if self.unroll:
            for b in range(self.n_blocks):
                bp = jax.tree.map(lambda a: a[b], params["blocks"])
                (x, aux_total), _ = fn((x, aux_total), bp)
        else:
            (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), params["blocks"])
        x = self._norm(params["final_norm"], x)
        logits = (x @ params["unembed"]["w"].T).astype(jnp.float32)
        return logits, aux_total / max(self.cfg.n_layers, 1)

    def loss_fn(self, params, batch, *, rng=None):
        logits, aux = self.forward(params, batch, rng=rng)
        cfg = self.cfg
        if cfg.family == "vlm" and "patches" in batch:
            P = batch["patches"].shape[1]
            S_text = batch["tokens"].shape[1]
            logits = jax.lax.dynamic_slice_in_dim(logits, P - 1, S_text, axis=1)
            labels = batch["tokens"]
        else:
            labels = batch["labels"]
        ce = cross_entropy_loss(logits, labels, batch.get("loss_mask"))
        return ce + self.moe_aux_coef * aux

    # ---------------------------------------------------------- serving ---
    def init_cache(self, B: int, max_len: int, *, window: int | None = None):
        cfg = self.cfg
        dt = cfg.jdtype
        W = min(window or max_len, max_len)

        def one(mixer):
            return KV.make_layer_cache(cfg, mixer, B, W, dt)

        cache = {"prefix": {str(i): one(self.specs[i][0])
                            for i in range(self.prefix_len)}}
        body = {}
        for j in range(self.period):
            c = one(self.specs[self.prefix_len + j][0])
            if self.specs[self.prefix_len + j][1] == "rwkv_cm":
                c["cm_last_x"] = jnp.zeros((B, cfg.d_model), dt)
            body[f"sub{j}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (self.n_blocks,) + a.shape), c)
        cache["blocks"] = body
        return cache

    def prefill(self, params, batch, cache, *, rng=None):
        """Run the full prompt, writing caches. Returns (last_logits, cache)."""
        x, positions, enc_out = self._embed_inputs(params, batch)
        new_cache = {"prefix": {}}
        for i in range(self.prefix_len):
            x, c, _ = self._apply_layer(params["prefix"][str(i)], self.specs[i], x,
                                        positions=positions, mode="prefill",
                                        cache=cache["prefix"][str(i)],
                                        enc_out=enc_out, rng=rng)
            new_cache["prefix"][str(i)] = c

        body_specs = [self.specs[self.prefix_len + j] for j in range(self.period)]

        def block_fn(h, xs):
            bp, bc = xs
            ncs = {}
            for j in range(self.period):
                h, nc, _ = self._apply_layer(bp[f"sub{j}"], body_specs[j], h,
                                             positions=positions, mode="prefill",
                                             cache=bc[f"sub{j}"], enc_out=enc_out,
                                             rng=rng)
                ncs[f"sub{j}"] = nc
            return h, ncs

        if self.unroll:
            percs = []
            for b in range(self.n_blocks):
                bp = jax.tree.map(lambda a: a[b], params["blocks"])
                bc = jax.tree.map(lambda a: a[b], cache["blocks"])
                x, nc = block_fn(x, (bp, bc))
                percs.append(nc)
            body_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *percs)
        else:
            x, body_caches = jax.lax.scan(block_fn, x,
                                          (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = body_caches
        x = self._norm(params["final_norm"], x)
        logits = (x[:, -1:] @ params["unembed"]["w"].T).astype(jnp.float32)
        return logits, new_cache

    def decode_step(self, params, token, pos, cache):
        """token: (B, 1) int32; pos: scalar int32. Returns (logits, cache)."""
        cfg = self.cfg
        x = params["embed"]["table"][token]
        if cfg.pos == "sinusoidal":
            x = x + _sinusoidal(1, cfg.d_model, pos).astype(x.dtype)
        positions = jnp.arange(1) + pos
        new_cache = {"prefix": {}}
        for i in range(self.prefix_len):
            x, c, _ = self._apply_layer(params["prefix"][str(i)], self.specs[i], x,
                                        positions=positions, mode="decode",
                                        cache=cache["prefix"][str(i)], pos=pos)
            new_cache["prefix"][str(i)] = c

        body_specs = [self.specs[self.prefix_len + j] for j in range(self.period)]

        def block_fn(h, xs):
            bp, bc = xs
            ncs = {}
            for j in range(self.period):
                h, nc, _ = self._apply_layer(bp[f"sub{j}"], body_specs[j], h,
                                             positions=positions, mode="decode",
                                             cache=bc[f"sub{j}"], pos=pos)
                ncs[f"sub{j}"] = nc
            return h, ncs

        if self.unroll:
            percs = []
            for b in range(self.n_blocks):
                bp = jax.tree.map(lambda a: a[b], params["blocks"])
                bc = jax.tree.map(lambda a: a[b], cache["blocks"])
                x, nc = block_fn(x, (bp, bc))
                percs.append(nc)
            body_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *percs)
        else:
            x, body_caches = jax.lax.scan(block_fn, x,
                                          (params["blocks"], cache["blocks"]))
        new_cache["blocks"] = body_caches
        x = self._norm(params["final_norm"], x)
        logits = (x @ params["unembed"]["w"].T).astype(jnp.float32)
        return logits, new_cache

    # ------------------------------------------------------------ sizes ---
    def param_count(self, params) -> int:
        return sum(int(p.size) for p in jax.tree.leaves(params))

    def active_param_count(self, params) -> int:
        """Parameters touched per token (MoE: top_k of n_experts routed)."""
        cfg = self.cfg
        total = 0
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
            keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
            sz = int(leaf.size)
            if "experts" in keys and cfg.moe:
                sz = sz * cfg.moe.top_k // cfg.moe.n_experts
            total += sz
        return total
