"""State-space / linear-attention mixers: Mamba (selective SSM, as used by
Jamba) and RWKV-6 "Finch" (data-dependent decay).

Both provide a full-sequence form (``*_forward``, lax.scan over time) for
training/prefill and an O(1)-state single-token form (``*_decode``) — the
reason these families run the ``long_500k`` shape natively while pure
attention archs need a sliding window.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense, dense_init

__all__ = [
    "init_mamba", "mamba_forward", "mamba_decode",
    "init_rwkv_time", "rwkv_time_forward", "rwkv_time_decode",
    "init_rwkv_channel", "rwkv_channel_forward", "rwkv_channel_decode",
]


# --------------------------------------------------------------- Mamba -----


def init_mamba(key, d: int, *, d_state: int = 16, d_conv: int = 4,
               expand: int = 2, dtype=jnp.float32):
    d_in = expand * d
    dt_rank = max(1, math.ceil(d / 16))
    ks = jax.random.split(key, 6)
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (d_in, d_conv)) / math.sqrt(d_conv)).astype(dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], d_in, dt_rank + 2 * d_state, dtype=dtype),
        "dt_proj": dense_init(ks[3], dt_rank, d_in, bias=True, dtype=dtype),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32),
                                          (d_in, d_state)).astype(jnp.float32)),
        "D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], d_in, d, dtype=dtype),
    }


def _mamba_inner(p, xz, conv_fn, d_state: int):
    """Shared post-conv selective-scan math. xz: (B, S, 2*d_in)."""
    d_in = xz.shape[-1] // 2
    x, z = jnp.split(xz, 2, axis=-1)
    x = conv_fn(x)  # causal depthwise conv + silu
    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = dense(p["x_proj"], x)
    dt, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dense(p["dt_proj"], dt))  # (B,S,d_in)
    A = -jnp.exp(p["A_log"])  # (d_in, N)
    return x, z, dt, Bc, Cc, A


def _causal_depthwise_conv(x, w, b):
    """x: (B, S, d_in), w: (d_in, K) -> causal depthwise conv along S."""
    K = w.shape[1]
    pads = [jnp.pad(x, ((0, 0), (K - 1 - i, i), (0, 0)))[:, : x.shape[1], :]
            for i in range(K)]
    # pads[i] holds x shifted so that tap i sees x_{t-(K-1-i)}
    stacked = jnp.stack(pads, axis=-1)  # (B,S,d_in,K)
    return jax.nn.silu(jnp.einsum("bsdk,dk->bsd", stacked, w) + b)


def mamba_forward(p, x, *, d_state: int = 16, return_state: bool = False,
                  chunk: int | None = None):
    """x: (B, S, d) -> (B, S, d). lax.scan over time (sequential reference).

    chunk=L: chunked parallel-in-time form — an associative scan inside each
    length-L chunk, sequential carry between chunks.  Cuts the HLO while-loop
    trip count from S to S/L (32768 -> 128 for prefill_32k), which is the
    difference between a latency-serial and a throughput-parallel SSM prefill
    on TPU, at the cost of materializing (B, L, d_in, N) chunk temporaries.
    Numerics match the sequential scan to fp tolerance (associativity).

    return_state=True additionally returns {"h", "conv"} for decode handoff.
    """
    if chunk is not None and x.shape[1] % chunk == 0 and x.shape[1] > chunk:
        return _mamba_forward_chunked(p, x, d_state=d_state,
                                      return_state=return_state, chunk=chunk)
    B, S, d = x.shape
    xz = dense(p["in_proj"], x)  # (B,S,2*d_in)
    u_pre = jnp.split(xz, 2, axis=-1)[0]  # pre-conv mixer input (for conv state)
    xc, z, dt, Bc, Cc, A = _mamba_inner(
        p, xz, lambda u: _causal_depthwise_conv(u, p["conv_w"], p["conv_b"]), d_state)
    d_in = xc.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp  # (B,d_in), (B,d_in), (B,N), (B,N)
        dA = jnp.exp(dtt[..., None] * A)  # (B,d_in,N)
        h = dA * h + (dtt * xt)[..., None] * Bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, Ct)
        return h, y

    h0 = jnp.zeros((B, d_in, d_state), jnp.float32)
    xs = (jnp.moveaxis(xc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dt, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Bc, 1, 0).astype(jnp.float32),
          jnp.moveaxis(Cc, 1, 0).astype(jnp.float32))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B,S,d_in)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    if return_state:
        K = p["conv_w"].shape[1]
        pad = jnp.pad(u_pre, ((0, 0), (K - 1, 0), (0, 0)))
        conv_state = pad[:, -(K - 1):, :] if K > 1 else jnp.zeros(
            (B, 0, d_in), x.dtype)
        return out, {"h": h_final, "conv": conv_state.astype(x.dtype)}
    return out


def _mamba_forward_chunked(p, x, *, d_state: int, return_state: bool,
                           chunk: int):
    """Chunked associative-scan selective SSM (see mamba_forward docstring).

    Recurrence h_t = a_t ⊙ h_{t-1} + b_t is associative under
    (a, b) ∘ (a', b') = (a·a', a'·b + b'); within a chunk we run
    jax.lax.associative_scan over time, and the inter-chunk carry applies
    each chunk's cumulative (a, b) to the incoming state.
    """
    B, S, d = x.shape
    xz = dense(p["in_proj"], x)
    u_pre = jnp.split(xz, 2, axis=-1)[0]
    xc, z, dt, Bc, Cc, A = _mamba_inner(
        p, xz, lambda u: _causal_depthwise_conv(u, p["conv_w"], p["conv_b"]),
        d_state)
    d_in = xc.shape[-1]
    nc = S // chunk

    # per-step coefficients: a (B,S,d_in,N), b (B,S,d_in,N)
    def chunk_step(h0, inp):
        xcc, dtc, Bcc, Ccc = inp  # (B, L, ...)
        a = jnp.exp(dtc[..., None] * A)  # (B,L,d_in,N)
        b = (dtc * xcc)[..., None] * Bcc[:, :, None, :]

        def comb(lhs, rhs):
            (a1, b1), (a2, b2) = lhs, rhs
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(comb, (a, b), axis=1)
        h = a_cum * h0[:, None] + b_cum  # (B,L,d_in,N)
        y = jnp.einsum("bldn,bln->bld", h, Ccc)
        return h[:, -1], y

    to_c = lambda t: jnp.moveaxis(
        t.astype(jnp.float32).reshape(B, nc, chunk, *t.shape[2:]), 1, 0)
    h0 = jnp.zeros((B, d_in, d_state), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_step, h0, (to_c(xc), to_c(dt), to_c(Bc), to_c(Cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)
    if return_state:
        K = p["conv_w"].shape[1]
        pad = jnp.pad(u_pre, ((0, 0), (K - 1, 0), (0, 0)))
        conv_state = pad[:, -(K - 1):, :] if K > 1 else jnp.zeros(
            (B, 0, d_in), x.dtype)
        return out, {"h": h_final, "conv": conv_state.astype(x.dtype)}
    return out


def mamba_decode(p, x, state, *, d_state: int = 16):
    """Single token. x: (B, 1, d); state: {"h": (B,d_in,N), "conv": (B,K-1,d_in)}.
    Returns (y, new_state)."""
    B = x.shape[0]
    xz = dense(p["in_proj"], x)  # (B,1,2*d_in)
    d_in = xz.shape[-1] // 2
    xt, z = jnp.split(xz[:, 0], 2, axis=-1)  # (B,d_in)
    K = p["conv_w"].shape[1]
    conv_buf = jnp.concatenate([state["conv"], xt[:, None, :]], axis=1)  # (B,K,d_in)
    xt = jax.nn.silu(jnp.einsum("bkd,dk->bd", conv_buf, p["conv_w"]) + p["conv_b"])
    new_conv = conv_buf[:, 1:, :]
    dt_rank = p["dt_proj"]["w"].shape[0]
    proj = dense(p["x_proj"], xt)
    dtc, Bt, Ct = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dtc = jax.nn.softplus(dense(p["dt_proj"], dtc))
    A = -jnp.exp(p["A_log"])
    h = state["h"]
    dA = jnp.exp(dtc[..., None].astype(jnp.float32) * A)
    h = dA * h + (dtc * xt)[..., None].astype(jnp.float32) * Bt[:, None, :].astype(jnp.float32)
    y = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32)).astype(x.dtype)
    y = y + xt * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = dense(p["out_proj"], y)[:, None, :]
    return out, {"h": h, "conv": new_conv}


# --------------------------------------------------------------- RWKV-6 ----


def init_rwkv_time(key, d: int, *, head_dim: int = 64, decay_lora: int = 64,
                   dtype=jnp.float32):
    H = d // head_dim
    ks = jax.random.split(key, 10)
    mus = {n: jnp.full((d,), 0.5, dtype) for n in ("mu_r", "mu_k", "mu_v", "mu_g", "mu_w")}
    return {
        **mus,
        "wr": dense_init(ks[0], d, d, dtype=dtype),
        "wk": dense_init(ks[1], d, d, dtype=dtype),
        "wv": dense_init(ks[2], d, d, dtype=dtype),
        "wg": dense_init(ks[3], d, d, dtype=dtype),
        "w0": jnp.full((d,), -6.0, jnp.float32),  # exp(-exp(-6)) ~ slow decay
        "w_A": (jax.random.normal(ks[4], (d, decay_lora)) * 0.01).astype(dtype),
        "w_B": (jax.random.normal(ks[5], (decay_lora, d)) * 0.01).astype(dtype),
        "u": (jax.random.normal(ks[6], (H, head_dim)) * 0.1).astype(jnp.float32),
        "ln_scale": jnp.ones((d,), dtype),
        "ln_bias": jnp.zeros((d,), dtype),
        "wo": dense_init(ks[7], d, d, dtype=dtype),
    }


def _rwkv_groupnorm(x, scale, bias, H, Dh, eps=1e-5):
    """Per-head layernorm. x: (B, H, Dh)."""
    x32 = x.astype(jnp.float32)
    mu = x32.mean(-1, keepdims=True)
    var = x32.var(-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y.reshape(*x.shape[:-2], H * Dh)
    return y * scale.astype(jnp.float32) + bias.astype(jnp.float32)


def _rwkv_proj(p, x, xs, H, Dh):
    """Token-shift lerps + projections. x, xs: (..., d)."""
    def lerp(mu):
        return x + (xs - x) * p[mu]

    shp = x.shape[:-1]
    r = dense(p["wr"], lerp("mu_r")).reshape(*shp, H, Dh)
    k = dense(p["wk"], lerp("mu_k")).reshape(*shp, H, Dh)
    v = dense(p["wv"], lerp("mu_v")).reshape(*shp, H, Dh)
    g = jax.nn.silu(dense(p["wg"], lerp("mu_g")))
    xw = lerp("mu_w")
    w = jnp.exp(-jnp.exp(p["w0"] + (jnp.tanh(xw @ p["w_A"]) @ p["w_B"]).astype(jnp.float32)))
    w = w.reshape(*shp, H, Dh)  # data-dependent decay in (0, 1)
    return r, k, v, g, w


def rwkv_time_forward(p, x, *, head_dim: int = 64, return_state: bool = False):
    """x: (B, S, d) -> (B, S, d). State S_h: (B, H, Dh, Dh)."""
    B, S, d = x.shape
    H, Dh = d // head_dim, head_dim
    xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]  # token shift
    r, k, v, g, w = _rwkv_proj(p, x, xs, H, Dh)
    u = p["u"]

    def step(Sh, inp):
        rt, kt, vt, wt = inp  # (B,H,Dh) each
        a = kt[..., :, None] * vt[..., None, :]  # (B,H,Dh,Dh) outer k^T v
        o = jnp.einsum("bhi,bhij->bhj", rt, Sh + u[None, :, :, None] * a)
        Sh = wt[..., :, None] * Sh + a
        return Sh, o

    to_t = lambda a: jnp.moveaxis(a.astype(jnp.float32), 1, 0)
    S0 = jnp.zeros((B, H, Dh, Dh), jnp.float32)
    S_final, os = jax.lax.scan(step, S0, (to_t(r), to_t(k), to_t(v), to_t(w)))
    o = jnp.moveaxis(os, 0, 1)  # (B,S,H,Dh)
    o = _rwkv_groupnorm(o, p["ln_scale"], p["ln_bias"], H, Dh)
    out = dense(p["wo"], (o * g.astype(jnp.float32)).astype(x.dtype))
    if return_state:
        return out, {"S": S_final, "last_x": x[:, -1]}
    return out


def rwkv_time_decode(p, x, state, *, head_dim: int = 64):
    """x: (B,1,d); state: {"S": (B,H,Dh,Dh), "last_x": (B,d)}."""
    B, _, d = x.shape
    H, Dh = d // head_dim, head_dim
    xt = x[:, 0]
    r, k, v, g, w = _rwkv_proj(p, xt, state["last_x"], H, Dh)
    u = p["u"]
    rf, kf, vf, wf = (a.astype(jnp.float32) for a in (r, k, v, w))
    a = kf[..., :, None] * vf[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", rf, state["S"] + u[None, :, :, None] * a)
    Snew = wf[..., :, None] * state["S"] + a
    o = _rwkv_groupnorm(o, p["ln_scale"], p["ln_bias"], H, Dh)
    y = dense(p["wo"], (o * g.astype(jnp.float32)).astype(x.dtype))[:, None, :]
    return y, {"S": Snew, "last_x": xt}


def init_rwkv_channel(key, d: int, d_ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_r": jnp.full((d,), 0.5, dtype),
        "wk": dense_init(ks[0], d, d_ff, dtype=dtype),
        "wv": dense_init(ks[1], d_ff, d, dtype=dtype),
        "wr": dense_init(ks[2], d, d, dtype=dtype),
    }


def _rwkv_channel(p, x, xs):
    xk = x + (xs - x) * p["mu_k"]
    xr = x + (xs - x) * p["mu_r"]
    v = dense(p["wv"], jnp.square(jax.nn.relu(dense(p["wk"], xk))))
    return jax.nn.sigmoid(dense(p["wr"], xr)) * v


def rwkv_channel_forward(p, x):
    xs = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return _rwkv_channel(p, x, xs)


def rwkv_channel_decode(p, x, state):
    """state: {"last_x": (B, d)}."""
    xt = x[:, 0]
    y = _rwkv_channel(p, xt, state["last_x"])
    return y[:, None, :], {"last_x": xt}
