"""Online straggler telemetry: EMA rate estimation → decode budgets.

The paper's observation that "the number of decoding iterations
automatically adjusts with the number of stragglers" is a per-step property
of the adaptive peeling decoder.  This module closes the same loop at the
SYSTEM level, across steps: the master observes each step's realized
per-worker erasure fraction, keeps a bias-corrected exponential moving
average ``q̂`` of it, and uses density evolution (Proposition 2) to turn
``q̂`` into

* a per-step decode ROUND BUDGET (:func:`decode_budget`): the smallest ``D``
  whose density-evolution residual ``q_D`` has collapsed, plus a safety
  slack — fed to the adaptive decoder as a TRACED operand, so budgets that
  track the straggler climate never recompile the step;
* a WAIT-FOR threshold (:func:`pick_wait_for`): how many fastest workers
  the master should wait for under :class:`repro.core.straggler.DelayModel`
  timing, cutting off no more workers than the code's erasure threshold
  ``q*(l, r)`` (times a safety margin) can absorb, and no more than the
  observed straggling suggests is useful.

Everything here is tiny host-side arithmetic (numpy floats) — it sits in
the driver loop between device launches, exactly where a real master's
control plane would run.
"""
from __future__ import annotations

import dataclasses
import functools

from repro.core.density_evolution import qd_sequence, threshold

__all__ = ["StragglerRateEstimator", "rounds_to_clear", "decode_budget",
           "pick_wait_for", "cached_threshold"]


@functools.lru_cache(maxsize=None)
def cached_threshold(l: int, r: int) -> float:
    """``q*(l, r)`` memoized — the bisection is ~2000 iterations deep and
    the driver asks every step."""
    return threshold(l, r)


@dataclasses.dataclass
class StragglerRateEstimator:
    """Bias-corrected EMA of the observed per-worker straggler fraction.

    ``rate`` after ``t`` observations is ``(1-decay)·Σ decay^i x_{t-i}``
    normalized by ``1 - decay^t`` — so early estimates are unbiased instead
    of dragged toward the zero init, and under i.i.d. Bernoulli(q0)
    straggling the estimate converges to ``q0`` (tested).  ``prior`` seeds
    the very first budget decision (before any observation the estimator
    returns it), defaulting to pessimistic-but-decodable.
    """

    decay: float = 0.8
    prior: float = 0.3
    _ema: float = 0.0
    _norm: float = 0.0
    steps: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.decay < 1.0:
            raise ValueError(f"decay must be in [0, 1); got {self.decay}")

    @property
    def rate(self) -> float:
        """Current estimate q̂ (the prior until the first observation)."""
        if self._norm == 0.0:
            return self.prior
        return self._ema / self._norm

    def observe(self, fraction: float) -> float:
        """Fold in one step's realized straggler fraction; returns q̂."""
        f = float(fraction)
        if not 0.0 <= f <= 1.0:
            raise ValueError(f"straggler fraction must be in [0, 1]; got {f}")
        self._ema = self.decay * self._ema + (1.0 - self.decay) * f
        self._norm = self.decay * self._norm + (1.0 - self.decay)
        self.steps += 1
        return self.rate


def rounds_to_clear(q0: float, l: int, r: int, *, max_rounds: int = 64,
                    tol: float = 1e-3) -> int:
    """Smallest ``D`` with ``q_D ≤ tol`` under density evolution.

    Above the ensemble threshold the recursion never collapses and the
    answer is ``max_rounds`` (the worst-case budget).  ``q0 = 0`` costs one
    round — the adaptive decoder's no-progress probe.
    """
    if q0 <= 0.0:
        return 1
    qs = qd_sequence(min(q0, 1.0), l, r, max_rounds)
    below = qs <= tol
    if not below.any():
        return max_rounds
    return max(1, int(below.argmax()))


def decode_budget(q_hat: float, l: int, r: int, *, max_rounds: int = 64,
                  slack: int = 2, headroom: float = 1.25,
                  tol: float = 1e-3) -> int:
    """Per-step adaptive round budget from the telemetry estimate.

    Density evolution is an asymptotic (N → ∞) statement; finite codes
    straggle behind it, so the rate is padded by ``headroom`` before the
    recursion and ``slack`` extra rounds are added after.  Clamped to
    ``[1, max_rounds]``; the fixed worst-case budget this replaces is
    ``max_rounds`` itself, so the benchmark's "telemetry lowers mean decode
    rounds" claim is measured against that ceiling.
    """
    D = rounds_to_clear(min(q_hat * headroom, 1.0), l, r,
                        max_rounds=max_rounds, tol=tol)
    return max(1, min(D + slack, max_rounds))


def pick_wait_for(q_hat: float, w: int, l: int, r: int, *,
                  margin: float = 0.9, headroom: float = 1.5) -> int:
    """How many fastest workers the master should wait for.

    Cutting off ``s`` workers makes the erasure fraction ``s / w``, so the
    cut is capped at ``margin · q*(l, r)`` — the decoder must stay safely
    inside the ensemble threshold (Remark 3's monotonicity condition) —
    and ALSO at ``headroom · q̂``: when telemetry says workers rarely
    straggle there is no point abandoning them, waiting costs nothing.
    Always leaves at least one worker cut-able only if the margins allow;
    never waits for fewer than ``K``-recoverable support, and never more
    than ``w``.
    """
    if w < 1:
        raise ValueError(f"need at least one worker; got {w}")
    cap_threshold = margin * cached_threshold(l, r)
    cap_observed = headroom * max(q_hat, 0.0)
    cut = int(min(cap_threshold, cap_observed, 1.0) * w)
    return max(1, w - cut)
