"""Online straggler telemetry: EMA rate estimation → decode budgets.

The paper's observation that "the number of decoding iterations
automatically adjusts with the number of stragglers" is a per-step property
of the adaptive peeling decoder.  This module closes the same loop at the
SYSTEM level, across steps: the master observes each step's realized
per-worker erasure fraction, keeps a bias-corrected exponential moving
average ``q̂`` of it, and uses density evolution (Proposition 2) to turn
``q̂`` into

* a per-step decode ROUND BUDGET (:func:`decode_budget`): the smallest ``D``
  whose density-evolution residual ``q_D`` has collapsed, plus a safety
  slack — fed to the adaptive decoder as a TRACED operand, so budgets that
  track the straggler climate never recompile the step;
* a WAIT-FOR threshold (:func:`pick_wait_for`): how many fastest workers
  the master should wait for under :class:`repro.core.straggler.DelayModel`
  timing, cutting off no more workers than the code's erasure threshold
  ``q*(l, r)`` (times a safety margin) can absorb, and no more than the
  observed straggling suggests is useful;
* for the pipelined runtime (:mod:`repro.distributed.pipeline`), an
  ARRIVAL-LAG distribution (:class:`ArrivalLagEstimator`): how many steps
  late the cut-off workers actually land, in units of the step length —
  :func:`pick_wait_and_staleness` turns it into a ``(wait_for,
  max_staleness)`` pair so the fold window covers most late arrivals
  without holding stale partials that will never come.

Everything here is tiny host-side arithmetic (numpy floats) — it sits in
the driver loop between device launches, exactly where a real master's
control plane would run.  :func:`pick_wait_for_cached` is the driver-loop
entry point: the per-step call is memoized on a quantized rate bucket so a
steady climate costs a dict lookup, not a density-evolution walk.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from repro.core.density_evolution import qd_sequence, threshold

__all__ = ["StragglerRateEstimator", "ArrivalLagEstimator",
           "rounds_to_clear", "decode_budget", "pick_wait_for",
           "pick_wait_for_cached", "pick_wait_and_staleness",
           "cached_threshold"]


@functools.lru_cache(maxsize=None)
def cached_threshold(l: int, r: int) -> float:
    """``q*(l, r)`` memoized — the bisection is ~2000 iterations deep and
    the driver asks every step."""
    return threshold(l, r)


@dataclasses.dataclass
class StragglerRateEstimator:
    """Bias-corrected EMA of the observed per-worker straggler fraction.

    ``rate`` after ``t`` observations is ``(1-decay)·Σ decay^i x_{t-i}``
    normalized by ``1 - decay^t`` — so early estimates are unbiased instead
    of dragged toward the zero init, and under i.i.d. Bernoulli(q0)
    straggling the estimate converges to ``q0`` (tested).  ``prior`` seeds
    the very first budget decision (before any observation the estimator
    returns it), defaulting to pessimistic-but-decodable.
    """

    decay: float = 0.8
    prior: float = 0.3
    _ema: float = 0.0
    _norm: float = 0.0
    steps: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.decay < 1.0:
            raise ValueError(f"decay must be in [0, 1); got {self.decay}")

    @property
    def rate(self) -> float:
        """Current estimate q̂ (the prior until the first observation)."""
        if self._norm == 0.0:
            return self.prior
        return self._ema / self._norm

    def observe(self, fraction: float) -> float:
        """Fold in one step's realized straggler fraction; returns q̂."""
        f = float(fraction)
        if not 0.0 <= f <= 1.0:
            raise ValueError(f"straggler fraction must be in [0, 1]; got {f}")
        self._ema = self.decay * self._ema + (1.0 - self.decay) * f
        self._norm = self.decay * self._norm + (1.0 - self.decay)
        self.steps += 1
        return self.rate

    def snapshot(self) -> dict:
        """JSON-ready estimator state (fed to the obs registry as an info
        metric by the distributed drivers)."""
        return {
            "kind": "straggler_rate",
            "rate": float(self.rate),
            "decay": float(self.decay),
            "prior": float(self.prior),
            "steps": int(self.steps),
            "ema": float(self._ema),
            "norm": float(self._norm),
            "bias_corrected": bool(self._norm > 0.0),
        }


def rounds_to_clear(q0: float, l: int, r: int, *, max_rounds: int = 64,
                    tol: float = 1e-3) -> int:
    """Smallest ``D`` with ``q_D ≤ tol`` under density evolution.

    Above the ensemble threshold the recursion never collapses and the
    answer is ``max_rounds`` (the worst-case budget).  ``q0 = 0`` costs one
    round — the adaptive decoder's no-progress probe.
    """
    if q0 <= 0.0:
        return 1
    qs = qd_sequence(min(q0, 1.0), l, r, max_rounds)
    below = qs <= tol
    if not below.any():
        return max_rounds
    return max(1, int(below.argmax()))


def decode_budget(q_hat: float, l: int, r: int, *, max_rounds: int = 64,
                  slack: int = 2, headroom: float = 1.25,
                  tol: float = 1e-3) -> int:
    """Per-step adaptive round budget from the telemetry estimate.

    Density evolution is an asymptotic (N → ∞) statement; finite codes
    straggle behind it, so the rate is padded by ``headroom`` before the
    recursion and ``slack`` extra rounds are added after.  Clamped to
    ``[1, max_rounds]``; the fixed worst-case budget this replaces is
    ``max_rounds`` itself, so the benchmark's "telemetry lowers mean decode
    rounds" claim is measured against that ceiling.
    """
    D = rounds_to_clear(min(q_hat * headroom, 1.0), l, r,
                        max_rounds=max_rounds, tol=tol)
    return max(1, min(D + slack, max_rounds))


@dataclasses.dataclass
class ArrivalLagEstimator:
    """Bias-corrected EMA of the late-worker arrival-lag distribution.

    The pipelined runtime can FOLD a cut-off worker's partial product into
    a later update if it lands within ``max_staleness`` steps
    (:mod:`repro.distributed.pipeline`).  Whether that window is worth its
    buffer space depends on WHERE the late arrivals land: a fleet whose
    stragglers are barely late (lag 1) wants a short window, one whose
    stragglers are hopeless (lag ≫ 1) should keep today's drop semantics.
    Each step the master observes every worker's arrival lag in step-length
    units (0 = arrived inside the wait-for cutoff, ``k`` = would land
    during step ``t+k``, anything past ``max_lag`` = effectively never) and
    this class maintains a bias-corrected EMA histogram of them — the same
    estimator shape as :class:`StragglerRateEstimator`, per lag bin.
    """

    decay: float = 0.8
    max_lag: int = 8
    _mass: np.ndarray | None = None
    _norm: float = 0.0
    steps: int = 0

    def __post_init__(self) -> None:
        if not 0.0 <= self.decay < 1.0:
            raise ValueError(f"decay must be in [0, 1); got {self.decay}")
        if self.max_lag < 1:
            raise ValueError(f"max_lag must be >= 1; got {self.max_lag}")
        if self._mass is None:
            # bins: lag 0 (on time), 1..max_lag (foldable), max_lag+1 (never)
            self._mass = np.zeros(self.max_lag + 2)

    def observe(self, lags) -> None:
        """Fold in one step's per-worker arrival lags (ints, 0 = on time)."""
        lags = np.clip(np.asarray(lags, int), 0, self.max_lag + 1)
        hist = np.bincount(lags, minlength=self.max_lag + 2)
        frac = hist / max(1, lags.size)
        self._mass = self.decay * self._mass + (1.0 - self.decay) * frac
        self._norm = self.decay * self._norm + (1.0 - self.decay)
        self.steps += 1

    @property
    def pmf(self) -> np.ndarray:
        """Estimated lag pmf over bins ``0..max_lag+1`` (uniform prior
        over the late bins until the first observation)."""
        if self._norm == 0.0:
            p = np.zeros(self.max_lag + 2)
            p[0] = 0.5
            p[1:] = 0.5 / (self.max_lag + 1)
            return p
        return self._mass / self._norm

    def coverage(self, staleness: int) -> float:
        """P(lag ≤ staleness | late): the fraction of late arrivals a fold
        window of ``staleness`` steps would recover.  1.0 when nothing is
        ever late (any window trivially covers an empty set)."""
        p = self.pmf
        late = p[1:].sum()
        if late <= 0.0:
            return 1.0
        s = int(min(max(staleness, 0), self.max_lag))
        return float(p[1:s + 1].sum() / late)

    def snapshot(self) -> dict:
        """JSON-ready estimator state: the lag pmf (bins ``0..max_lag+1``,
        last bin = "effectively never") and the fold-window coverage curve
        the policy in :func:`pick_wait_and_staleness` walks."""
        return {
            "kind": "arrival_lag",
            "decay": float(self.decay),
            "max_lag": int(self.max_lag),
            "steps": int(self.steps),
            "norm": float(self._norm),
            "pmf": [float(x) for x in self.pmf],
            "coverage": [float(self.coverage(s))
                         for s in range(self.max_lag + 1)],
        }


def pick_wait_for(q_hat: float, w: int, l: int, r: int, *,
                  margin: float = 0.9, headroom: float = 1.5) -> int:
    """How many fastest workers the master should wait for.

    Cutting off ``s`` workers makes the erasure fraction ``s / w``, so the
    cut is capped at ``margin · q*(l, r)`` — the decoder must stay safely
    inside the ensemble threshold (Remark 3's monotonicity condition) —
    and ALSO at ``headroom · q̂``: when telemetry says workers rarely
    straggle there is no point abandoning them, waiting costs nothing.
    Always leaves at least one worker cut-able only if the margins allow;
    never waits for fewer than ``K``-recoverable support, and never more
    than ``w``.
    """
    if w < 1:
        raise ValueError(f"need at least one worker; got {w}")
    cap_threshold = margin * cached_threshold(l, r)
    cap_observed = headroom * max(q_hat, 0.0)
    cut = int(min(cap_threshold, cap_observed, 1.0) * w)
    return max(1, w - cut)


_RATE_BUCKETS = 1024


@functools.lru_cache(maxsize=8192)
def _pick_wait_for_bucketed(bucket: int, w: int, l: int, r: int,
                            margin: float, headroom: float) -> int:
    return pick_wait_for(bucket / _RATE_BUCKETS, w, l, r,
                         margin=margin, headroom=headroom)


def pick_wait_for_cached(q_hat: float, w: int, l: int, r: int, *,
                         margin: float = 0.9, headroom: float = 1.5) -> int:
    """:func:`pick_wait_for` memoized on ``(rate bucket, w, l, r)``.

    The density-evolution threshold inside :func:`pick_wait_for` is already
    memoized, but the driver loop still pays the wrapper arithmetic and the
    threshold-cache lookup every step.  Quantizing ``q̂`` to 1/1024 buckets
    makes the whole per-step decision one ``lru_cache`` hit; the bucket
    width is finer than the ``1/w`` cut granularity at w ≤ 1024 workers,
    so the chosen wait-for differs from the exact policy by at most one
    worker, and only when ``headroom·q̂·w`` sits exactly on an integer
    boundary.
    """
    b = int(round(min(max(q_hat, 0.0), 1.0) * _RATE_BUCKETS))
    return _pick_wait_for_bucketed(b, w, l, r, margin, headroom)


def pick_wait_and_staleness(q_hat: float, lag_est: ArrivalLagEstimator,
                            w: int, l: int, r: int, *,
                            coverage: float = 0.9,
                            max_window: int = 4) -> tuple[int, int]:
    """Joint online policy for the pipelined runtime: how many fastest
    workers to wait for, and how long a fold window to keep for the rest.

    ``wait_for`` comes from the cut policy (:func:`pick_wait_for_cached`);
    ``max_staleness`` is the smallest window whose estimated coverage of
    late arrivals (:meth:`ArrivalLagEstimator.coverage`) reaches
    ``coverage``, capped at ``max_window`` — if even the cap cannot reach
    it, the cap is returned (recovering SOME late partials still beats
    dropping them all).  Returns ``(wait_for, max_staleness)``.
    """
    wait = pick_wait_for_cached(q_hat, w, l, r)
    cap = int(min(max_window, lag_est.max_lag))
    staleness = cap
    for s in range(cap + 1):
        if lag_est.coverage(s) >= coverage:
            staleness = s
            break
    return wait, staleness
