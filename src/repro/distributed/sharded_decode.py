"""Sharded master decode: check tiles partitioned over the workers mesh.

Once N outgrows one device, the master's peeling decode itself must shard.
The peeling update is per-variable OVERWRITE semantics (a solvable check
writes its resolved neighbour's value), NOT an f32 contraction — so unlike
the gradient epilogue it shards WITHOUT changing any summation order: each
check row's sum stays entirely inside the shard that owns the row, and the
cross-shard merge is a select, not an add.  That is what makes the sharded
decode bit-identical to the single-device one (proved by
``repro.distributed.selfcheck --master-decode sharded`` and
``tests/test_distributed.py`` on the fake 8-device mesh).

Layout: the CHECK-side neighbor table (``check_idx`` / ``check_coeff``,
padded so the check count divides the mesh — pad rows are degree-0 checks:
sentinel-indexed, zero-weighted, never solvable) is partitioned
``P("workers", None)`` over the mesh's ``"workers"`` axis; the value vector
and erasure mask stay replicated.  Each round, every device runs the SAME
:func:`repro.core.decoder.peel_round_sparse` the single-device master runs,
restricted to its own check rows, and the per-shard results are
all-gathered ONCE and merged in ascending device order with
later-shard-overwrites.  Ascending contiguous row shards make that merge
order exactly the ascending-check-row order in which XLA applies the
single-device round's duplicate scatter updates (updates are applied in
operand order), so even the rare same-round duplicate resolutions land on
identical bits.  (Scatter duplicate order is implementation-defined in HLO;
the selfcheck is the guard on any backend where it differs.)

Budget policy mirrors the single-device master: the fixed mode runs a
static number of rounds; the telemetry mode takes the round budget as a
TRACED ``(1,)`` operand and early-exits on the shared
no-progress/nothing-erased/budget-exhausted predicate (computed from the
replicated mask, so every device agrees), returning the rounds spent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.core.decoder import peel_round_sparse
from repro.core.ldpc import LDPCCode

__all__ = ["pad_check_tables", "shard_check_tables", "build_sharded_decode"]


def pad_check_tables(code: LDPCCode, n_shards: int
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Check-side neighbor table padded so ``p`` divides ``n_shards``.

    Pad rows are degree-0 checks (``check_idx`` = the sentinel ``N``,
    ``check_coeff`` = 0): their erased-neighbour count is always 0, so they
    are never solvable and never write — the padded decode follows the
    unpadded trajectory exactly.
    """
    idx, coeff = code.check_idx, code.check_coeff
    p, r_max = idx.shape
    pad = (-p) % n_shards
    if pad:
        idx = np.concatenate(
            [idx, np.full((pad, r_max), code.N, np.int32)])
        coeff = np.concatenate([coeff, np.zeros((pad, r_max), np.float32)])
    return idx, coeff


def shard_check_tables(code: LDPCCode, mesh: Mesh,
                       axis: str = "workers") -> tuple[jax.Array, jax.Array]:
    """``device_put`` the (padded) check tables row-sharded over ``axis``."""
    n_dev = mesh.shape[axis]
    idx, coeff = pad_check_tables(code, n_dev)
    sh = NamedSharding(mesh, P(axis, None))
    return jax.device_put(idx, sh), jax.device_put(coeff, sh)


def build_sharded_decode(mesh: Mesh, *, iters: int, adaptive: bool = False,
                         axis: str = "workers"):
    """The sharded fixed-D / adaptive peeling decode over ``mesh``.

    Returns ``decode(check_idx_sh, check_coeff_sh, values, erased, budget)``
    → ``(values, erased, rounds ()i32)`` where the tables are row-sharded
    ``P(axis, None)`` (see :func:`shard_check_tables`), ``values (N, V)``
    and ``erased (N,) bool`` are replicated, and ``budget (1,) int32`` is
    the traced round cap of the adaptive mode (ignored — rounds ==
    ``iters`` — when ``adaptive=False``).  The function is shard_map-ped
    but NOT jitted; callers jit the surrounding master program.
    """
    n_dev = mesh.shape[axis]

    def local_decode(idx_sh, coeff_sh, values, erased, budget):
        # Runs per device: idx/coeff are this device's check rows; values,
        # erased, and budget are replicated (identical on every device).
        def round_body(v, e):
            v_d, e_d = peel_round_sparse(idx_sh, coeff_sh, v, e)
            resolved_d = e & ~e_d                          # (N,)
            # ONE all-gather of the round's per-shard results ...
            V_all = jax.lax.all_gather(v_d, axis)          # (W, N, V)
            R_all = jax.lax.all_gather(resolved_d, axis)   # (W, N)

            # ... merged in ascending device order, later shard overwrites:
            # == ascending global check-row order == the order XLA applies
            # the single-device scatter's duplicate updates.  Pure selects —
            # no f32 sum crosses a shard boundary.
            def merge(d, carry):
                v_, e_ = carry
                r = jax.lax.dynamic_index_in_dim(R_all, d, keepdims=False)
                vd = jax.lax.dynamic_index_in_dim(V_all, d, keepdims=False)
                return jnp.where(r[:, None], vd, v_), e_ & ~r

            return jax.lax.fori_loop(0, n_dev, merge, (v, e))

        if not adaptive:
            vals, e = jax.lax.fori_loop(
                0, iters, lambda _, c: round_body(*c), (values, erased))
            return vals, e, jnp.int32(iters)

        def cond(carry):
            _, e, d, progressed = carry
            return (d < budget[0]) & progressed & e.any()

        def body(carry):
            v, e, d, _ = carry
            v2, e2 = round_body(v, e)
            return v2, e2, d + 1, (e2 != e).any()

        vals, e, d, _ = jax.lax.while_loop(
            cond, body, (values, erased, jnp.int32(0), jnp.bool_(True)))
        return vals, e, d

    return shard_map(
        local_decode, mesh=mesh,
        in_specs=(P(axis, None), P(axis, None), P(), P(), P()),
        out_specs=(P(), P(), P()),
        check_rep=False)
