"""Worker side of the distributed coded pipeline.

Each worker owns a contiguous shard of the encoded moment's rows (its slice
of ``C = G·M``) and, per step, computes the partial products for exactly
those rows — ``z_local = C_shard @ θ`` — then reports them to the master.
A straggling worker reports nothing, which the master sees as the erasure
of ALL of that worker's rows: straggler injection is realized here at
per-WORKER granularity (``StragglerModel`` masks sampled at width ``W``
and lifted through :meth:`repro.distributed.topology.WorkerTopology
.to_symbol_erasure`), not per-symbol as the single-device simulation does.

:func:`build_worker_products` returns the ``shard_map``-ped compute over
the mesh's ``"workers"`` axis.  Inside the mapped function every device
sees only its own row shard — the per-device working set is
``(N / n_devices) × k``, which is what lets the encoded operator scale past
single-device memory.  The erasure zeroing ALSO runs worker-side (a real
straggler never sends bytes); the master re-applies its own mask when it
decodes, so the two layers cannot disagree.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.straggler import StragglerModel
from repro.distributed.topology import WorkerTopology, row_sharding

__all__ = ["WorkerStragglers", "local_products", "build_worker_products",
           "shard_encoded_rows"]


@dataclasses.dataclass(frozen=True)
class WorkerStragglers:
    """A per-symbol :class:`~repro.core.straggler.StragglerModel`, lifted to
    per-WORKER granularity: sample a (W,) worker mask, then expand it to the
    (N,) symbol erasure through the topology's row assignment.

    Any model satisfying the ``StragglerModel`` protocol lifts unchanged —
    the protocol's width argument is simply the worker count instead of the
    symbol count (Bernoulli q0 per worker, exactly-s workers, adversarial
    fixed worker sets, ...).
    """

    model: StragglerModel
    topology: WorkerTopology

    def sample_workers(self, key: jax.Array) -> jax.Array:
        """(W,) bool — which workers straggle this step."""
        return self.model.sample(key, self.topology.n_workers)

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        """StragglerModel protocol: (N,) symbol mask (for drop-in use by
        ``run_pgd``-style drivers that expect per-symbol masks)."""
        if w != self.topology.N:
            raise ValueError(f"expected symbol width {self.topology.N}, got {w}")
        return self.topology.to_symbol_erasure(self.sample_workers(key))


def local_products(C_shard: jax.Array, theta: jax.Array,
                   erased_shard: jax.Array) -> jax.Array:
    """One worker shard's step: partial products, zeroed if straggling.

    Runs INSIDE ``shard_map`` — ``C_shard`` is this device's
    ``(rows/device, k)`` slice, ``theta`` is replicated, ``erased_shard``
    this device's slice of the symbol erasure mask.  Row-block matvecs are
    bitwise identical to the corresponding rows of the full ``C @ θ`` (each
    output element is an independent dot product), which is what makes the
    distributed trajectory reproduce the single-device one bit-for-bit.
    """
    z = C_shard @ theta
    return jnp.where(erased_shard, 0.0, z)


def build_worker_products(mesh: Mesh):
    """The sharded worker-compute stage: ``(C, θ, erased) → z (N,)``.

    ``C`` sharded ``P("workers", None)``, ``θ`` replicated, ``erased``
    sharded ``P("workers")``; the output keeps the row sharding — the
    master's gather happens where the decode consumes it (XLA inserts the
    all-gather at the jit boundary's replicated consumer).
    """
    return shard_map(
        local_products, mesh=mesh,
        in_specs=(P("workers", None), P(), P("workers")),
        out_specs=P("workers"))


def shard_encoded_rows(C: jax.Array, mesh: Mesh,
                       topology: WorkerTopology) -> jax.Array:
    """Place the encoded operator with rows split over the workers axis.

    Validates that worker shards do not straddle devices, then
    ``device_put``s ``C (N, k)`` with ``P("workers", None)`` — after this
    every device holds only its own workers' rows.
    """
    if C.shape[0] != topology.N:
        raise ValueError(f"C has {C.shape[0]} rows; topology expects "
                         f"{topology.N}")
    topology.validate_mesh(mesh)
    return jax.device_put(C, row_sharding(mesh))
