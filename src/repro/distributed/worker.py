"""Worker side of the distributed coded pipeline.

Each worker owns a contiguous shard of the encoded moment's rows (its slice
of ``C = G·M``) and, per step, computes the partial products for exactly
those rows — ``z_local = C_shard @ θ`` — then reports them to the master.
A straggling worker reports nothing, which the master sees as the erasure
of ALL of that worker's rows: straggler injection is realized here at
per-WORKER granularity (``StragglerModel`` masks sampled at width ``W``
and lifted through :meth:`repro.distributed.topology.WorkerTopology
.to_symbol_erasure`), not per-symbol as the single-device simulation does.

:func:`build_worker_products` returns the ``shard_map``-ped compute over
the mesh's ``"workers"`` axis.  Inside the mapped function every device
sees only its own row shard — the per-device working set is
``(N / n_devices) × k``, which is what lets the encoded operator scale past
single-device memory.  The erasure zeroing ALSO runs worker-side (a real
straggler never sends bytes); the master re-applies its own mask when it
decodes, so the two layers cannot disagree.

SEEDED workers (:func:`local_products_seeded` /
:func:`build_seeded_worker_products`): for a seeded LDGM code the worker
never holds its rows of the encoding matrix AT ALL — it keeps only its
``(rows/device, row_weight)`` slice of the generator gather tables
(regenerable from ``(seed, row)``; :func:`shard_generator_tables`) and
fuses encode into the matvec: ``y = M θ`` (replicated — the same bits on
every device), then ``z_local = Σ_s coeff·y[idx]`` over its rows.  This is
the SAME per-row gather+sum the single-device seeded
``Scheme2.build_seeded`` runs, so distributed products are bit-identical
to the single-device ones; the per-device structure footprint drops from
``(N/W)·k`` floats to ``(N/W)·row_weight`` table entries.
:func:`build_seeded_fused_worker_products` goes one step further: the
gather runs inside the fused Pallas encode kernel with indices regenerated
in-register from the seed, so workers hold NO tables at all (structure
footprint: a few ints).

The worker payload may be 2-D: ``theta (k, dim)`` (coded gradient
AGGREGATION, where each systematic symbol is a flattened partial gradient)
produces ``z (rows, dim)`` — the same row-sharded program serves
:class:`repro.distributed.master.DistributedCodedAggregator`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.encoding import gather_encode, generator_structure_of
from repro.core.ldpc import LDPCCode, seeded_generator_rows
from repro.core.straggler import StragglerModel
from repro.distributed.topology import WorkerTopology, row_sharding

__all__ = ["WorkerStragglers", "local_products", "build_worker_products",
           "shard_encoded_rows", "local_products_seeded",
           "build_seeded_worker_products", "shard_generator_tables",
           "build_seeded_fused_worker_products"]


@dataclasses.dataclass(frozen=True)
class WorkerStragglers:
    """A per-symbol :class:`~repro.core.straggler.StragglerModel`, lifted to
    per-WORKER granularity: sample a (W,) worker mask, then expand it to the
    (N,) symbol erasure through the topology's row assignment.

    Any model satisfying the ``StragglerModel`` protocol lifts unchanged —
    the protocol's width argument is simply the worker count instead of the
    symbol count (Bernoulli q0 per worker, exactly-s workers, adversarial
    fixed worker sets, ...).
    """

    model: StragglerModel
    topology: WorkerTopology

    def sample_workers(self, key: jax.Array) -> jax.Array:
        """(W,) bool — which workers straggle this step."""
        return self.model.sample(key, self.topology.n_workers)

    def sample(self, key: jax.Array, w: int) -> jax.Array:
        """StragglerModel protocol: (N,) symbol mask (for drop-in use by
        ``run_pgd``-style drivers that expect per-symbol masks)."""
        if w != self.topology.N:
            raise ValueError(f"expected symbol width {self.topology.N}, got {w}")
        return self.topology.to_symbol_erasure(self.sample_workers(key))


def local_products(C_shard: jax.Array, theta: jax.Array,
                   erased_shard: jax.Array) -> jax.Array:
    """One worker shard's step: partial products, zeroed if straggling.

    Runs INSIDE ``shard_map`` — ``C_shard`` is this device's
    ``(rows/device, k)`` slice, ``theta`` is replicated, ``erased_shard``
    this device's slice of the symbol erasure mask.  Row-block matvecs are
    bitwise identical to the corresponding rows of the full ``C @ θ`` (each
    output element is an independent dot product), which is what makes the
    distributed trajectory reproduce the single-device one bit-for-bit.

    ``theta`` may also be a 2-D ``(k, dim)`` payload (coded gradient
    aggregation) — ``z`` is then ``(rows, dim)`` with the erasure mask
    broadcast over the payload axis.
    """
    z = C_shard @ theta
    m = erased_shard
    while m.ndim < z.ndim:
        m = m[..., None]
    return jnp.where(m, 0.0, z)


def build_worker_products(mesh: Mesh):
    """The sharded worker-compute stage: ``(C, θ, erased) → z (N, ...)``.

    ``C`` sharded ``P("workers", None)``, ``θ`` replicated (``(k,)`` or a
    ``(k, dim)`` payload block), ``erased`` sharded ``P("workers")``; the
    output keeps the row sharding — the master's gather happens where the
    decode consumes it (XLA inserts the all-gather at the jit boundary's
    replicated consumer).
    """
    return shard_map(
        local_products, mesh=mesh,
        in_specs=(P("workers", None), P(), P("workers")),
        out_specs=P("workers"))


def local_products_seeded(idx_shard: jax.Array, coeff_shard: jax.Array,
                          M: jax.Array, theta: jax.Array,
                          erased_shard: jax.Array) -> jax.Array:
    """One worker shard's step with the encode FUSED into the matvec.

    Runs INSIDE ``shard_map``.  ``idx_shard``/``coeff_shard`` are this
    device's ``(rows/device, row_weight)`` generator gather tables —
    everything it ever stores about the code; ``M (k, k)`` and ``theta``
    are replicated.  Each device computes ``y = M θ`` locally (replicated
    math: identical bits everywhere, no communication) and gathers its
    rows of the codeword — the exact gather+sum
    :func:`repro.core.encoding.gather_encode` runs on a single device, so
    products are bit-identical to ``Scheme2.build_seeded``'s.
    """
    y = M @ theta
    z = gather_encode(idx_shard, coeff_shard, y)
    m = erased_shard
    while m.ndim < z.ndim:
        m = m[..., None]
    return jnp.where(m, 0.0, z)


def build_seeded_worker_products(mesh: Mesh):
    """The seeded sharded worker stage: ``(idx, coeff, M, θ, erased) → z``.

    Gather tables row-sharded ``P("workers", None)``; ``M``/``θ``
    replicated; ``erased`` sharded ``P("workers")``; output row-sharded
    like :func:`build_worker_products`'s.
    """
    return shard_map(
        local_products_seeded, mesh=mesh,
        in_specs=(P("workers", None), P("workers", None), P(), P(),
                  P("workers")),
        out_specs=P("workers"))


def build_seeded_fused_worker_products(code: LDPCCode, mesh: Mesh):
    """The FUSED seeded worker stage: ``(M, θ, erased) → z`` — no tables.

    Each device computes ``y = M θ`` (replicated math) and runs the fused
    Pallas encode kernel over ITS OWN codeword row window, regenerating the
    generator's (column, weight) pairs in-register from the code's seed:
    the per-device structure footprint drops from ``(N/W)·row_weight``
    table entries to the handful of seed ints baked into the program.  The
    row offset ``axis_index · rows_per_worker`` is a TRACED kernel operand,
    so all shards share one compilation.  Products are bit-identical to
    :func:`local_products_seeded`'s under jit (the kernel and the
    sequential :func:`repro.core.encoding.gather_encode` lower to the same
    FMA chain) — and therefore to ``Scheme2.build_seeded``'s.
    """
    from repro.kernels.ldpc_peel.ops import encode_seeded_fused_pallas

    st = generator_structure_of(code)
    n_workers = mesh.shape["workers"]
    if code.N % n_workers:
        raise ValueError(f"N={code.N} not divisible by {n_workers} workers")
    rows_per = code.N // n_workers

    def local_fused(M, theta, erased_shard):
        y = M @ theta
        row0 = jax.lax.axis_index("workers") * rows_per
        z = encode_seeded_fused_pallas(st, y, row0, n_out=rows_per)
        m = erased_shard
        while m.ndim < z.ndim:
            m = m[..., None]
        return jnp.where(m, 0.0, z)

    # check_rep=False: shard_map has no replication rule for pallas_call;
    # the kernel only READS the replicated y, so the spec stays sound.
    return shard_map(
        local_fused, mesh=mesh,
        in_specs=(P(), P(), P("workers")),
        out_specs=P("workers"), check_rep=False)


def shard_generator_tables(code: LDPCCode, mesh: Mesh,
                           topology: WorkerTopology
                           ) -> tuple[jax.Array, jax.Array]:
    """Place a seeded code's generator gather tables row-sharded.

    ``(idx (N, row_weight) int32, coeff (N, row_weight) f32)`` with rows
    split over the workers axis — after this every device holds only its
    own workers' table rows (a real deployment would regenerate them from
    ``(seed, row)`` on arrival; here the host builds them once and shards).
    """
    topology.validate_mesh(mesh)
    idx, coeff = seeded_generator_rows(code, 0, code.N)
    sharding = row_sharding(mesh)
    return (jax.device_put(jnp.asarray(idx), sharding),
            jax.device_put(jnp.asarray(coeff), sharding))


def shard_encoded_rows(C: jax.Array, mesh: Mesh,
                       topology: WorkerTopology) -> jax.Array:
    """Place the encoded operator with rows split over the workers axis.

    Validates that worker shards do not straddle devices, then
    ``device_put``s ``C (N, k)`` with ``P("workers", None)`` — after this
    every device holds only its own workers' rows.
    """
    if C.shape[0] != topology.N:
        raise ValueError(f"C has {C.shape[0]} rows; topology expects "
                         f"{topology.N}")
    topology.validate_mesh(mesh)
    return jax.device_put(C, row_sharding(mesh))
