"""Worker topology: encoded-row → worker assignment and mesh placement.

The paper's system is W workers, each storing a contiguous block of rows of
the encoded moment ``C = G·M`` and returning the partial products for its
rows each step; a straggling worker erases ALL of its rows at once.  This
module owns the two mappings everything distributed builds on:

* **row → worker**: row ``i`` belongs to worker ``i // (N/W)`` (contiguous
  blocks — the systematic coordinates land on the first ``W·K/N`` workers,
  matching the paper's storage layout where worker ``j`` holds ``c_j``).
  :meth:`WorkerTopology.to_symbol_erasure` lifts a per-WORKER straggler
  mask ``(W,)`` to the per-symbol erasure mask ``(N,)`` the decoder
  consumes; the lift is a partition (every symbol is covered by exactly one
  worker — property-tested), so worker-granular straggling is exactly the
  erasure-channel abstraction the analysis is built on, just with
  block-correlated erasures.

* **worker → device**: :func:`make_worker_mesh` builds a 1-D JAX mesh with
  a ``"workers"`` axis (layered on :mod:`repro.launch.mesh`'s conventions:
  a function, never module-level device state).  Logical workers are
  decoupled from devices — ``W`` logical workers shard onto ``n_devices``
  mesh slots (each device simulates ``W / n_devices`` workers), so the same
  :class:`repro.distributed.master.DistributedCodedGD` runs on one real CPU
  device, the fake 8-device CI mesh
  (``XLA_FLAGS=--xla_force_host_platform_device_count=8``), or a real TPU
  slice, with bit-identical trajectories.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["WorkerTopology", "make_worker_mesh", "row_sharding",
           "replicated_sharding"]


def make_worker_mesh(n_devices: int | None = None) -> Mesh:
    """1-D device mesh with the ``"workers"`` axis.

    Uses the first ``n_devices`` JAX devices (default: all).  Like
    :func:`repro.launch.mesh.make_mesh` this is a function — importing the
    module never touches device state, so tests/benchmarks keep seeing
    whatever device set their process was started with.
    """
    devs = jax.devices()
    if n_devices is not None:
        if n_devices > len(devs):
            raise ValueError(
                f"asked for {n_devices} devices, have {len(devs)} "
                "(set XLA_FLAGS=--xla_force_host_platform_device_count=N "
                "before the first jax import to fake a CPU mesh)")
        devs = devs[:n_devices]
    return Mesh(np.array(devs), ("workers",))


def row_sharding(mesh: Mesh) -> NamedSharding:
    """Encoded rows (leading axis) split over the ``"workers"`` axis."""
    return NamedSharding(mesh, P("workers"))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Master-side state (θ, b, decode tables): replicated on every device."""
    return NamedSharding(mesh, P())


@dataclasses.dataclass(frozen=True)
class WorkerTopology:
    """Assignment of the N encoded rows to W logical workers.

    ``n_workers`` is the paper's ``w`` knob, independent of the device
    count; :class:`~repro.distributed.master.DistributedCodedGD` additionally
    requires ``n_workers`` to be divisible by the mesh size so no worker's
    rows straddle a device shard.
    """

    n_workers: int
    N: int

    def __post_init__(self) -> None:
        if self.n_workers < 1:
            raise ValueError(f"need at least one worker; got {self.n_workers}")
        if self.N % self.n_workers != 0:
            raise ValueError(
                f"N={self.N} encoded rows do not split evenly over "
                f"W={self.n_workers} workers")

    @property
    def rows_per_worker(self) -> int:
        return self.N // self.n_workers

    @property
    def worker_of_row(self) -> np.ndarray:
        """(N,) int32 — the owning worker of every encoded row."""
        return np.repeat(np.arange(self.n_workers, dtype=np.int32),
                         self.rows_per_worker)

    def worker_rows(self, j: int) -> slice:
        if not 0 <= j < self.n_workers:
            raise IndexError(f"worker {j} out of range [0, {self.n_workers})")
        rpw = self.rows_per_worker
        return slice(j * rpw, (j + 1) * rpw)

    def to_symbol_erasure(self, worker_mask: jax.Array) -> jax.Array:
        """Lift a per-worker straggler mask to the per-symbol erasure mask.

        ``worker_mask (..., W) bool`` → ``(..., N) bool``: a straggling
        worker erases exactly its own rows.  jit-able (pure repeat along the
        last axis), and a partition: summing the result back per worker
        recovers ``rows_per_worker * worker_mask`` exactly.
        """
        return jnp.repeat(jnp.asarray(worker_mask, bool),
                          self.rows_per_worker, axis=-1)

    def observed_fraction(self, worker_mask: jax.Array) -> jax.Array:
        """Per-step straggler fraction the telemetry estimator consumes."""
        return jnp.asarray(worker_mask, jnp.float32).mean(axis=-1)

    def validate_mesh(self, mesh: Mesh) -> int:
        """Check worker shards don't straddle devices; returns mesh size."""
        n_dev = mesh.shape["workers"]
        if self.n_workers % n_dev != 0:
            raise ValueError(
                f"W={self.n_workers} logical workers cannot shard onto "
                f"{n_dev} mesh devices (need n_devices | W)")
        return n_dev
