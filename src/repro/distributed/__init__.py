"""Sharded coded-worker runtime: master/worker moment-encoded GD over a
real device mesh, with online straggler telemetry driving decode budgets.

Layers (each its own module):

* :mod:`repro.distributed.topology` — worker mesh construction, encoded-row
  → worker assignment, per-worker → per-symbol erasure lifting;
* :mod:`repro.distributed.worker` — per-worker shard ownership and local
  partial-product compute (``shard_map`` over the ``"workers"`` axis), with
  straggler injection at per-WORKER granularity; the SEEDED variant fuses
  encode into the matvec so workers hold only generator gather tables
  (regenerable from the seed), never encoding-matrix rows;
* :mod:`repro.distributed.master` — survivor gather, decode through the
  shared :class:`repro.core.engine.CodedComputeEngine` backends, the
  :class:`~repro.distributed.master.DistributedCodedGD` driver (bit-identical
  to single-device ``Scheme2``; ``worker_encode="seeded"`` swaps the
  sharded encoded operator for seeded on-the-fly worker encode), the
  :class:`~repro.distributed.master.DistributedCodedAggregator` serving the
  additive-loss ``grad_agg`` path over the same worker launch, and the
  production-scale AOT step;
* :mod:`repro.distributed.sharded_decode` — the master decode itself sharded
  over the mesh (``master_decode="sharded"``): check tiles partitioned over
  the ``"workers"`` axis, per-round all-gather merge, bit-identical to the
  single-device sparse decode (overwrite semantics shard without changing
  f32 summation order);
* :mod:`repro.distributed.telemetry` — online EMA straggler-rate estimation
  feeding density evolution to pick wait-for thresholds, per-step adaptive
  decode budgets, and (for the pipelined runtime) arrival-lag-driven fold
  windows;
* :mod:`repro.distributed.pipeline` — the depth-k asynchronous runtime
  (:class:`~repro.distributed.pipeline.AsyncDistributedCodedGD`): worker
  launch ``t+1`` dispatched before decode ``t`` is consumed (double-buffered
  θ broadcasts, donated master buffers), late straggler partials within
  ``max_staleness`` steps folded into the current update with staleness
  weights ``w(τ)``.  Depth 1 with a zero fold window is bit-identical to
  :class:`~repro.distributed.master.DistributedCodedGD`, which stays the
  synchronous parity reference.
"""
from repro.distributed.master import (
    DistributedCodedAggregator,
    DistributedCodedGD,
    DistributedRunResult,
    build_distributed_gd_step,
    delay_step_control,
)
from repro.distributed.pipeline import (
    AsyncDistributedCodedGD,
    PipelineRunResult,
    pipeline_timeline,
)
from repro.distributed.sharded_decode import (
    build_sharded_decode,
    shard_check_tables,
)
from repro.distributed.telemetry import (
    ArrivalLagEstimator,
    StragglerRateEstimator,
    decode_budget,
    pick_wait_and_staleness,
    pick_wait_for,
    pick_wait_for_cached,
    rounds_to_clear,
)
from repro.distributed.topology import (
    WorkerTopology,
    make_worker_mesh,
    row_sharding,
)
from repro.distributed.worker import (
    WorkerStragglers,
    build_seeded_worker_products,
    build_worker_products,
    shard_encoded_rows,
    shard_generator_tables,
)

__all__ = [
    "DistributedCodedGD", "DistributedRunResult", "build_distributed_gd_step",
    "DistributedCodedAggregator", "delay_step_control",
    "AsyncDistributedCodedGD", "PipelineRunResult", "pipeline_timeline",
    "build_sharded_decode", "shard_check_tables",
    "StragglerRateEstimator", "ArrivalLagEstimator", "decode_budget",
    "pick_wait_for", "pick_wait_for_cached", "pick_wait_and_staleness",
    "rounds_to_clear",
    "WorkerTopology", "make_worker_mesh", "row_sharding",
    "WorkerStragglers", "build_worker_products", "shard_encoded_rows",
    "build_seeded_worker_products", "shard_generator_tables",
]
