"""Pipelined master/worker runtime: overlap worker matvec with master
decode, fold late stragglers into later updates with staleness weights.

The synchronous :class:`repro.distributed.master.DistributedCodedGD` runs
encode → wait → decode → update as a strict barrier per step, so worker
latency and master decode time ADD, and every worker slower than the
wait-for cutoff is erased outright.  This module relaxes both, keeping the
synchronous driver as the bit-parity reference:

**Double-buffered θ broadcast (depth-k pipeline).**  With ``depth = k``,
step ``t``'s worker launch computes its partial products at
``θ_{t-depth+1}`` — the newest iterate whose decode has certainly been
DISPATCHED by then — so the SPMD worker program of step ``t+1`` and the
master decode program of step ``t`` are independent device programs in
flight together (classic delayed-gradient SGD; "Stochastic Gradient
Coding", Bitar et al., arXiv:1905.05383, gives the convergence frame: a
stale gradient is a bounded-bias oracle, the paper's erasure model is the
zero-staleness limit).  ``depth = 1`` is the synchronous dependency chain
and stays BIT-IDENTICAL to ``DistributedCodedGD`` (``selfcheck
--pipeline``).  The host never calls ``block_until_ready`` on the critical
path: a bounded deque holds at most ``depth`` steps' un-pulled scalars and
JAX async dispatch keeps both device programs queued.

**Device-resident carried state.**  θ and the running average live on the
master device and thread through the fused master program (θ̄ with
``donate_argnums``; θ's output buffer doubles zero-copy as the master
shard of the replicated broadcast) — the per-step cost is ONE replicated
broadcast of the new iterate, not the synchronous path's put-per-operand
churn.  The convergence metric and the running average are computed INSIDE
the master program (θ* rides along as a traced operand), so a driver step
is exactly two device programs plus one broadcast.

**Late-arrival folding.**  Under a delay model, a worker slower than the
cutoff but landing within ``max_staleness`` later steps is not erased
forever: its partial products (computed at the stale θ it was given) are
re-decoded against the stored survivor vector of its source step, and the
NEWLY resolved coordinates enter the current update as a staleness-weighted
delta ``w(τ) · debias(ĉ′ − b)`` (``w(τ) = staleness_decay^τ``).  The fold
re-decode depends only on the source step's stored ``(z, mask)`` — not on
the current θ — so it pipelines like everything else.
``staleness_decay = 0`` (w ≡ 0) reproduces today's drop semantics exactly.
:class:`repro.distributed.telemetry.ArrivalLagEstimator` learns where late
arrivals land and :func:`repro.distributed.telemetry.pick_wait_and_staleness`
chooses ``(wait_for, max_staleness)`` online (``auto_staleness=True``).

:func:`pipeline_timeline` composes the simulated wall-clock of a depth-k
run from the injected worker delays and per-step decode service times —
the same simulated clock :class:`DistributedRunResult` has always recorded
(``step_times`` = the wait-for order statistic), extended to count master
decode time and pipeline overlap.  The benchmark's ``pipeline`` section
gates the sync/pipelined steps-per-second ratio on that clock, alongside
the measured host wall-clock ratio.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.coded_step import Scheme2
from repro.core.straggler import DelayModel
from repro.distributed.master import (
    DistributedCodedGD,
    _record_plan_metrics,
    _record_step_metrics,
    delay_step_control,
)
from repro.distributed.telemetry import (
    ArrivalLagEstimator,
    StragglerRateEstimator,
    decode_budget,
    pick_wait_and_staleness,
    pick_wait_for_cached,
)
from repro.distributed.topology import WorkerTopology
from repro.obs import metrics as _obs_metrics
from repro.obs import trace as _obs_trace
from repro.obs.trace import span as _span

__all__ = ["AsyncDistributedCodedGD", "PipelineRunResult",
           "pipeline_timeline"]


class PipelineRunResult(NamedTuple):
    theta: jax.Array         # final iterate
    theta_bar: jax.Array     # running average (folded into the master program)
    errors: np.ndarray       # (T,) ||θ_t - θ*|| (or loss / norm)
    unresolved: np.ndarray   # (T,) |U_t| per step AFTER late folds landed
    resolved_late: np.ndarray  # (T,) coords recovered by folds, per SOURCE step
    rounds: np.ndarray       # (T,) main-decode rounds spent per step
    fold_rounds: np.ndarray  # (T,) fold-decode rounds spent per step
    budgets: np.ndarray      # (T,) round budget granted per step
    rates: np.ndarray        # (T,) telemetry estimate q̂ entering each step
    wait_for: np.ndarray     # (T,) workers waited for (delay runs; else W)
    staleness: np.ndarray    # (T,) fold window in force per step
    step_times: np.ndarray   # (T,) simulated wait at the cutoff (delay runs)
    thetas: np.ndarray | None  # (T, K) per-step iterates (record_thetas=True)


def pipeline_timeline(waits, decode_times, depth: int
                      ) -> tuple[np.ndarray, np.ndarray]:
    """Simulated wall-clock of a depth-k pipelined run.

    ``waits[t]`` is step ``t``'s worker phase (the injected wait-for order
    statistic), ``decode_times[t]`` its master phase (decode service,
    including any folds dispatched that step).  Worker launch ``t`` needs
    ``θ_{t-depth+1}``, i.e. the master phase of step ``t - depth + 1`` to
    have finished, and the worker fleet / the master each run one phase at
    a time — the classic two-stage pipeline recurrence:

      worker_end[t] = max(worker_end[t-1], master_end[t-depth]) + waits[t]
      master_end[t] = max(master_end[t-1], worker_end[t]) + decode_times[t]

    ``depth = 1`` degenerates to the synchronous barrier (total =
    Σ waits + Σ decode_times); larger depths hide the shorter phase behind
    the longer one.  Returns ``(worker_end, master_end)`` as (T,) arrays;
    ``master_end[-1]`` is the run's makespan.
    """
    if depth < 1:
        raise ValueError(f"depth must be >= 1; got {depth}")
    waits = np.asarray(waits, float)
    decode_times = np.asarray(decode_times, float)
    T = waits.shape[0]
    w_end = np.zeros(T)
    m_end = np.zeros(T)
    for t in range(T):
        theta_ready = m_end[t - depth] if t - depth >= 0 else 0.0
        start = max(w_end[t - 1] if t else 0.0, theta_ready)
        w_end[t] = start + waits[t]
        m_end[t] = max(m_end[t - 1] if t else 0.0, w_end[t]) + decode_times[t]
    return w_end, m_end


@dataclasses.dataclass
class _FoldEntry:
    """Stored survivors of one step, waiting for late arrivals to land."""
    step: int
    z_m: jax.Array           # (N,) master-device view of the worker output
    u: jax.Array             # (K,) unresolved mask on the master (updated)
    cut_mask: np.ndarray     # (W,) workers missed at the cutoff
    lags: np.ndarray         # (W,) arrival lags in step units
    window: int              # fold window in force at the source step


@dataclasses.dataclass
class _StepPlan:
    """One step's control-plane decision, fixed before any device work.

    This is the pipeline's per-step control record (formerly an internal
    ``ctrl`` dict): everything the host decided — the wait-for cut, the
    fold window, the decode budget, the telemetry estimate it acted on —
    lives here, is recorded into the obs registry at PLAN time (it is all
    host data; nothing waits on a device), and is reported back through
    :class:`PipelineRunResult`'s tail arrays.
    """
    cut: np.ndarray            # (W,) workers missed at the wait-for cutoff
    never: np.ndarray          # (W,) rows zeroed outright (outside window)
    lags: np.ndarray | None    # (W,) arrival lags (delay runs)
    wait: int                  # workers waited for
    window: int                # fold window in force
    budget: int                # decode round budget granted
    rate: float                # telemetry estimate q̂ ENTERING the step
    cutoff: float              # simulated wall-clock at the cutoff
    observed: float | None     # realized straggler fraction (telemetry obs)

    def record(self) -> None:
        """Feed the plan into the obs registry (host data only)."""
        _record_plan_metrics("pipeline", wait_for=self.wait, rate=self.rate,
                             observed=self.observed)
        reg = _obs_metrics.active()
        if reg is None:
            return
        reg.histogram("pipeline.staleness_window",
                      bins=_obs_metrics.LAG_BINS).observe(self.window)
        if self.lags is not None:
            reg.histogram("pipeline.arrival_lag",
                          bins=_obs_metrics.LAG_BINS).observe_many(
                              self.lags[self.cut])


@dataclasses.dataclass
class AsyncDistributedCodedGD:
    """Depth-k pipelined moment-encoded GD over a worker mesh.

    Wraps the synchronous :class:`DistributedCodedGD` (which supplies the
    worker program, the sharded operator placement, and the bit-parity
    reference) and replaces its barrier driver with the pipelined one
    described in the module docstring.  ``depth=1, max_staleness=0`` is
    bit-identical to ``DistributedCodedGD.run``.
    """

    scheme: Scheme2
    topology: WorkerTopology
    mesh: Mesh | None = None
    depth: int = 2
    # Fold window: how many steps a cut-off worker's partials stay foldable
    # (0 = drop semantics).  With auto_staleness=True this is the CAP the
    # online (wait_for, staleness) policy picks within.
    max_staleness: int = 0
    # w(τ) = staleness_decay ** τ for a fold landing τ steps late.  0.0
    # short-circuits every fold (w ≡ 0 ≡ drop semantics, bit-exactly).
    staleness_decay: float = 0.5
    auto_staleness: bool = False
    budget_mode: str = "fixed"
    worker_encode: str = "materialized"
    # "single" (default) or "replay": which decode the fused master program
    # runs.  "replay" pre-solves each step's peeling schedule HOST-SIDE in
    # the plan loop (the step-t mask is known before any device work, so
    # the symbolic solve never sits on the decode critical path) and the
    # per-step decode is the straight-line numeric replay — bit-identical
    # to "single" over a sparse engine.  Passed through to the wrapped
    # synchronous driver so the depth-1 parity reference runs the SAME
    # decode and shares the SAME schedule cache.
    master_decode: str = "single"
    estimator: StragglerRateEstimator | None = None
    lag_estimator: ArrivalLagEstimator | None = None
    max_rounds: int | None = None
    straggler_factor: float = 2.0
    schedule_cache: object | None = None

    def __post_init__(self) -> None:
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1; got {self.depth}")
        if self.master_decode not in ("single", "replay"):
            raise ValueError(
                f"unknown pipeline master_decode {self.master_decode!r}; "
                "want 'single' or 'replay' (the sharded decode has no "
                "pipelined master program)")
        if self.max_staleness < 0:
            raise ValueError(
                f"max_staleness must be >= 0; got {self.max_staleness}")
        if not 0.0 <= self.staleness_decay <= 1.0:
            raise ValueError(f"staleness_decay must be in [0, 1]; "
                             f"got {self.staleness_decay}")
        if self.auto_staleness and self.max_staleness < 1:
            raise ValueError("auto_staleness picks the fold window within "
                             "max_staleness — set max_staleness >= 1")
        # The synchronous runtime supplies worker program + placement (and
        # stays available as the parity reference).  The pipelined master
        # program replaces its per-step master launch.
        self._sync = DistributedCodedGD(
            self.scheme, self.topology, self.mesh,
            budget_mode=self.budget_mode, worker_encode=self.worker_encode,
            master_decode=self.master_decode,
            estimator=self.estimator, max_rounds=self.max_rounds,
            straggler_factor=self.straggler_factor,
            schedule_cache=self.schedule_cache)
        self.mesh = self._sync.mesh
        self.estimator = self._sync.estimator
        self.schedule_cache = self._sync.schedule_cache
        if self.lag_estimator is None:
            self.lag_estimator = ArrivalLagEstimator()
        self.max_rounds = self._sync.max_rounds
        self.master_device = self._sync.master_device
        self._replicated = self._sync._replicated
        self._master_cache: dict = {}
        self._fold_program = self._build_fold_program()
        self._add = jax.jit(jnp.add)

    # ------------------------------------------------------------- programs

    @property
    def n_workers(self) -> int:
        return self.topology.n_workers

    def _build_master_program(self, *, with_folds: bool, loss_fn=None):
        """The fused per-step master program: decode + epilogue + update +
        running average + metric, one launch.  ``with_folds`` statically
        adds the fold-delta operand; the no-fold variant keeps the update
        arithmetic LITERALLY the synchronous program's (the depth-1 parity
        gate compares bits).

        Only θ̄ is donated: the θ output's buffer doubles as the master
        device's shard of the replicated broadcast (``device_put`` to the
        replicated sharding reuses the matching-device buffer zero-copy),
        so donating θ would delete the broadcast under the in-flight
        worker programs.
        """
        scheme, topo = self.scheme, self.topology
        eng = scheme.engine
        fixed = self.budget_mode == "fixed"

        if self.master_decode == "replay":
            # Replay variant: the decode dispatch stays EAGER (the mask is
            # concrete host data, the schedule is a cache hit — pre-solved
            # in the plan loop — and the replay executors jit internally
            # keyed on segment shapes); the value-level epilogue/update/
            # average/metric is ONE jitted program whose elementwise chain
            # is the same arithmetic as the fused variant below, so the
            # depth-1 parity gate against the sync replay driver holds.
            from repro.core.decoder import DecodeResult
            r_eng = dataclasses.replace(eng, backend="replay",
                                        schedule_cache=self.schedule_cache)

            def replay_epilogue(values, erased, theta, tbar, fold_dg, t,
                                theta_star):
                c_hat, unresolved = eng.systematic(
                    DecodeResult(values, erased, jnp.int32(0)))
                g, n_unres = scheme.finish_gradient(c_hat, unresolved)
                if with_folds:
                    g = g + fold_dg
                theta2 = scheme.projection(theta - scheme.lr * g)
                tbar2 = (tbar * t + theta2) / (t + 1.0)
                if loss_fn is None:
                    err = jnp.linalg.norm(theta2 - theta_star)
                else:
                    err = loss_fn(theta2)
                return theta2, tbar2, n_unres, err, unresolved

            epilogue = jax.jit(replay_epilogue, donate_argnums=(3,))

            def replay_master(z, worker_mask, theta, tbar, fold_dg, t,
                              budget, theta_star):
                erased = topo.to_symbol_erasure(jnp.asarray(worker_mask))
                z = r_eng.erase(z, erased)
                if fixed:
                    dec = r_eng.decode(z, erased)
                    values, er2, rounds = (dec.values, dec.erased,
                                           dec.rounds_used)
                else:
                    dec = r_eng.decode_batch(z[None], erased[None],
                                             adaptive=True, budgets=budget)
                    values, er2, rounds = (dec.values[0], dec.erased[0],
                                           dec.rounds_used[0])
                theta2, tbar2, n_unres, err, u = epilogue(
                    values, er2, theta, tbar, fold_dg, t, theta_star)
                return theta2, tbar2, n_unres, rounds, err, u

            replay_master._cache_size = epilogue._cache_size
            return replay_master

        def master_program(z, worker_mask, theta, tbar, fold_dg, t, budget,
                           theta_star):
            erased = topo.to_symbol_erasure(worker_mask)
            if fixed:
                c_hat, unresolved = eng.recover(z, erased)
                rounds = jnp.int32(eng.decode_iters)
            else:
                dec = eng.decode_batch(z[None], erased[None], adaptive=True,
                                       budgets=budget)
                c_hat, unresolved = eng.systematic(dec)
                c_hat, unresolved = c_hat[0], unresolved[0]
                rounds = dec.rounds_used[0]
            g, n_unres = scheme.finish_gradient(c_hat, unresolved)
            if with_folds:
                g = g + fold_dg
            theta2 = scheme.projection(theta - scheme.lr * g)
            tbar2 = (tbar * t + theta2) / (t + 1.0)
            if loss_fn is None:
                err = jnp.linalg.norm(theta2 - theta_star)
            else:
                err = loss_fn(theta2)
            return theta2, tbar2, n_unres, rounds, err, unresolved

        return jax.jit(master_program, donate_argnums=(3,))

    def _get_master_program(self, *, with_folds: bool, loss_fn=None):
        key = (with_folds, id(loss_fn) if loss_fn is not None else None)
        if key not in self._master_cache:
            self._master_cache[key] = self._build_master_program(
                with_folds=with_folds, loss_fn=loss_fn)
        return self._master_cache[key]

    def _build_fold_program(self):
        """Re-decode a stored step's survivors with the newly-landed rows
        restored; the staleness-weighted delta covers exactly the
        coordinates the original decode left unresolved (never resolved
        coords — those were already applied — so nothing double-counts).
        Budget is a traced operand (adaptive decode at B=1): a fold that
        has little left to peel exits early, and changing budgets/weights
        never recompile."""
        scheme, topo = self.scheme, self.topology
        eng = scheme.engine

        def fold_program(z, remaining_mask, u_old, budget, w):
            erased = topo.to_symbol_erasure(remaining_mask)
            dec = eng.decode_batch(eng.erase(z, erased)[None], erased[None],
                                   adaptive=True, budgets=budget)
            c2, u2 = eng.systematic(dec)
            c2, u2 = c2[0], u2[0]
            newly = u_old & ~u2
            delta = scheme._debias(jnp.where(newly, c2 - scheme.b, 0.0)) * w
            return delta, u_old & u2, newly.sum(), dec.rounds_used[0]

        return jax.jit(fold_program)

    def _cache_size(self) -> int:
        """Compiled-variant count across the pipelined programs (the
        no-recompile tests pin this to one per program in use)."""
        sizes = [p._cache_size() for p in self._master_cache.values()]
        return max(sizes + [0]) if sizes else 0

    # -------------------------------------------------------------- driving

    def run(
        self,
        theta0: jax.Array,
        straggler_model,
        steps: int,
        *,
        key: jax.Array | None = None,
        theta_star: jax.Array | None = None,
        loss_fn: Callable[[jax.Array], jax.Array] | None = None,
        delay_model: DelayModel | None = None,
        record_thetas: bool = False,
    ) -> PipelineRunResult:
        """Drive ``steps`` pipelined master steps.

        Mirrors :meth:`DistributedCodedGD.run`'s surface (same key
        schedule, same straggler/delay models, same telemetry policy —
        shared through :func:`repro.distributed.master.delay_step_control`
        so both runtimes realize identical masks).  Folding needs arrival
        lags, so it activates only under a ``delay_model``.
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, steps)
        W = self.n_workers
        code = self.scheme.code
        sync = self._sync
        est = self.estimator
        tau = self.depth - 1

        # ---- control plane, presampled host-side (one pass, no per-step
        # device round-trips in the pipelined loop) ----------------------
        if delay_model is not None:
            delays_all = np.stack([
                np.asarray(delay_model.sample_delays(keys[t], W))
                for t in range(steps)])
        else:
            masks_all = np.stack([
                np.asarray(straggler_model.sample(keys[t], W))
                for t in range(steps)])

        ctrl: list[_StepPlan] = []
        for t in range(steps):
            if delay_model is not None:
                if self.auto_staleness:
                    wait, window = pick_wait_and_staleness(
                        est.rate, self.lag_estimator, W, code.l, code.r,
                        max_window=self.max_staleness)
                else:
                    wait = pick_wait_for_cached(est.rate, W, code.l, code.r)
                    window = self.max_staleness
                cut, cutoff, observed = delay_step_control(
                    delays_all[t], wait, self.straggler_factor)
                lags = DelayModel.arrival_lags(delays_all[t], cutoff)
                self.lag_estimator.observe(lags)
                # workers landing inside the fold window keep their true
                # products in z; only the effectively-never rows are zeroed
                never = cut & (lags > window)
            else:
                wait, window, cutoff = W, 0, 0.0
                cut = never = masks_all[t]
                lags, observed = None, None
            rate = est.rate
            if self.budget_mode == "telemetry":
                if observed is None:
                    observed = float(cut.mean())
                budget = decode_budget(est.observe(observed), code.l, code.r,
                                       max_rounds=self.max_rounds)
            else:
                budget = int(self.scheme.decode_iters)
            plan = _StepPlan(cut=cut, never=never, lags=lags, wait=int(wait),
                             window=int(window), budget=int(budget),
                             rate=float(rate), cutoff=float(cutoff),
                             observed=observed)
            plan.record()
            if self.master_decode == "replay":
                # Pre-solve the step's peeling schedule NOW: the mask is
                # host data before any device work, so a cold pattern's
                # symbolic solve overlaps the worker matvecs instead of
                # sitting in the decode path; the step's decode then hits
                # the cache unconditionally.
                with _span("master/schedule_solve", lane="master", step=t):
                    self.schedule_cache.get(code, np.asarray(
                        self.topology.to_symbol_erasure(jnp.asarray(cut))))
            ctrl.append(plan)

        use_folds = (delay_model is not None and self.staleness_decay > 0.0
                     and any(c.window > 0 for c in ctrl))
        master = self._get_master_program(with_folds=use_folds,
                                          loss_fn=loss_fn)

        # ---- device-resident carried state ------------------------------
        # θ enters the donated master chain through a FRESH host transfer,
        # so the donation can never alias a buffer the caller (or the
        # replicated broadcast) still holds.
        m = self.master_device
        rep = self._replicated
        theta0_np = np.asarray(theta0)
        theta_m = jax.device_put(theta0_np, m)
        tbar_m = jax.device_put(np.zeros_like(theta0_np), m)
        tstar_m = jax.device_put(
            np.zeros_like(theta0_np) if theta_star is None
            else np.asarray(theta_star), m)
        zero_dg = jax.device_put(np.zeros_like(theta0_np), m)
        fold_budget = np.asarray([self.max_rounds], np.int32)
        theta0_rep = jax.device_put(theta0_np, rep)
        theta_rep: list = []     # broadcast iterates, worker-side inputs
        rec_thetas: list = []

        pend: deque = deque()
        live_folds: list[_FoldEntry] = []
        fold_newly: dict[int, list] = {}
        fold_rounds_at: dict[int, list] = {}
        errors = np.zeros(steps)
        unres = np.zeros(steps, int)
        rounds = np.zeros(steps, int)

        def drain_one():
            # THE queue-pull point: the host blocks on step t's already-
            # dispatched scalars here, so recording/stamping from them adds
            # zero synchronization to the critical path.
            t, nu, r, err, ts_disp = pend.popleft()
            unres[t] = int(nu)
            rounds[t] = int(r)
            errors[t] = float(err)
            _record_step_metrics("pipeline", rounds=int(rounds[t]),
                                 unresolved=int(unres[t]),
                                 budget=ctrl[t].budget)
            tr = _obs_trace.active_tracer()
            if tr is not None:
                # Async-safe stamping: dispatch-time → drain-time span of
                # step t's in-flight window, from host clocks captured when
                # the entry was enqueued (no block_until_ready added).
                tr.complete("pipeline/step", ts_disp,
                            _obs_trace.now_us() - ts_disp, lane="pipeline",
                            step=t, rounds=int(rounds[t]),
                            unresolved=int(unres[t]), budget=ctrl[t].budget)

        for t in range(steps):
            c = ctrl[t]
            # 1. worker launch at the stale iterate θ_{t-depth} — already
            # broadcast, so this dispatch depends on no in-flight decode
            # (depth > 1) and the two programs overlap on the devices.
            ti = t - 1 - tau
            theta_in = theta_rep[ti] if ti >= 0 else theta0_rep
            never_rep = jax.device_put(c.never, rep)
            with _span("worker/launch", lane="worker", step=t):
                z = sync._launch_workers(theta_in, never_rep)

            # 2. folds whose arrivals land THIS step (independent of the
            # current θ, so they overlap the worker launch like the decode)
            fold_dg = zero_dg
            if use_folds:
                reg = _obs_metrics.active()
                still = []
                for entry in live_folds:
                    lag = t - entry.step
                    arriving = entry.cut_mask & (entry.lags == lag)
                    if arriving.any():
                        remaining = entry.cut_mask & (entry.lags > lag)
                        w_tau = np.float32(self.staleness_decay ** lag)
                        with _span("fold/dispatch", lane="fold", step=t,
                                   source_step=entry.step, lag=lag):
                            delta, u2, n_new, fr = self._fold_program(
                                entry.z_m, remaining, entry.u, fold_budget,
                                w_tau)
                        entry.u = u2
                        fold_newly.setdefault(entry.step, []).append(n_new)
                        fold_rounds_at.setdefault(t, []).append(fr)
                        fold_dg = (delta if fold_dg is zero_dg
                                   else self._add(fold_dg, delta))
                        if reg is not None:
                            # dispatch-side host facts only — n_new/fr stay
                            # un-fetched device scalars until the end of run
                            reg.counter("pipeline.folds_total").inc()
                            reg.histogram(
                                "pipeline.fold_lag",
                                bins=_obs_metrics.LAG_BINS).observe(lag)
                            reg.histogram(
                                "pipeline.staleness_weight",
                                bins=_obs_metrics.FRACTION_BINS).observe(
                                    float(w_tau))
                    if lag < entry.window and (
                            entry.cut_mask & (entry.lags > lag)).any():
                        still.append(entry)
                live_folds = still

            # 3. fused master launch (decode + update + average + metric);
            # θ̄ is donated through the chain, z/mask arrive zero-copy
            with _span("master/dispatch", lane="master", step=t,
                       budget=c.budget):
                theta_m, tbar_m, nu, r, err, u_mask = master(
                    sync._mshard(z), np.asarray(c.cut), theta_m, tbar_m,
                    fold_dg, np.float32(t),
                    np.asarray([c.budget], np.int32), tstar_m)

            # 4. broadcast the new iterate (zero-copy on the master device:
            # the replicated put reuses θ's buffer for the master shard)
            t_rep = jax.device_put(theta_m, rep)
            theta_rep.append(t_rep)
            if record_thetas:
                rec_thetas.append(t_rep)
            if len(theta_rep) > tau + 2:
                theta_rep[t - tau - 1] = None  # release old broadcasts

            # 5. remember this step's survivors if its cut workers can
            # still land inside the fold window
            if use_folds and c.window > 0 and (
                    c.cut & (c.lags > 0)
                    & (c.lags <= c.window)).any():
                live_folds.append(_FoldEntry(
                    step=t, z_m=sync._mshard(z), u=u_mask,
                    cut_mask=c.cut, lags=c.lags, window=c.window))

            pend.append((t, nu, r, err, _obs_trace.now_us()))
            while len(pend) > self.depth:
                drain_one()

        while pend:
            drain_one()

        resolved_late = np.zeros(steps, int)
        for s, counts in fold_newly.items():
            resolved_late[s] = sum(int(n) for n in counts)
        unres = unres - resolved_late
        fold_rounds = np.zeros(steps, int)
        for t, counts in fold_rounds_at.items():
            fold_rounds[t] = sum(int(r) for r in counts)

        reg = _obs_metrics.active()
        if reg is not None:
            # End-of-run totals from the fold scalars that were device
            # values during the loop (fetching them mid-run would have
            # serialized the pipeline), plus the estimator states.
            reg.counter("pipeline.resolved_late_total").inc(
                int(resolved_late.sum()))
            reg.counter("pipeline.fold_rounds_total").inc(
                int(fold_rounds.sum()))
            reg.info("telemetry.straggler_estimator", est.snapshot(),
                     driver="pipeline")
            reg.info("telemetry.arrival_lag_estimator",
                     self.lag_estimator.snapshot(), driver="pipeline")

        thetas = None
        if record_thetas:
            thetas = np.stack([np.asarray(x) for x in rec_thetas])
        return PipelineRunResult(
            theta_m, tbar_m, errors, unres, resolved_late, rounds,
            fold_rounds, np.asarray([c.budget for c in ctrl]),
            np.asarray([c.rate for c in ctrl]),
            np.asarray([c.wait for c in ctrl]),
            np.asarray([c.window for c in ctrl]),
            np.asarray([c.cutoff for c in ctrl]), thetas)
