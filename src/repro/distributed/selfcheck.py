"""Distributed-vs-single-device parity selfcheck, runnable on any mesh.

Runs the same moment-encoded GD trajectory twice — single-device
:class:`repro.core.coded_step.Scheme2` under the lifted per-worker masks,
and :class:`repro.distributed.master.DistributedCodedGD` over the current
device mesh — and asserts the iterates match BIT FOR BIT at every step,
for every requested decode backend.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.distributed.selfcheck --workers 8

Exit code 0 and a one-line "parity OK" per backend on success; an assertion
with the first diverging step otherwise.  The CI fake-8-device job and
``tests/test_distributed.py``'s subprocess test both run this module.

``--worker-encode seeded`` swaps both sides to the seeded-LDGM pipeline
(``Scheme2.build_seeded`` vs ``DistributedCodedGD(worker_encode="seeded")``):
workers hold only their slice of the generator gather tables and fuse the
encode into the matvec — parity then proves the on-the-fly worker encode is
bit-identical to the single-device seeded gather.  ``--worker-encode
seeded-fused`` goes one step further: BOTH sides run the fused Pallas
encode kernel (reference ``Scheme2.build_seeded(..., encode_fused=True)``
vs fused shard-local kernels with traced row offsets) — parity proves the
in-register index regeneration matches per shard.  ``--grad-agg`` checks the
additive-loss path instead: :class:`repro.distributed.master
.DistributedCodedAggregator` vs the single-device
:class:`repro.core.grad_agg.CodedAggregator` under the lifted worker masks.
``--pipeline`` checks the asynchronous runtime's degenerate corner:
:class:`repro.distributed.pipeline.AsyncDistributedCodedGD` at depth 1
with a zero fold window must walk the EXACT synchronous trajectory —
double buffering, donated master buffers, and the fold machinery being
armed-but-idle change no bit.
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BernoulliStragglers,
    CodedAggregator,
    DelayModel,
    Scheme2,
    make_regular_ldpc,
    second_moment,
)
from repro.core.ldpc import make_seeded_ldgm
from repro.data import make_linear_problem
from repro.distributed.master import (
    DistributedCodedAggregator,
    DistributedCodedGD,
)
from repro.distributed.pipeline import AsyncDistributedCodedGD
from repro.distributed.topology import WorkerTopology, make_worker_mesh
from repro.distributed.worker import WorkerStragglers


def _build_scheme(K: int, worker_encode: str, backend: str, seed: int):
    """The shared problem + scheme of the GD parity checks: a seeded LDGM
    scheme for the seeded worker encodes (fused kernel on the reference
    side under ``seeded-fused`` — the kernel must sit on BOTH sides for
    bit-parity, since it fixes its own FMA summation order), the
    materialized regular-LDPC scheme otherwise."""
    if worker_encode in ("seeded", "seeded-fused"):
        # Seeded layered-permutation P needs K % rw == 0 and
        # p % (K // rw) == 0; (K, K//2, rw=8) satisfies both for K % 16 == 0.
        code = make_seeded_ldgm(K, K // 2, row_weight=8, seed=seed)
    else:
        code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    prob = make_linear_problem(m=4 * K, k=K, seed=seed)
    mom = second_moment(prob.X, prob.y)
    if worker_encode == "materialized":
        scheme = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                               decode_backend=backend)
    else:
        scheme = Scheme2.build_seeded(
            code, mom, lr=prob.lr, decode_iters=8, decode_backend=backend,
            encode_fused=(worker_encode == "seeded-fused"))
    return scheme, prob


def check_parity(*, K: int = 64, n_workers: int = 8, steps: int = 6,
                 q0: float = 0.25, backend: str = "sparse",
                 master_decode: str = "single",
                 worker_encode: str = "materialized", seed: int = 0) -> int:
    """Returns the number of steps checked; raises AssertionError on the
    first diverging iterate.

    ``master_decode="sharded"`` swaps the master's decode for the
    check-tile-sharded one (:mod:`repro.distributed.sharded_decode`) while
    the single-device reference keeps decoding through the engine — the
    assertion then proves the SHARDED decode itself is bit-identical to the
    single-device decode (use ``backend="sparse"``: the sharded rounds are
    the sparse neighbor-table rounds, shard-partitioned).

    ``worker_encode="seeded"`` runs the seeded-LDGM pipeline on BOTH sides:
    the reference is the single-device ``Scheme2.build_seeded`` (per-row
    generator gather over ``y = M θ``), the distributed side shards the
    gather tables over the mesh — parity proves the fused worker-side
    encode-matvec is bit-identical to the single-device one.
    ``worker_encode="seeded-fused"`` puts the fused Pallas encode kernel on
    both sides (reference built ``encode_fused=True``; workers run the same
    kernel over their own row windows with a traced row offset).
    """
    scheme, prob = _build_scheme(K, worker_encode, backend, seed)
    code = scheme.code
    topo = WorkerTopology(n_workers, code.N)
    dist = DistributedCodedGD(scheme, topo, make_worker_mesh(),
                              master_decode=master_decode,
                              worker_encode=worker_encode)
    stragglers = WorkerStragglers(BernoulliStragglers(q0), topo)

    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, steps)
    theta_ref = jnp.zeros(K)
    theta_dist = jnp.zeros(K)
    # Jitted like the distributed step — the claim under test is that
    # DISTRIBUTION (sharded workers, per-worker erasure, gather) changes
    # nothing, so both sides must be whole-step XLA programs; an eager
    # reference differs in fused-multiply-add choices, not in placement.
    ref_step = jax.jit(scheme.step)
    for t in range(steps):
        worker_mask = stragglers.sample_workers(keys[t])
        # single-device reference: Scheme2 under the LIFTED mask
        theta_ref, _ = ref_step(theta_ref,
                                topo.to_symbol_erasure(worker_mask))
        theta_dist, _, _, _ = dist.step(theta_dist, worker_mask)
        ref, got = np.asarray(theta_ref), np.asarray(theta_dist)
        if not (ref == got).all():
            bad = int(np.argmax(ref != got))
            raise AssertionError(
                f"backend={backend} master_decode={master_decode} "
                f"worker_encode={worker_encode}: iterates diverge at step "
                f"{t}, coordinate {bad}: {ref[bad]!r} != {got[bad]!r}")
    return steps


def check_grad_agg_parity(*, n_shards: int = 64, dim: int = 17,
                          n_workers: int = 8, steps: int = 4,
                          q0: float = 0.25, backend: str = "sparse",
                          seed: int = 0) -> int:
    """Additive-loss path parity: :class:`DistributedCodedAggregator` (2-D
    payload worker launch + master decode) vs the single-device
    :class:`CodedAggregator` under the lifted worker mask, bit for bit.
    Returns the number of masks checked."""
    agg = CodedAggregator.build(n_shards=n_shards, redundancy=0.5,
                                row_weight=4, seed=seed,
                                decode_backend=backend)
    topo = WorkerTopology(n_workers, agg.n_workers)
    dagg = DistributedCodedAggregator(agg, topo, make_worker_mesh())
    model = BernoulliStragglers(q0)
    key = jax.random.PRNGKey(seed)
    partials = jax.random.normal(key, (n_shards, dim))
    ref_agg = jax.jit(agg.aggregate)
    for t in range(steps):
        worker_mask = model.sample(jax.random.fold_in(key, t), n_workers)
        total_d, unres_d = dagg.aggregate(partials, worker_mask)
        total_s, unres_s = ref_agg(partials,
                                   topo.to_symbol_erasure(worker_mask))
        ref, got = np.asarray(total_s), np.asarray(total_d)
        if not (ref == got).all():
            bad = int(np.argmax(ref != got))
            raise AssertionError(
                f"grad-agg backend={backend}: sums diverge at mask {t}, "
                f"coordinate {bad}: {ref[bad]!r} != {got[bad]!r}")
        if int(unres_s) != int(unres_d):
            raise AssertionError(
                f"grad-agg backend={backend}: unresolved counts diverge at "
                f"mask {t}: {int(unres_s)} != {int(unres_d)}")
    return steps


def check_pipeline_parity(*, K: int = 64, n_workers: int = 8, steps: int = 6,
                          q0: float = 0.25, backend: str = "sparse",
                          worker_encode: str = "materialized",
                          master_decode: str = "single",
                          seed: int = 0) -> int:
    """Depth-1 / zero-fold-window pipeline vs the synchronous driver.

    Both runtimes consume the same key schedule, so they realize identical
    masks (straggler-model leg) and identical delays → wait-for → cut
    decisions (delay-model leg, which exercises the telemetry-driven
    control plane shared through ``delay_step_control``).  The iterates,
    unresolved counts, round counts, and budgets must match exactly; the
    assertion names the first diverging step.  Returns total steps checked.

    ``master_decode="replay"`` puts the pattern-compiled replay decode on
    BOTH drivers (each with its own schedule cache): parity then proves
    the pipeline's plan-time schedule pre-solve and eager replay dispatch
    change no bit relative to the synchronous replay step.
    """
    scheme, prob = _build_scheme(K, worker_encode, backend, seed)
    code = scheme.code
    topo = WorkerTopology(n_workers, code.N)
    mesh = make_worker_mesh()
    theta0 = jnp.zeros(K)
    key = jax.random.PRNGKey(seed)
    checked = 0
    legs = (("straggler", BernoulliStragglers(q0), None),
            ("delay", None, DelayModel(tau=1.0, mu=1.0)))
    for name, model, delay_model in legs:
        sync = DistributedCodedGD(scheme, topo, mesh,
                                  master_decode=master_decode,
                                  worker_encode=worker_encode)
        pipe = AsyncDistributedCodedGD(scheme, topo, mesh, depth=1,
                                       max_staleness=0,
                                       master_decode=master_decode,
                                       worker_encode=worker_encode)
        rs = sync.run(theta0, model, steps, key=key,
                      theta_star=prob.theta_star, delay_model=delay_model)
        rp = pipe.run(theta0, model, steps, key=key,
                      theta_star=prob.theta_star, delay_model=delay_model,
                      record_thetas=True)
        ref, got = np.asarray(rs.theta), np.asarray(rp.theta)
        if not (ref == got).all():
            bad = int(np.argmax(ref != got))
            raise AssertionError(
                f"pipeline backend={backend} worker_encode={worker_encode} "
                f"master_decode={master_decode} "
                f"leg={name}: final iterates diverge at coordinate {bad}: "
                f"{ref[bad]!r} != {got[bad]!r}")
        for field in ("unresolved", "rounds", "budgets", "wait_for"):
            a, b = getattr(rs, field), getattr(rp, field)
            if not (np.asarray(a) == np.asarray(b)).all():
                t = int(np.argmax(np.asarray(a) != np.asarray(b)))
                raise AssertionError(
                    f"pipeline backend={backend} leg={name}: {field} "
                    f"diverges at step {t}: {a[t]!r} != {b[t]!r}")
        checked += steps
    return checked


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--q0", type=float, default=0.25)
    ap.add_argument("--backends", default="dense,sparse,pallas",
                    help="comma-separated decode backends to check")
    ap.add_argument("--master-decode", default="single",
                    choices=["single", "sharded", "replay"],
                    help="sharded = the master decode itself runs over the "
                         "mesh (check tiles partitioned; reference stays "
                         "the single-device sparse decode); replay = the "
                         "pattern-compiled schedule replay with a cross-step "
                         "cache (reference likewise the single-device "
                         "sparse decode)")
    ap.add_argument("--worker-encode", default="materialized",
                    choices=["materialized", "seeded", "seeded-fused"],
                    help="seeded = workers hold only generator gather "
                         "tables and fuse encode into the matvec "
                         "(reference is the single-device seeded scheme); "
                         "seeded-fused = the fused Pallas encode kernel on "
                         "both sides, indices regenerated in-register")
    ap.add_argument("--grad-agg", action="store_true",
                    help="check the additive-loss DistributedCodedAggregator "
                         "against the single-device CodedAggregator instead "
                         "of the moment-encoded GD step")
    ap.add_argument("--pipeline", action="store_true",
                    help="check the depth-1 / zero-fold-window asynchronous "
                         "pipeline against the synchronous driver (straggler "
                         "and delay-model legs) instead of the GD step")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable result: one JSON object on stdout "
                         "({ok, devices, workers, checks: [...]}) with "
                         "per-check pass/fail instead of human parity lines; "
                         "failures are collected (exit 1), not raised")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="export obs metrics JSONL (+ .trace.json spans) "
                         "from the instrumented parity runs to PATH")
    args = ap.parse_args(argv)
    from repro.obs import ObsSession
    session = ObsSession.start(args.obs_out)
    n_dev = jax.device_count()

    # (kind, backend, extra-detail, runner, human success line) per check —
    # one uniform loop so --json and the human output cannot drift.
    checks = []
    if args.pipeline:
        # Replay overrides the scheme backend on both drivers, so one
        # sparse-scheme run is the whole matrix (as with sharded below).
        backends = (["sparse"] if args.master_decode == "replay"
                    else args.backends.split(","))
        for backend in backends:
            checks.append((
                "pipeline", backend,
                {"worker_encode": args.worker_encode,
                 "master_decode": args.master_decode},
                functools.partial(check_pipeline_parity, K=args.K,
                                  n_workers=args.workers, steps=args.steps,
                                  q0=args.q0, backend=backend,
                                  worker_encode=args.worker_encode,
                                  master_decode=args.master_decode),
                lambda steps, backend=backend: (
                    f"parity OK: pipeline backend={backend} "
                    f"worker_encode={args.worker_encode} "
                    f"master_decode={args.master_decode} W={args.workers} "
                    f"devices={n_dev} steps={steps} "
                    "(bit-identical iterates)")))
    elif args.grad_agg:
        for backend in args.backends.split(","):
            checks.append((
                "grad-agg", backend, {},
                functools.partial(check_grad_agg_parity, n_shards=args.K,
                                  n_workers=args.workers, steps=args.steps,
                                  q0=args.q0, backend=backend),
                lambda steps, backend=backend: (
                    f"parity OK: grad-agg backend={backend} W={args.workers} "
                    f"devices={n_dev} masks={steps} (bit-identical sums)")))
    else:
        if args.master_decode in ("sharded", "replay"):
            # The sharded rounds ARE the sparse neighbor-table rounds (and
            # replay reproduces the sparse flooding arithmetic exactly), so
            # the bit-parity reference is the sparse single-device decode.
            backends = ["sparse"]
        else:
            backends = args.backends.split(",")
        for backend in backends:
            checks.append((
                "gd-step", backend,
                {"master_decode": args.master_decode,
                 "worker_encode": args.worker_encode},
                functools.partial(check_parity, K=args.K,
                                  n_workers=args.workers, steps=args.steps,
                                  q0=args.q0, backend=backend,
                                  master_decode=args.master_decode,
                                  worker_encode=args.worker_encode),
                lambda steps, backend=backend: (
                    f"parity OK: backend={backend} "
                    f"master_decode={args.master_decode} "
                    f"worker_encode={args.worker_encode} W={args.workers} "
                    f"devices={n_dev} steps={steps} "
                    "(bit-identical iterates)")))

    records, ok_all = [], True
    try:
        for kind, backend, detail, run, ok_line in checks:
            rec = {"kind": kind, "backend": backend, **detail}
            try:
                steps = run()
            except AssertionError as e:
                if not args.json:
                    raise     # legacy behavior: fail loudly on first diverge
                rec.update(ok=False, error=str(e))
                ok_all = False
            else:
                rec.update(ok=True, steps=int(steps))
                if not args.json:
                    print(ok_line(steps))
            records.append(rec)
    finally:
        # ObsSession prints its status to stderr, keeping --json stdout pure.
        session.finish()
    if args.json:
        print(json.dumps({"ok": ok_all, "devices": n_dev,
                          "workers": args.workers, "checks": records}))
    return 0 if ok_all else 1


if __name__ == "__main__":
    raise SystemExit(main())
