"""Distributed-vs-single-device parity selfcheck, runnable on any mesh.

Runs the same moment-encoded GD trajectory twice — single-device
:class:`repro.core.coded_step.Scheme2` under the lifted per-worker masks,
and :class:`repro.distributed.master.DistributedCodedGD` over the current
device mesh — and asserts the iterates match BIT FOR BIT at every step,
for every requested decode backend.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
      PYTHONPATH=src python -m repro.distributed.selfcheck --workers 8

Exit code 0 and a one-line "parity OK" per backend on success; an assertion
with the first diverging step otherwise.  The CI fake-8-device job and
``tests/test_distributed.py``'s subprocess test both run this module.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    BernoulliStragglers,
    Scheme2,
    make_regular_ldpc,
    second_moment,
)
from repro.data import make_linear_problem
from repro.distributed.master import DistributedCodedGD
from repro.distributed.topology import WorkerTopology, make_worker_mesh
from repro.distributed.worker import WorkerStragglers


def check_parity(*, K: int = 64, n_workers: int = 8, steps: int = 6,
                 q0: float = 0.25, backend: str = "sparse",
                 master_decode: str = "single", seed: int = 0) -> int:
    """Returns the number of steps checked; raises AssertionError on the
    first diverging iterate.

    ``master_decode="sharded"`` swaps the master's decode for the
    check-tile-sharded one (:mod:`repro.distributed.sharded_decode`) while
    the single-device reference keeps decoding through the engine — the
    assertion then proves the SHARDED decode itself is bit-identical to the
    single-device decode (use ``backend="sparse"``: the sharded rounds are
    the sparse neighbor-table rounds, shard-partitioned).
    """
    code = make_regular_ldpc(K, l=3, r=6, seed=seed)
    prob = make_linear_problem(m=4 * K, k=K, seed=seed)
    mom = second_moment(prob.X, prob.y)
    scheme = Scheme2.build(code, mom, lr=prob.lr, decode_iters=8,
                           decode_backend=backend)
    topo = WorkerTopology(n_workers, code.N)
    dist = DistributedCodedGD(scheme, topo, make_worker_mesh(),
                              master_decode=master_decode)
    stragglers = WorkerStragglers(BernoulliStragglers(q0), topo)

    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, steps)
    theta_ref = jnp.zeros(K)
    theta_dist = jnp.zeros(K)
    # Jitted like the distributed step — the claim under test is that
    # DISTRIBUTION (sharded workers, per-worker erasure, gather) changes
    # nothing, so both sides must be whole-step XLA programs; an eager
    # reference differs in fused-multiply-add choices, not in placement.
    ref_step = jax.jit(scheme.step)
    for t in range(steps):
        worker_mask = stragglers.sample_workers(keys[t])
        # single-device reference: Scheme2 under the LIFTED mask
        theta_ref, _ = ref_step(theta_ref,
                                topo.to_symbol_erasure(worker_mask))
        theta_dist, _, _, _ = dist.step(theta_dist, worker_mask)
        ref, got = np.asarray(theta_ref), np.asarray(theta_dist)
        if not (ref == got).all():
            bad = int(np.argmax(ref != got))
            raise AssertionError(
                f"backend={backend} master_decode={master_decode}: iterates "
                f"diverge at step {t}, coordinate {bad}: "
                f"{ref[bad]!r} != {got[bad]!r}")
    return steps


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--steps", type=int, default=6)
    ap.add_argument("--q0", type=float, default=0.25)
    ap.add_argument("--backends", default="dense,sparse,pallas",
                    help="comma-separated decode backends to check")
    ap.add_argument("--master-decode", default="single",
                    choices=["single", "sharded"],
                    help="sharded = the master decode itself runs over the "
                         "mesh (check tiles partitioned; reference stays "
                         "the single-device sparse decode)")
    args = ap.parse_args(argv)
    n_dev = jax.device_count()
    if args.master_decode == "sharded":
        # The sharded rounds ARE the sparse neighbor-table rounds, so the
        # bit-parity reference is the sparse single-device decode.
        backends = ["sparse"]
    else:
        backends = args.backends.split(",")
    for backend in backends:
        steps = check_parity(K=args.K, n_workers=args.workers,
                             steps=args.steps, q0=args.q0, backend=backend,
                             master_decode=args.master_decode)
        print(f"parity OK: backend={backend} "
              f"master_decode={args.master_decode} W={args.workers} "
              f"devices={n_dev} steps={steps} (bit-identical iterates)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
