"""Master side: gather survivors, decode, update — and the end-to-end driver.

:class:`DistributedCodedGD` composes the distributed subsystem into a
master/worker train step over a real device mesh, as TWO device programs —
the same split the paper's Section-5 cluster runs:

  1. **worker program** (one SPMD launch, ``shard_map`` over the
     ``"workers"`` axis, θ broadcast in): each device computes the partial
     products for its row shard of ``C`` and zeroes them if its workers
     straggled (:mod:`repro.distributed.worker`); the program's replicated
     output IS the master's gather of survivor rows (the wait-for-fastest
     semantics live one level up, where the straggler mask is produced —
     :meth:`DistributedCodedGD.run` with a
     :class:`~repro.core.straggler.DelayModel` waits for the fastest
     ``wait_for`` workers per :func:`~repro.core.straggler.DelayModel
     .mask_and_time`, with ``wait_for`` chosen online by telemetry);
  2. **master program** (a single-device launch on the master device):
     peel-decode of whatever arrived through the existing
     :class:`repro.core.engine.CodedComputeEngine` stages — every decode
     backend (dense / sparse / pallas) works unchanged — then the scheme's
     own epilogue and projection, shared verbatim with the single-device
     :class:`repro.core.coded_step.Scheme2`.

The split is what makes the distributed trajectory BIT-IDENTICAL to the
single-device ``Scheme2`` one (tested on the fake 8-device CPU mesh): the
sharded row-block matvec produces the same bits as the full matvec (each
output element is an independent dot product), and the decode runs as a
single-device program on the master instead of being auto-partitioned over
the mesh (an SPMD decode would shard the peeling matmuls' contraction and
change f32 summation order).

Budget policy: ``budget_mode="fixed"`` runs the scheme's fixed-D decode
(the parity configuration); ``budget_mode="telemetry"`` decodes adaptively
under a per-step round budget chosen by the online straggler-rate estimator
(:mod:`repro.distributed.telemetry`).  The budget is a TRACED operand of
the one compiled master program (via the engine's batched-adaptive decode
at B=1), so a drifting straggler climate never recompiles.

``master_decode="sharded"`` replaces step 2's single-device decode with
:mod:`repro.distributed.sharded_decode`: the check-side neighbor table is
partitioned over the ``"workers"`` mesh axis and the per-shard round
results are all-gathered and merged ONCE per round — the peeling update is
per-variable overwrite semantics, not an f32 contraction, so the sharded
decode stays bit-identical to the single-device one (the objection above
applies to AUTO-partitioned dense decodes, not to an explicit check-axis
shard).  Telemetry budgets flow into the sharded program through the same
traced ``(1,)`` operand.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from jax.experimental.shard_map import shard_map

from repro.core.coded_step import Scheme2
from repro.core.decoder import DecodeResult
from repro.core.engine import blocked_epilogue
from repro.core.straggler import DelayModel
from repro.distributed.sharded_decode import (
    build_sharded_decode,
    shard_check_tables,
)
from repro.distributed.telemetry import (
    StragglerRateEstimator,
    decode_budget,
    pick_wait_for_cached,
)
from repro.distributed.topology import (
    WorkerTopology,
    make_worker_mesh,
    replicated_sharding,
)
from repro.distributed.worker import (
    build_seeded_fused_worker_products,
    build_seeded_worker_products,
    build_worker_products,
    shard_encoded_rows,
    shard_generator_tables,
)
from repro.obs import metrics as _obs_metrics
from repro.obs.trace import span as _span

__all__ = ["DistributedRunResult", "DistributedCodedGD",
           "DistributedCodedAggregator", "build_distributed_gd_step",
           "delay_step_control"]

BUDGET_MODES = ("fixed", "telemetry")
MASTER_DECODES = ("single", "sharded", "replay")
WORKER_ENCODES = ("materialized", "seeded", "seeded-fused")


def _record_step_metrics(driver: str, *, rounds: int, unresolved: int,
                         budget: int) -> None:
    """Per-step decode outcome, recorded from ALREADY-FETCHED host ints at
    the point every driver blocks anyway (the ``int(...)`` pulls) — shared
    by the sync driver (``driver="sync"``) and the pipelined one
    (``driver="pipeline"``) so the two emit comparable metric streams."""
    reg = _obs_metrics.active()
    if reg is None:
        return
    reg.counter("distributed.steps_total", driver=driver).inc()
    reg.histogram("distributed.step.rounds", bins=_obs_metrics.ROUND_BINS,
                  driver=driver).observe(rounds)
    reg.histogram("distributed.step.unresolved",
                  bins=_obs_metrics.COUNT_BINS,
                  driver=driver).observe(unresolved)
    reg.histogram("distributed.step.budget", bins=_obs_metrics.ROUND_BINS,
                  driver=driver).observe(budget)
    reg.histogram("distributed.step.budget_headroom",
                  bins=_obs_metrics.ROUND_BINS,
                  driver=driver).observe(max(budget - rounds, 0))


def _record_plan_metrics(driver: str, *, wait_for: int | None = None,
                         rate: float | None = None,
                         observed: float | None = None) -> None:
    """Per-step control-plane decision vs realized straggling: the wait-for
    cut, the EMA estimate ENTERING the step, the observed fraction, and
    their gap (the straggler-rate tracking error)."""
    reg = _obs_metrics.active()
    if reg is None:
        return
    if wait_for is not None:
        reg.histogram("distributed.wait_for", bins=_obs_metrics.COUNT_BINS,
                      driver=driver).observe(wait_for)
    if rate is not None:
        reg.histogram("distributed.straggler.rate_estimate",
                      bins=_obs_metrics.FRACTION_BINS,
                      driver=driver).observe(rate)
    if observed is not None:
        reg.histogram("distributed.straggler.observed",
                      bins=_obs_metrics.FRACTION_BINS,
                      driver=driver).observe(observed)
    if rate is not None and observed is not None:
        reg.histogram("distributed.straggler.tracking_error",
                      bins=_obs_metrics.FRACTION_BINS,
                      driver=driver).observe(abs(rate - observed))


def delay_step_control(delays: np.ndarray, wait_for: int,
                       straggler_factor: float
                       ) -> tuple[np.ndarray, float, float]:
    """Per-step host-side control math for delay-model runs, in ONE numpy
    pass: the straggler mask at the wait-for cutoff, the cutoff itself
    (the step's simulated wall-clock), and the telemetry observation
    (fraction of workers slower than ``straggler_factor`` × the waited-for
    median — NOT the mask, which is the cut the estimator itself chose).

    Shared by the synchronous driver and the pipelined one
    (:mod:`repro.distributed.pipeline`) so the two runtimes realize
    IDENTICAL masks from identical delays — the depth-1 bit-parity gate
    rests on it.  Returns ``(mask (W,) bool, cutoff, observed_fraction)``.
    """
    delays = np.asarray(delays)
    order = np.argsort(delays, kind="stable")
    cutoff = float(delays[order[wait_for - 1]])
    mask = delays > cutoff  # stragglers: slower than the wait-for cutoff
    med = float(np.median(delays[order[:wait_for]]))
    observed = float((delays > straggler_factor * med).mean())
    return mask, cutoff, observed


class DistributedRunResult(NamedTuple):
    theta: jax.Array        # final iterate
    theta_bar: jax.Array    # running average (Theorem 1 is stated for it)
    errors: np.ndarray      # (T,) ||θ_t - θ*|| (or loss / norm)
    unresolved: np.ndarray  # (T,) |U_t| per step
    rounds: np.ndarray      # (T,) decode rounds actually spent per step
    budgets: np.ndarray     # (T,) round budget granted per step
    rates: np.ndarray       # (T,) telemetry estimate q̂ entering each step
    wait_for: np.ndarray    # (T,) workers waited for (delay-model runs; else W)
    step_times: np.ndarray  # (T,) simulated wall-clock (delay-model runs; else 0)


@dataclasses.dataclass
class DistributedCodedGD:
    """Moment-encoded GD over a worker mesh, driven from a master loop.

    ``scheme`` supplies the code, the encoded operator ``C``, the moment
    vector ``b``, the learning rate, the decode backend, and the gradient
    epilogue — everything the single-device path uses, reused verbatim.
    ``topology`` fixes the row→worker assignment (``W`` logical workers);
    ``mesh`` places the workers onto devices (``n_devices | W``).
    """

    scheme: Scheme2
    topology: WorkerTopology
    mesh: Mesh | None = None
    budget_mode: str = "fixed"
    # "single": decode as one single-device program on the master (the
    # default — any engine backend).  "sharded": the decode itself runs
    # over the workers mesh with check tiles partitioned across devices
    # (repro.distributed.sharded_decode) — for N past one device; stays
    # bit-identical to the single-device sparse decode.  "replay": the
    # pattern-compiled decode — the step's concrete mask (known on the host
    # at dispatch) looks its peeling schedule up in a cross-step
    # ScheduleCache (recurring straggler patterns pay the symbolic solve
    # once) and the decode is the straight-line numeric replay; stays
    # bit-identical to the single-device sparse decode.
    master_decode: str = "single"
    # "materialized": workers hold their rows of the encoded C (the default
    # — scheme.C is the (N, k) encoded operator, row-sharded over the mesh).
    # "seeded": workers hold ONLY their slice of the seeded generator gather
    # tables and fuse encode into the matvec (z = gather(M θ) per row);
    # requires a Scheme2.build_seeded scheme (scheme.C is then the raw M).
    # Products — hence trajectories — are bit-identical across the two.
    # "seeded-fused": like "seeded" but the gather runs inside the fused
    # Pallas encode kernel with indices regenerated in-register from the
    # seed — workers hold NO tables at all.  Bit-identical to a reference
    # Scheme2 built with encode_fused=True (kernel on both sides).
    worker_encode: str = "materialized"
    estimator: StragglerRateEstimator | None = None
    max_rounds: int | None = None     # telemetry worst-case budget ceiling
    # Delay-model runs: a worker counts as STRAGGLING when its latency
    # exceeds straggler_factor × the median of the waited-for arrivals.
    # This is what telemetry observes under a DelayModel — observing the
    # erasure mask itself would be circular there (the mask is exactly the
    # wait-for cut the estimator chose, so q̂ would converge to its own
    # decision instead of to anything about the workers).
    straggler_factor: float = 2.0
    # master_decode="replay" only: the cross-step LRU of compiled peeling
    # schedules.  None = the driver builds its own; pass one to share it
    # (e.g. the pipelined driver hands its cache to the wrapped sync
    # driver so warm patterns carry across).
    schedule_cache: object | None = None

    def __post_init__(self) -> None:
        if self.budget_mode not in BUDGET_MODES:
            raise ValueError(f"unknown budget_mode {self.budget_mode!r}; "
                             f"want one of {BUDGET_MODES}")
        if self.master_decode not in MASTER_DECODES:
            raise ValueError(f"unknown master_decode {self.master_decode!r}; "
                             f"want one of {MASTER_DECODES}")
        if self.worker_encode not in WORKER_ENCODES:
            raise ValueError(f"unknown worker_encode {self.worker_encode!r}; "
                             f"want one of {WORKER_ENCODES}")
        if (self.worker_encode in ("seeded", "seeded-fused")
                and not self.scheme.seeded_encode):
            raise ValueError(
                f"worker_encode={self.worker_encode!r} needs a "
                "Scheme2.build_seeded scheme (seeded_encode=True, C holding "
                "the raw moment matrix M); this scheme stores a "
                "materialized encoded operator")
        if self.topology.N != self.scheme.w:
            raise ValueError(
                f"topology covers N={self.topology.N} rows but the scheme's "
                f"code has N={self.scheme.w}")
        if self.mesh is None:
            self.mesh = make_worker_mesh()
        self.topology.validate_mesh(self.mesh)
        if self.estimator is None:
            self.estimator = StragglerRateEstimator()
        if self.max_rounds is None:
            self.max_rounds = int(self.scheme.decode_iters)
        self._replicated = replicated_sharding(self.mesh)
        if self.worker_encode in ("seeded", "seeded-fused"):
            # Workers never hold encoding-matrix rows: the raw moment matrix
            # M (scheme.C under seeded_encode) is replicated problem data.
            # Plain "seeded" shards the generator gather tables; the fused
            # mode regenerates indices in-kernel and needs no tables at all.
            if self.worker_encode == "seeded":
                self._tables_sharded = shard_generator_tables(
                    self.scheme.code, self.mesh, self.topology)
            self._M_replicated = jax.device_put(
                jnp.asarray(self.scheme.C), self._replicated)
        else:
            self._C_sharded = shard_encoded_rows(
                jnp.asarray(self.scheme.C), self.mesh, self.topology)
        self.master_device = self.mesh.devices.flat[0]
        if self.master_decode == "sharded":
            # Check tiles partitioned over the workers axis, once at build.
            self._sharded_tables = shard_check_tables(self.scheme.code,
                                                      self.mesh)
        if self.master_decode == "replay" and self.schedule_cache is None:
            from repro.core.schedule_cache import ScheduleCache
            self.schedule_cache = ScheduleCache()
        # Which addressable shard of a replicated array lives on the master
        # device: the worker program's replicated output hands the master
        # program its operand ZERO-COPY via that shard's buffer, instead of
        # a fresh device_put per step.
        probe = jax.device_put(jnp.zeros((1,)), self._replicated)
        self._mshard_idx = next(
            i for i, s in enumerate(probe.addressable_shards)
            if s.device == self.master_device)
        self._worker_program, self._master_program = self._build_programs()

    def _mshard(self, x: jax.Array) -> jax.Array:
        """The master device's shard of a replicated array — a zero-copy
        single-device view, usable as a master-program operand."""
        return x.addressable_shards[self._mshard_idx].data

    def _launch_workers(self, theta_rep: jax.Array,
                        mask_rep: jax.Array) -> jax.Array:
        """One SPMD worker launch with the operands the built program wants:
        the per-mode operator placement (sharded C rows / sharded gather
        tables + replicated M / replicated M alone) plus the replicated
        broadcast.  Shared by :meth:`step` and the pipelined driver so the
        worker-encode dispatch lives exactly once."""
        if self.worker_encode == "seeded":
            idx_sh, coeff_sh = self._tables_sharded
            return self._worker_program(idx_sh, coeff_sh, self._M_replicated,
                                        theta_rep, mask_rep)
        if self.worker_encode == "seeded-fused":
            return self._worker_program(self._M_replicated, theta_rep,
                                        mask_rep)
        return self._worker_program(self._C_sharded, theta_rep, mask_rep)

    # ------------------------------------------------------------ step build

    @property
    def n_workers(self) -> int:
        return self.topology.n_workers

    def _build_programs(self):
        scheme, topo = self.scheme, self.topology
        eng = scheme.engine

        # Worker program: ONE SPMD launch over the workers axis.  θ and the
        # per-worker mask come in replicated (the master's broadcast), each
        # device computes/erases only its own rows, and the replicated
        # output is the master's gather of survivor rows.
        if self.worker_encode == "seeded":
            seeded_products = build_seeded_worker_products(self.mesh)

            def worker_program(idx_sh, coeff_sh, M, theta, worker_mask):
                erased = topo.to_symbol_erasure(worker_mask)  # partition lift
                return seeded_products(idx_sh, coeff_sh, M, theta, erased)
        elif self.worker_encode == "seeded-fused":
            fused_products = build_seeded_fused_worker_products(
                scheme.code, self.mesh)

            def worker_program(M, theta, worker_mask):
                erased = topo.to_symbol_erasure(worker_mask)  # partition lift
                return fused_products(M, theta, erased)
        else:
            worker_products = build_worker_products(self.mesh)

            def worker_program(C_sh, theta, worker_mask):
                erased = topo.to_symbol_erasure(worker_mask)  # partition lift
                return worker_products(C_sh, theta, erased)

        worker_jit = jax.jit(worker_program, out_shardings=self._replicated)

        if self.master_decode == "sharded":
            # Sharded master program: the decode runs over the SAME mesh,
            # check tiles partitioned across devices, values replicated; the
            # scheme's epilogue/update stays replicated elementwise math.
            # Both budget modes flow through the traced (1,) budget operand
            # (the fixed program bakes its round count in statically and
            # ignores it, mirroring the single-device fixed program).
            eng_iters = int(eng.decode_iters)
            decode_fn = build_sharded_decode(
                self.mesh, iters=eng_iters,
                adaptive=self.budget_mode == "telemetry")
            fixed_mode = self.budget_mode == "fixed"

            def master_program(idx_sh, coeff_sh, z, worker_mask, theta,
                               budget):
                erased = topo.to_symbol_erasure(worker_mask)
                z = eng.erase(z, erased)      # idempotent, mirrors recover()
                vals, e2, rounds = decode_fn(idx_sh, coeff_sh, z[:, None],
                                             erased, budget)
                dec = DecodeResult(vals[:, 0], e2, rounds)
                c_hat, unresolved = eng.systematic(dec)
                g, n_unres = scheme.finish_gradient(c_hat, unresolved)
                theta2 = scheme.projection(theta - scheme.lr * g)
                return theta2, n_unres, (jnp.int32(eng_iters) if fixed_mode
                                         else rounds)

            return worker_jit, jax.jit(master_program)

        if self.master_decode == "replay":
            # Replay master program: the decode dispatch stays EAGER — the
            # step's mask is concrete on the host at dispatch, so the
            # engine looks the pattern's compiled schedule up in the
            # cross-step cache (hit → no symbolic solve) and the numeric
            # replay jits internally keyed on the schedule's segment
            # shapes.  Only the value-level epilogue/update is jitted
            # here.  Replay reproduces the sparse flooding arithmetic
            # bit-for-bit, so the sync-parity gates hold unchanged.
            r_eng = dataclasses.replace(eng, backend="replay",
                                        schedule_cache=self.schedule_cache)
            fixed_mode = self.budget_mode == "fixed"

            @jax.jit
            def replay_epilogue(values, erased, theta):
                c_hat, unresolved = eng.systematic(
                    DecodeResult(values, erased, jnp.int32(0)))
                g, n_unres = scheme.finish_gradient(c_hat, unresolved)
                theta2 = scheme.projection(theta - scheme.lr * g)
                return theta2, n_unres

            def master_program(z, worker_mask, theta, budget):
                erased = topo.to_symbol_erasure(worker_mask)
                z = r_eng.erase(z, erased)    # idempotent, mirrors recover()
                if fixed_mode:
                    dec = r_eng.decode(z, erased)
                    values, er2, rounds = (dec.values, dec.erased,
                                           dec.rounds_used)
                else:
                    dec = r_eng.decode_batch(z[None], erased[None],
                                             adaptive=True, budgets=budget)
                    values, er2, rounds = (dec.values[0], dec.erased[0],
                                           dec.rounds_used[0])
                theta2, n_unres = replay_epilogue(values, er2, theta)
                return theta2, n_unres, rounds

            return worker_jit, master_program

        # Master program: a SINGLE-DEVICE launch (inputs committed to the
        # master device pin it there) — decode of the gathered survivors
        # plus the scheme's own epilogue/update, shared verbatim with the
        # single-device Scheme2 so the two paths cannot diverge.  erase()
        # on the already-zeroed survivors is idempotent, so the decode sees
        # exactly what Scheme2.gradient feeds it.
        if self.budget_mode == "fixed":
            def master_program(z, worker_mask, theta, budget):
                del budget  # fixed-D decode; kept for a stable signature
                erased = topo.to_symbol_erasure(worker_mask)
                c_hat, unresolved = eng.recover(z, erased)
                g, n_unres = scheme.finish_gradient(c_hat, unresolved)
                theta2 = scheme.projection(theta - scheme.lr * g)
                return theta2, n_unres, jnp.int32(eng.decode_iters)
        else:
            # Telemetry mode rides the engine's batched-adaptive decode at
            # B=1: the round budget is a TRACED (1,) operand (changing
            # budgets never recompile) and rounds_used surfaces per step.
            def master_program(z, worker_mask, theta, budget):
                erased = topo.to_symbol_erasure(worker_mask)
                dec = eng.decode_batch(z[None], erased[None], adaptive=True,
                                       budgets=budget)
                c_hat, unresolved = eng.systematic(dec)
                g, n_unres = scheme.finish_gradient(c_hat[0], unresolved[0])
                theta2 = scheme.projection(theta - scheme.lr * g)
                return theta2, n_unres, dec.rounds_used[0]

        return worker_jit, jax.jit(master_program)

    # --------------------------------------------------------------- driving

    def step(self, theta: jax.Array, worker_mask: jax.Array, *,
             observed_fraction: float | None = None
             ) -> tuple[jax.Array, int, int, int]:
        """One master step from a realized (W,) worker straggler mask.

        Telemetry observes BEFORE the decode budget is chosen — the master
        knows exactly which workers reported when it starts decoding.  The
        default observation is the mask's straggler fraction (right for
        straggler-model runs, where the mask is exogenous);
        ``observed_fraction`` overrides it for callers whose mask is a
        policy DECISION rather than a measurement (delay-model runs pass a
        latency-derived fraction — see :meth:`run`).  Returns
        ``(θ', n_unresolved, rounds_spent, budget)``.
        """
        worker_mask = jnp.asarray(worker_mask, bool)
        if worker_mask.shape != (self.n_workers,):
            raise ValueError(f"worker_mask must be ({self.n_workers},); "
                             f"got {worker_mask.shape}")
        if self.budget_mode == "telemetry":
            if observed_fraction is None:
                observed_fraction = float(
                    self.topology.observed_fraction(worker_mask))
            rate_in = self.estimator.rate   # estimate ENTERING the step
            rate = self.estimator.observe(observed_fraction)
            code = self.scheme.code
            budget = decode_budget(rate, code.l, code.r,
                                   max_rounds=self.max_rounds)
            _record_plan_metrics("sync", rate=rate_in,
                                 observed=observed_fraction)
        else:
            budget = int(self.scheme.decode_iters)
        # broadcast θ + mask to the workers, one SPMD partial-product
        # launch.  device_put is a no-op when the operand already carries
        # the replicated sharding (θ handed back by a previous step), so a
        # driver loop pays ONE broadcast per array per step, not the old
        # replicated-put + master-put pair.
        theta_rep = jax.device_put(theta, self._replicated)
        mask_rep = jax.device_put(worker_mask, self._replicated)
        budget_arr = np.asarray([budget], np.int32)
        with _span("worker/launch", lane="worker"):
            z = self._launch_workers(theta_rep, mask_rep)
        with _span("master/decode", lane="master", budget=budget):
            if self.master_decode == "sharded":
                # decode over the mesh: check tiles stay sharded; z/θ/mask
                # are already replicated (z is the worker program's output
                # sharding)
                idx_sh, coeff_sh = self._sharded_tables
                theta2, n_unres, rounds = self._master_program(
                    idx_sh, coeff_sh, z, mask_rep, theta_rep,
                    jax.device_put(jnp.asarray(budget_arr), self._replicated))
            else:
                # master-local decode + update: operands are the master
                # device's OWN shards of the replicated worker output /
                # broadcast (zero-copy views), plus the budget scalar which
                # jit places alongside them.
                theta2, n_unres, rounds = self._master_program(
                    self._mshard(z), self._mshard(mask_rep),
                    self._mshard(theta_rep), budget_arr)
            n_unres, rounds = int(n_unres), int(rounds)
        _record_step_metrics("sync", rounds=rounds, unresolved=n_unres,
                             budget=budget)
        return theta2, n_unres, rounds, budget

    def run(
        self,
        theta0: jax.Array,
        straggler_model,
        steps: int,
        *,
        key: jax.Array | None = None,
        theta_star: jax.Array | None = None,
        loss_fn: Callable[[jax.Array], jax.Array] | None = None,
        delay_model: DelayModel | None = None,
    ) -> DistributedRunResult:
        """Drive ``steps`` master steps.

        ``straggler_model`` samples per-WORKER masks (width ``W``) with the
        same key schedule as :func:`repro.core.coded_step.run_pgd` (one
        ``jax.random.split`` of ``key``), so a single-device reference run
        under the lifted mask sees identical erasure realizations.  With a
        ``delay_model``, masks instead come from per-worker latencies and a
        telemetry-chosen wait-for-fastest threshold (the paper's Section-5
        timing model); ``step_times`` then records the simulated wall-clock
        of each step (the order statistic at the cutoff).
        """
        if key is None:
            key = jax.random.PRNGKey(0)
        keys = jax.random.split(key, steps)
        W = self.n_workers
        code = self.scheme.code

        def metric(theta):
            if theta_star is not None:
                return jnp.linalg.norm(theta - theta_star)
            if loss_fn is not None:
                return loss_fn(theta)
            return jnp.linalg.norm(theta)

        theta = jnp.asarray(theta0)
        tbar = jnp.zeros_like(theta)
        errors, unresolved, rounds, budgets, rates, waits, times = \
            [], [], [], [], [], [], []
        for t in range(steps):
            observed = None
            if delay_model is not None:
                wait = pick_wait_for_cached(self.estimator.rate, W,
                                            code.l, code.r)
                delays = np.asarray(delay_model.sample_delays(keys[t], W))
                # One host-side numpy pass: mask at the cutoff, simulated
                # step time, and the telemetry observation (tail latency
                # relative to the waited-for median, NOT the mask — the
                # mask is the cut the estimator itself chose; observing it
                # would close a feedback loop where q̂ converges to its own
                # decision and homogeneous fast fleets keep getting cut
                # forever).
                worker_mask, cutoff, observed = delay_step_control(
                    delays, wait, self.straggler_factor)
                times.append(cutoff)
            else:
                wait = W
                worker_mask = straggler_model.sample(keys[t], W)
                times.append(0.0)
            rates.append(self.estimator.rate)
            _record_plan_metrics("sync", wait_for=int(wait))
            theta, n_unres, spent, budget = self.step(
                theta, worker_mask, observed_fraction=observed)
            tbar = (tbar * t + theta) / (t + 1.0)
            errors.append(float(metric(theta)))
            unresolved.append(n_unres)
            rounds.append(spent)
            budgets.append(budget)
            waits.append(int(wait))
        reg = _obs_metrics.active()
        if reg is not None:
            reg.info("telemetry.straggler_estimator",
                     self.estimator.snapshot(), driver="sync")
        return DistributedRunResult(
            theta, tbar, np.asarray(errors), np.asarray(unresolved),
            np.asarray(rounds), np.asarray(budgets), np.asarray(rates),
            np.asarray(waits), np.asarray(times))


# ------------------------------------------ distributed coded aggregation


@dataclasses.dataclass
class DistributedCodedAggregator:
    """The beyond-paper additive-loss path served by the worker runtime.

    :class:`repro.core.grad_agg.CodedAggregator` run as the SAME two device
    programs as :class:`DistributedCodedGD`: the generator rows are sharded
    over the ``"workers"`` mesh axis and each device computes its rows of
    ``G @ partials`` — a 2-D-payload :func:`repro.distributed.worker
    .build_worker_products` launch (each systematic symbol is a flattened
    ``(dim,)`` partial gradient) — then the master peels the survivor
    symbols and sums the recovered shards.  Row-block matmuls are bitwise
    identical to the full ``G @ partials`` and the decode runs as a
    single-device program on the master, so ``aggregate`` is BIT-IDENTICAL
    to the single-device :meth:`CodedAggregator.aggregate` under the lifted
    mask (asserted by ``repro.distributed.selfcheck --grad-agg`` on the
    fake 8-device mesh).
    """

    agg: "CodedAggregator"
    topology: WorkerTopology
    mesh: Mesh | None = None

    def __post_init__(self) -> None:
        from repro.core.grad_agg import CodedAggregator
        if not isinstance(self.agg, CodedAggregator):
            raise TypeError(f"agg must be a CodedAggregator; "
                            f"got {type(self.agg).__name__}")
        if self.topology.N != self.agg.n_workers:
            raise ValueError(
                f"topology covers N={self.topology.N} rows but the "
                f"aggregator's code has N={self.agg.n_workers}")
        if self.mesh is None:
            self.mesh = make_worker_mesh()
        self.topology.validate_mesh(self.mesh)
        self._G_sharded = shard_encoded_rows(
            jnp.asarray(self.agg.code.G, jnp.float32), self.mesh,
            self.topology)
        self._replicated = replicated_sharding(self.mesh)
        self.master_device = self.mesh.devices.flat[0]

        topo, agg = self.topology, self.agg
        worker_products = build_worker_products(self.mesh)
        eng = agg.engine

        def worker_program(G_sh, partials, worker_mask):
            erased = topo.to_symbol_erasure(worker_mask)
            return worker_products(G_sh, partials, erased)

        def master_program(z, worker_mask):
            erased = topo.to_symbol_erasure(worker_mask)
            recovered, unresolved = eng.recover(z, erased)
            total = recovered.sum(axis=0) * agg.debias_scale
            return total, unresolved.sum()

        self._worker_program = jax.jit(worker_program,
                                       out_shardings=self._replicated)
        self._master_program = jax.jit(master_program)

    @property
    def n_workers(self) -> int:
        return self.topology.n_workers

    def aggregate(self, partials: jax.Array, worker_mask: jax.Array
                  ) -> tuple[jax.Array, int]:
        """Coded sum of ``partials (K, dim)`` under a ``(W,)`` worker mask.

        One SPMD worker launch (sharded generator rows, 2-D payload), one
        master decode launch.  Returns ``(Σ_i ĝ_i (dim,), n_unresolved)``.
        """
        partials = jnp.asarray(partials)
        worker_mask = jnp.asarray(worker_mask, bool)
        if worker_mask.shape != (self.n_workers,):
            raise ValueError(f"worker_mask must be ({self.n_workers},); "
                             f"got {worker_mask.shape}")
        z = self._worker_program(
            self._G_sharded,
            jax.device_put(partials, self._replicated),
            jax.device_put(worker_mask, self._replicated))
        m = self.master_device
        total, n_unres = self._master_program(
            jax.device_put(z, m), jax.device_put(worker_mask, m))
        return total, int(n_unres)


# ------------------------------------------------- production-scale AOT step


def build_distributed_gd_step(k: int, K: int, decode_iters: int, dtype,
                              mesh: Mesh, *, decode: str = "sparse",
                              r: int = 6):
    """Sharded-worker Scheme2Blocked step at production scale, for AOT
    lower/compile analysis (:mod:`repro.launch.paper_dryrun`'s
    ``--distributed`` variant).

    Unlike :func:`repro.launch.steps.build_coded_gd_step` (which shards the
    encoded operator as an undifferentiated tensor), this step places the
    pipeline the way the real system runs it: the mesh carries an explicit
    ``("workers", "data")`` layout, the worker compute is a ``shard_map``
    over the ``"workers"`` axis (each chip holds its workers' rows of every
    block and contributes partial sums over its ``"data"`` slice of θ, with
    one ``psum`` over "data"), the straggler mask is PER-WORKER ``(W,)``
    (W = the workers-axis size) lifted to symbols inside the step, and the
    master decode runs on the gathered survivors through the shared
    :mod:`repro.core.decoder` fixed-D loops + engine epilogue.

    Returns ``(jitted_step, arg_specs)`` ready for AOT lower/compile.
    """
    from jax.sharding import NamedSharding
    from repro.core.decoder import peel_fixed_dense, peel_fixed_sparse

    N, p, nb = 2 * K, K, k // K
    W = mesh.shape["workers"]
    topo = WorkerTopology(W, N)
    sh = lambda *spec: NamedSharding(mesh, P(*spec))

    def worker_fn(C_shard, theta_shard, erased_shard):
        # C_shard (nb, N/W, k/data); theta_shard (k/data,) — partial sums
        # over the feature axis, one psum over "data" completes the dot.
        z = jnp.einsum("bnk,k->nb", C_shard,
                       theta_shard.astype(C_shard.dtype))
        z = jax.lax.psum(z.astype(jnp.float32), "data")
        return jnp.where(erased_shard[:, None], 0.0, z)

    worker_products = shard_map(
        worker_fn, mesh=mesh,
        in_specs=(P(None, "workers", "data"), P("data"), P("workers")),
        out_specs=P("workers", None))

    def epilogue(vals, erased_sym, theta, b, lr):
        g, _ = blocked_epilogue(vals, erased_sym, b, K=K, nb=nb)
        return theta - lr * g

    common = (
        jax.ShapeDtypeStruct((k,), jnp.float32),   # theta
        jax.ShapeDtypeStruct((k,), jnp.float32),   # b
        jax.ShapeDtypeStruct((W,), jnp.bool_),     # PER-WORKER mask
        jax.ShapeDtypeStruct((), jnp.float32),     # lr
    )
    common_sh = (sh(), sh(), sh(), sh())
    c_spec = jax.ShapeDtypeStruct((nb, N, k), dtype)
    c_sh = sh(None, "workers", "data")

    if decode == "dense":
        def step_dense(C_blocks, H, theta, b, worker_mask, lr):
            erased = topo.to_symbol_erasure(worker_mask)
            z = worker_products(C_blocks, theta, erased)
            vals, er = peel_fixed_dense(H, H != 0.0, z, erased, decode_iters)
            return epilogue(vals, er, theta, b, lr)

        args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
        in_sh = (c_sh, sh("workers", None), *common_sh)
        return jax.jit(step_dense, in_shardings=in_sh,
                       out_shardings=sh()), args

    if decode != "sparse":
        raise ValueError(f"unknown distributed decode variant {decode!r}; "
                         "want dense|sparse")

    def step_sparse(C_blocks, H_idx, H_val, theta, b, worker_mask, lr):
        erased = topo.to_symbol_erasure(worker_mask)
        z = worker_products(C_blocks, theta, erased)
        vals, er = peel_fixed_sparse(H_idx, H_val, z, erased, decode_iters)
        return epilogue(vals, er, theta, b, lr)

    args = (c_spec, jax.ShapeDtypeStruct((p, r), jnp.int32),
            jax.ShapeDtypeStruct((p, r), jnp.float32), *common)
    in_sh = (c_sh, sh("workers", None), sh("workers", None), *common_sh)
    return jax.jit(step_sparse, in_shardings=in_sh, out_shardings=sh()), args
