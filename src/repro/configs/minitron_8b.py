"""minitron-8b [dense] — 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000; pruned Nemotron-4 (squared-ReLU MLP, no bias).
[arXiv:2407.14679]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=256000,
    act="relu2",          # nemotron-family squared ReLU
    rope_theta=1e4,
    source="arXiv:2407.14679 (Minitron / pruned Nemotron-4)",
))
