"""deepseek-v2-236b [moe] — 60L d_model=5120 128H MLA (kv_lora=512,
q_lora=1536, nope 128 / rope 64 / v 128) vocab=102400; MoE: 2 shared +
160 routed experts top-6, expert d_ff=1536, first layer dense (d_ff=12288).
[arXiv:2405.04434]"""
from repro.configs.base import ArchConfig, MLASpec, MoESpec, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,       # MLA: per-head K/V (latent-compressed)
    head_dim=128,
    d_ff=12288,           # the single dense (non-MoE) first layer
    vocab=102400,
    mla=MLASpec(kv_lora=512, q_lora=1536, qk_nope=128, qk_rope=64, v_head=128),
    moe=MoESpec(n_experts=160, top_k=6, d_ff_expert=1536, n_shared=2,
                first_dense=1),
    rope_theta=1e4,
    source="arXiv:2405.04434 (DeepSeek-V2)",
))
