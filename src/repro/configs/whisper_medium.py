"""whisper-medium [audio] — 24L enc + 24L dec, d_model=1024 16H (MHA)
d_ff=4096 vocab=51865; encoder-decoder; conv/mel frontend is a STUB —
input_specs() supplies post-conv frame embeddings (B, 1500, d_model).
[arXiv:2212.04356]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab=51865,
    act="gelu",
    norm="layernorm",
    pos="sinusoidal",
    enc_layers=24,
    enc_seq=1500,
    source="arXiv:2212.04356 (Whisper medium)",
))
