"""The paper's own experiment configurations (Section 4): least squares and
sparse recovery with a (40, 20) rate-1/2 LDPC code on w = 40 workers."""
import dataclasses

__all__ = ["PaperConfig", "FIG1_LS", "FIG2_SPARSE_OVER", "FIG3_SPARSE_UNDER"]


@dataclasses.dataclass(frozen=True)
class PaperConfig:
    name: str
    m: int                 # samples
    k: int                 # model dimension
    w: int = 40            # workers
    ldpc_l: int = 3
    ldpc_r: int = 6        # rate 1/2 -> (2k, k) code with N matched to w via k=K
    stragglers: tuple = (5, 10)
    sparsity: tuple = ()   # nonzero-fraction grid (sparse recovery figures)
    steps: int = 800
    tol: float = 1e-2      # ||theta - theta*|| threshold for "converged"


FIG1_LS = PaperConfig(name="fig1_least_squares", m=2048, k=0,  # k swept
                      stragglers=(5, 10))
FIG2_SPARSE_OVER = PaperConfig(name="fig2_sparse_overdetermined", m=2048, k=0,
                               sparsity=(0.1, 0.2, 0.3, 0.4, 0.5))
FIG3_SPARSE_UNDER = PaperConfig(name="fig3_sparse_underdetermined", m=1024,
                                k=2000, sparsity=(0.05, 0.1))
