"""Architecture registry: importing this package registers every assigned
architecture (plus the paper's own experiment configs)."""
from repro.configs.base import ArchConfig, get_config, list_configs, register

# Assigned architectures (10) — each module registers itself on import.
from repro.configs import (  # noqa: F401
    qwen3_1p7b,
    codeqwen1p5_7b,
    jamba_1p5_large,
    whisper_medium,
    minitron_8b,
    deepseek_v2,
    kimi_k2,
    qwen2_1p5b,
    internvl2_2b,
    rwkv6_3b,
    paper,
)

__all__ = ["ArchConfig", "get_config", "list_configs", "register"]
