"""internvl2-2b [vlm] — language backbone (InternLM2-1.8B-like): 24L
d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The InternViT vision
encoder + MLP projector are a STUB — input_specs() supplies 256 projected
patch embeddings (B, 256, d_model) prepended to the text sequence.
[arXiv:2404.16821]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92553,
    n_patches=256,
    rope_theta=1e6,
    source="arXiv:2404.16821 (InternVL2-2B; InternLM2 backbone)",
))
