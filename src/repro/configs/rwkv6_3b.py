"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free, 40 heads x 64)
d_ff=8960 vocab=65536; RWKV-6 "Finch" with data-dependent decay.
[arXiv:2404.05892]"""
from repro.configs.base import ArchConfig, RWKVSpec, register

CONFIG = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,           # d_model / rwkv.head_dim
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    rwkv=RWKVSpec(head_dim=64, decay_lora=64),
    attn_every=0,
    pos="none",
    source="arXiv:2404.05892 (RWKV-6 Finch, 3B)",
))
