"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8, head_dim 128)
vocab=163840; MoE 384 routed experts top-8 + 1 shared, expert d_ff=2048,
first layer dense (d_ff=18432).  Trillion-param MoE (paper-table dims).
[arXiv:2501.kimi2]"""
from repro.configs.base import ArchConfig, MoESpec, register

CONFIG = register(ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=18432,           # dense first layer; experts use d_ff_expert
    vocab=163840,
    moe=MoESpec(n_experts=384, top_k=8, d_ff_expert=2048, n_shared=1,
                first_dense=1),
    rope_theta=5e7,
    source="arXiv:2501.kimi2 (Kimi K2 paper-table dims)",
))
