"""codeqwen1.5-7b [dense] — 32L d_model=4096 32H (GQA kv=32 == MHA)
d_ff=13440 vocab=92416, qwen1.5 arch (QKV bias, no qk-norm).
[hf:Qwen/CodeQwen1.5-7B]"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="codeqwen1.5-7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab=92416,
    qk_norm=False,
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/CodeQwen1.5-7B",
))
