"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16e top-2; Mamba:attention 7:1 interleave
(one attention layer per 8), MoE every 2nd layer.  [arXiv:2403.19887]"""
from repro.configs.base import ArchConfig, MambaSpec, MoESpec, register

CONFIG = register(ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=65536,
    moe=MoESpec(n_experts=16, top_k=2, d_ff_expert=24576, every=2),
    mamba=MambaSpec(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    attn_offset=4,
    rope_theta=1e6,
    source="arXiv:2403.19887 (Jamba); 1.5-large scaling",
))
