"""Architecture configuration system.

One ``ArchConfig`` per assigned architecture (see sibling modules, each of
which cites its source) — the same dataclass drives param init, the train
forward, the serving paths, sharding rules, and the dry-run input specs.

``reduced()`` produces the CPU-smoke-test variant (<=2 layers, d_model<=512,
<=4 experts) of the same family, per the assignment contract.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

__all__ = ["MoESpec", "MLASpec", "MambaSpec", "RWKVSpec", "ArchConfig",
           "register", "get_config", "list_configs"]


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0          # shared experts (DeepSeek style), as a dense MLP
    capacity_factor: float = 1.25
    every: int = 1             # MoE ffn every `every` layers (jamba: 2)
    first_dense: int = 0       # leading layers with dense FFN (dsv2/kimi: 1)


@dataclasses.dataclass(frozen=True)
class MLASpec:
    kv_lora: int = 512
    q_lora: int = 0
    qk_nope: int = 128
    qk_rope: int = 64
    v_head: int = 128


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class RWKVSpec:
    head_dim: int = 64
    decay_lora: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0          # 0 -> d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    act: str = "swiglu"
    norm: str = "rmsnorm"      # rmsnorm | layernorm (whisper)
    pos: str = "rope"          # rope | sinusoidal | none
    moe: Optional[MoESpec] = None
    mla: Optional[MLASpec] = None
    mamba: Optional[MambaSpec] = None
    rwkv: Optional[RWKVSpec] = None
    attn_every: int = 1        # attention mixer every N layers (jamba: 8); 0 = attn-free
    attn_offset: int = 0       # which index within the period is attention (jamba: 4)
    enc_layers: int = 0        # whisper encoder depth (enc-dec if > 0)
    enc_seq: int = 1500        # encoder frame count (post-conv stub)
    n_patches: int = 0         # vlm: image patch embeddings prepended
    sliding_window: int = 8192  # window used for the long_500k decode variant
    dtype: str = "bfloat16"
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def mixer_of(self, i: int) -> str:
        if self.rwkv is not None:
            return "rwkv"
        if self.mla is not None:
            return "mla"
        if self.attn_every == 0:
            raise ValueError("attn-free arch must set rwkv/mamba")
        if self.mamba is not None:
            return "attn" if i % self.attn_every == self.attn_offset else "mamba"
        return "attn"

    def ffn_of(self, i: int) -> str:
        if self.rwkv is not None:
            return "rwkv_cm"
        if self.moe is not None and i >= self.moe.first_dense and \
                (i % self.moe.every == self.moe.every - 1 or self.moe.every == 1):
            return "moe"
        return "dense"

    def layer_specs(self) -> list[tuple[str, str]]:
        return [(self.mixer_of(i), self.ffn_of(i)) for i in range(self.n_layers)]

    def stack_plan(self) -> tuple[int, int]:
        """(prefix_len, period): layers[prefix:] is periodic with `period`."""
        specs = self.layer_specs()
        n = len(specs)
        for prefix in range(0, min(3, n)):
            body = specs[prefix:]
            if not body:
                continue
            for period in range(1, min(len(body), 16) + 1):
                if len(body) % period == 0 and all(
                        body[i] == body[i % period] for i in range(len(body))):
                    return prefix, period
        return n, 1  # fully unrolled fallback

    def reduced(self) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        hd = 64 if self.rwkv is None else 32
        heads = 4
        kv = min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else heads
        d_model = heads * hd
        changes: dict = dict(
            n_layers=2, d_model=d_model, n_heads=heads, n_kv_heads=kv,
            head_dim=hd, d_ff=4 * d_model, vocab=min(self.vocab, 512),
            enc_layers=min(self.enc_layers, 2), enc_seq=min(self.enc_seq, 32),
            n_patches=min(self.n_patches, 8), sliding_window=16,
            dtype="float32",
        )
        if self.moe:
            changes["moe"] = dataclasses.replace(
                self.moe, n_experts=4, top_k=2, d_ff_expert=2 * d_model,
                n_shared=min(self.moe.n_shared, 1),
                first_dense=min(self.moe.first_dense, 1))
        if self.mla:
            changes["mla"] = MLASpec(kv_lora=32, q_lora=16 if self.mla.q_lora else 0,
                                     qk_nope=hd // 2, qk_rope=hd // 4, v_head=hd // 2)
        if self.mamba:
            changes["mamba"] = MambaSpec(d_state=8, d_conv=4, expand=2)
            changes["attn_every"] = 2  # 2 layers: one mamba, one attention
            changes["attn_offset"] = 1
        if self.rwkv:
            changes["rwkv"] = RWKVSpec(head_dim=hd, decay_lora=16)
        return dataclasses.replace(self, **changes)


_REGISTRY: dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro import configs as _  # ensure all config modules imported
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from repro import configs as _
    return sorted(_REGISTRY)
