"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS for 512 placeholder
devices before any jax import; tests and benchmarks see the single real CPU
device).
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "make_abstract_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e production mesh: 16x16 = 256 chips/pod; 2 pods = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh (reduced integration tests use e.g. (2, 2))."""
    return jax.make_mesh(shape, axes)


def make_abstract_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Device-free AbstractMesh, across JAX signature changes.

    Older JAX (≤ 0.4.x) takes ``AbstractMesh(((name, size), ...))``; newer
    JAX takes ``AbstractMesh(axis_sizes, axis_names)``.  Passing the new
    calling convention to the old constructor dies with
    ``TypeError: 'int' object is not iterable`` — this helper accepts the
    new-style ``(shape, axes)`` pair and dispatches to whichever the
    installed JAX understands.
    """
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axes)  # JAX >= 0.5 signature
    except TypeError:
        return AbstractMesh(tuple(zip(axes, shape)))
