"""Step builders: jitted train / prefill / decode steps with explicit
in/out shardings for a given (arch, shape, mesh).

Used by the dry-run (lower+compile on placeholder meshes), by the real
trainer (single-device or small meshes on CPU), and by the roofline
analyzer.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.specs import input_specs
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import (
    batch_sharding,
    cache_sharding,
    make_param_shardings,
    opt_state_shardings,
)

__all__ = ["BuiltStep", "build_step"]


class BuiltStep(NamedTuple):
    kind: str
    jitted: Any          # jax.jit'd step fn
    args: tuple          # ShapeDtypeStruct args matching the jitted signature
    model: Model
    param_shardings: Any


def build_step(cfg: ArchConfig, mesh, shape_name: str, *,
               opt: AdamWConfig | None = None, remat: bool = True,
               attn_chunk: int = 512, donate: bool = True,
               unroll: bool = True, seq_shard_kv: bool = False,
               moe_groups: int | None = None,
               mamba_chunk: int | None = None) -> BuiltStep:
    # unroll=True (dry-run default): python-loop layer blocks so
    # cost_analysis counts all layers (XLA counts while bodies once).
    if moe_groups is None:
        moe_groups = 1
    model = Model(cfg, remat=remat, attn_chunk=attn_chunk, unroll=unroll,
                  moe_groups=moe_groups, mamba_chunk=mamba_chunk)
    kind, specs = input_specs(cfg, shape_name, model)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = make_param_shardings(cfg, params_shapes, mesh)
    rep = NamedSharding(mesh, P())
    opt = opt or AdamWConfig()

    if kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        o_sh = opt_state_shardings(cfg, params_shapes, mesh)
        b_sh = batch_sharding(cfg, mesh, specs["batch"])

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            new_params, new_opt = adamw_update(params, grads, opt_state, opt)
            return new_params, new_opt, loss

        jitted = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, rep),
            donate_argnums=(0, 1) if donate else (),
        )
        return BuiltStep(kind, jitted, (params_shapes, opt_shapes, specs["batch"]),
                         model, p_sh)

    if kind == "prefill":
        b_sh = batch_sharding(cfg, mesh, specs["batch"])
        c_sh = cache_sharding(cfg, mesh, specs["cache"])

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(rep, c_sh),
            donate_argnums=(2,) if donate else (),
        )
        return BuiltStep(kind, jitted, (params_shapes, specs["batch"],
                                        specs["cache"]), model, p_sh)

    # decode
    c_sh = cache_sharding(cfg, mesh, specs["cache"], seq_shard_kv=seq_shard_kv)
    t_sh = batch_sharding(cfg, mesh, {"token": specs["token"]})["token"]

    def decode_step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    jitted = jax.jit(
        decode_step,
        in_shardings=(p_sh, t_sh, rep, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(3,) if donate else (),
    )
    return BuiltStep(kind, jitted, (params_shapes, specs["token"], specs["pos"],
                                    specs["cache"]), model, p_sh)
