"""Step builders: jitted train / prefill / decode steps with explicit
in/out shardings for a given (arch, shape, mesh), plus the paper-workload
coded-GD step (:func:`build_coded_gd_step`).

Used by the dry-runs (lower+compile on placeholder meshes), by the real
trainer (single-device or small meshes on CPU), and by the roofline
analyzer.  No step builder carries its own decode implementation: the
coded-GD step composes the shared :mod:`repro.core.decoder` fixed-D loops
and the :mod:`repro.core.engine` epilogue, so the decode math exists in
exactly one place.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core.decoder import peel_fixed_dense, peel_fixed_sparse
from repro.core.engine import blocked_epilogue
from repro.launch.specs import input_specs
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import (
    batch_sharding,
    cache_sharding,
    make_param_shardings,
    opt_state_shardings,
)

__all__ = ["BuiltStep", "build_step", "build_coded_gd_step",
           "build_pipeline_fold_step"]


class BuiltStep(NamedTuple):
    kind: str
    jitted: Any          # jax.jit'd step fn
    args: tuple          # ShapeDtypeStruct args matching the jitted signature
    model: Model
    param_shardings: Any


def build_step(cfg: ArchConfig, mesh, shape_name: str, *,
               opt: AdamWConfig | None = None, remat: bool = True,
               attn_chunk: int = 512, donate: bool = True,
               unroll: bool = True, seq_shard_kv: bool = False,
               moe_groups: int | None = None,
               mamba_chunk: int | None = None) -> BuiltStep:
    # unroll=True (dry-run default): python-loop layer blocks so
    # cost_analysis counts all layers (XLA counts while bodies once).
    if moe_groups is None:
        moe_groups = 1
    model = Model(cfg, remat=remat, attn_chunk=attn_chunk, unroll=unroll,
                  moe_groups=moe_groups, mamba_chunk=mamba_chunk)
    kind, specs = input_specs(cfg, shape_name, model)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = make_param_shardings(cfg, params_shapes, mesh)
    rep = NamedSharding(mesh, P())
    opt = opt or AdamWConfig()

    if kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        o_sh = opt_state_shardings(cfg, params_shapes, mesh)
        b_sh = batch_sharding(cfg, mesh, specs["batch"])

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            new_params, new_opt = adamw_update(params, grads, opt_state, opt)
            return new_params, new_opt, loss

        jitted = jax.jit(
            train_step,
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, rep),
            donate_argnums=(0, 1) if donate else (),
        )
        return BuiltStep(kind, jitted, (params_shapes, opt_shapes, specs["batch"]),
                         model, p_sh)

    if kind == "prefill":
        b_sh = batch_sharding(cfg, mesh, specs["batch"])
        c_sh = cache_sharding(cfg, mesh, specs["cache"])

        def prefill_step(params, batch, cache):
            return model.prefill(params, batch, cache)

        jitted = jax.jit(
            prefill_step,
            in_shardings=(p_sh, b_sh, c_sh),
            out_shardings=(rep, c_sh),
            donate_argnums=(2,) if donate else (),
        )
        return BuiltStep(kind, jitted, (params_shapes, specs["batch"],
                                        specs["cache"]), model, p_sh)

    # decode
    c_sh = cache_sharding(cfg, mesh, specs["cache"], seq_shard_kv=seq_shard_kv)
    t_sh = batch_sharding(cfg, mesh, {"token": specs["token"]})["token"]

    def decode_step(params, token, pos, cache):
        return model.decode_step(params, token, pos, cache)

    jitted = jax.jit(
        decode_step,
        in_shardings=(p_sh, t_sh, rep, c_sh),
        out_shardings=(None, c_sh),
        donate_argnums=(3,) if donate else (),
    )
    return BuiltStep(kind, jitted, (params_shapes, specs["token"], specs["pos"],
                                    specs["cache"]), model, p_sh)


# --------------------------------------------------- paper coded-GD step --


def build_coded_gd_step(k: int, K: int, decode_iters: int, dtype,
                        mesh, *, decode: str = "dense", r: int = 6,
                        bp: int | None = None,
                        vmem_budget_bytes: int | None = None,
                        seed: int | None = None,
                        seeded_mode: str = "dense_tile"):
    """Functional Scheme2Blocked step at scale, with explicit shardings.

    Shapes: N = 2K (rate-1/2), nb = k/K blocks, p = N - K checks.
    C_blocks (nb, N, k) sharded (None, model, data);
    theta/b (k,) replicated.

    The step is pure composition of the shared engine stages — worker
    matvec, a :mod:`repro.core.decoder` fixed-D loop
    (:func:`peel_fixed_dense` / :func:`peel_fixed_sparse`, whose operands
    are plain shardable arrays), and the engine's
    :func:`repro.core.engine.blocked_epilogue` — there is no launch-local
    decode implementation.

    decode variants (the §Perf hillclimb):
      dense       — paper-faithful baseline: H and its boolean mask Hb are
                    two dense (p, N) operands per round (3 passes over H).
      dense-fused — Hb computed on the fly from H (one dense operand/round).
      sparse      — H stored as (p, r) neighbour indices + edge values
                    (the Tanner graph IS r-regular): decode rounds become
                    gathers/scatters, no dense (p, N) traffic at all.
      pallas      — the fused one-kernel decode: the whole fixed-D loop
                    inside a single kernel.  The variant is chosen by the
                    VMEM estimate (``repro.core.decoder.vmem_bytes_estimate``
                    against ``vmem_budget_bytes``): H resident in VMEM
                    (:func:`repro.kernels.ldpc_peel.peel_decode_pallas`)
                    while the working set fits, else the check-axis-TILED
                    kernel (``peel_decode_tiled_pallas``: H stays in HBM
                    and streams ``bp`` check rows at a time), which is what
                    production-size N lowers to.  H is REPLICATED per chip
                    either way (the kernel shards the payload axis, not H),
                    so its roofline trades collective traffic for per-chip
                    H bandwidth; off-TPU the kernel lowers via interpret
                    mode, so compile works everywhere but the HLO op mix is
                    the emulated kernel, not Mosaic.

    ``seed`` (pallas only) switches the decode to the SEEDED kernel
    (``peel_decode_seeded_pallas``): the step takes NO H operand at all —
    each ``bp × N`` check tile is regenerated from ``(seed, row)`` inside
    the kernel — so the step lowers and compiles at N where even
    materializing the (p, N) parity-check matrix would exceed host memory.
    The seeded ensemble is the (4, 8)-regular layered-permutation one
    (``repro.core.ldpc.seeded_structure``), which the rate-1/2 shape here
    (p = K, N = 2K) satisfies for any K divisible by 4.  ``seeded_mode``
    picks the round kernel: ``"dense_tile"`` regenerates dense ``bp × N``
    H tiles per round, ``"gather"`` generates only the r (column, weight)
    pairs per check row (edge-proportional FLOPs), ``"auto"`` resolves via
    the :mod:`repro.core.hwcaps` FLOPs crossover — erasure trajectories
    are bit-identical across all of them.

    Returns ``(jitted_step, arg_specs)`` ready for AOT lower/compile.
    """
    N, p, nb = 2 * K, K, k // K
    dax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dspec = dax if len(dax) > 1 else dax[0]
    sh = lambda *spec: NamedSharding(mesh, P(*spec))

    def update(vals, erased, theta, b, lr):
        g, _ = blocked_epilogue(vals, erased, b, K=K, nb=nb)
        return theta - lr * g

    def worker_products(C_blocks, theta, mask):
        z = jnp.einsum("bnk,k->nb", C_blocks, theta.astype(C_blocks.dtype))
        return jnp.where(mask[:, None], 0.0, z.astype(jnp.float32))  # (N, nb)

    c_spec = jax.ShapeDtypeStruct((nb, N, k), dtype)
    common = (
        jax.ShapeDtypeStruct((k,), jnp.float32),          # theta
        jax.ShapeDtypeStruct((k,), jnp.float32),          # b
        jax.ShapeDtypeStruct((N,), jnp.bool_),            # mask
        jax.ShapeDtypeStruct((), jnp.float32),            # lr
    )
    common_sh = (sh(), sh(), sh(), sh())

    if decode == "dense":
        # paper-faithful: Hb is a SECOND materialized dense operand
        def step_dense(C_blocks, H, Hb, theta, b, mask, lr):
            z = worker_products(C_blocks, theta, mask)
            # Hb is streamed as a SECOND dense f32 operand (that is the
            # point of this paper-faithful variant); the decoder's round
            # wants it boolean.
            vals, erased = peel_fixed_dense(H, Hb != 0.0, z, mask,
                                            decode_iters)
            return update(vals, erased, theta, b, lr)

        args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32),
                jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
        in_sh = (sh(None, "model", dspec), sh("model", None),
                 sh("model", None), *common_sh)
        return jax.jit(step_dense, in_shardings=in_sh,
                       out_shardings=sh()), args

    if decode == "dense-fused":
        def step_fused(C_blocks, H, theta, b, mask, lr):
            z = worker_products(C_blocks, theta, mask)
            vals, erased = peel_fixed_dense(H, H != 0.0, z, mask,
                                            decode_iters)
            return update(vals, erased, theta, b, lr)

        args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
        in_sh = (sh(None, "model", dspec), sh("model", None), *common_sh)
        return jax.jit(step_fused, in_shardings=in_sh,
                       out_shardings=sh()), args

    if seed is not None and decode != "pallas":
        raise ValueError("seed= (the seeded on-the-fly H decode) requires "
                         f"decode='pallas'; got {decode!r}")

    if decode == "pallas":
        from repro.core.decoder import pick_tile_bp, vmem_bytes_estimate
        from repro.core.decoder import _DEFAULT_VMEM_BUDGET_BYTES
        from repro.kernels.ldpc_peel import (peel_decode_pallas,
                                             peel_decode_seeded_pallas,
                                             peel_decode_tiled_pallas)

        if seed is not None:
            # Seeded on-the-fly H: no (p, N) operand anywhere in the step.
            from repro.core.decoder import _resolve_seeded_mode
            from repro.core.ldpc import seeded_structure
            spec = seeded_structure(p, N, 8, seed)
            bp_seeded = bp if bp is not None else 128
            mode = _resolve_seeded_mode(seeded_mode, spec, nb, bp_seeded)

            def step_seeded(C_blocks, theta, b, mask, lr):
                z = worker_products(C_blocks, theta, mask)
                vals, erased = peel_decode_seeded_pallas(
                    spec, z, mask, decode_iters, bp=bp_seeded, bv=8,
                    mode=mode)
                return update(vals, erased, theta, b, lr)

            args = (c_spec, *common)
            in_sh = (sh(None, "model", dspec), *common_sh)
            return jax.jit(step_seeded, in_shardings=in_sh,
                           out_shardings=sh()), args

        budget = vmem_budget_bytes or _DEFAULT_VMEM_BUDGET_BYTES
        tiled = vmem_bytes_estimate((p, N), bv=8) > budget
        if tiled and bp is None:
            bp = pick_tile_bp((p, N), vmem_budget_bytes=budget)

        def step_pallas(C_blocks, H, theta, b, mask, lr):
            z = worker_products(C_blocks, theta, mask)
            if tiled:   # production N: H streamed over check tiles from HBM
                vals, erased = peel_decode_tiled_pallas(
                    H, z, mask, decode_iters, bp=bp, bv=8)
            else:       # small N: whole H resident in VMEM
                vals, erased = peel_decode_pallas(H, z, mask, decode_iters,
                                                  bv=8)  # nb small; pad to 8
            return update(vals, erased, theta, b, lr)

        args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
        # H replicated either way: resident keeps it whole in VMEM, tiled
        # streams per-chip tiles out of the replicated HBM copy.
        in_sh = (sh(None, "model", dspec), sh(), *common_sh)
        return jax.jit(step_pallas, in_shardings=in_sh,
                       out_shardings=sh()), args

    if decode != "sparse":
        raise ValueError(f"unknown decode variant {decode!r}")

    # sparse decode: H as neighbour lists (p, r) — the Tanner graph is
    # r-regular, so this is exact, and removes ALL dense (p, N) traffic.
    def step_sparse(C_blocks, H_idx, H_val, theta, b, mask, lr):
        z = worker_products(C_blocks, theta, mask)
        vals, erased = peel_fixed_sparse(H_idx, H_val, z, mask, decode_iters)
        return update(vals, erased, theta, b, lr)

    args = (c_spec, jax.ShapeDtypeStruct((p, r), jnp.int32),
            jax.ShapeDtypeStruct((p, r), jnp.float32), *common)
    in_sh = (sh(None, "model", dspec), sh("model", None), sh("model", None),
             *common_sh)
    return jax.jit(step_sparse, in_shardings=in_sh, out_shardings=sh()), args


def build_pipeline_fold_step(k: int, K: int, decode_iters: int, dtype,
                             mesh, *, r: int = 6):
    """The pipelined runtime's LATE-FOLD program at production scale.

    When a straggler's partial products land within the fold window
    (:class:`repro.distributed.pipeline.AsyncDistributedCodedGD`), the
    master re-decodes the SOURCE step's stored survivor vector with the
    newly-landed rows restored and applies a staleness-weighted delta on
    exactly the coordinates the original decode left unresolved.  This
    builder is that program with explicit production shardings, composed
    from the same shared stages as :func:`build_coded_gd_step` (sparse
    neighbour-table decode rounds + the blocked epilogue):

      (H_idx, H_val, z, remaining_mask, u_old, b, w)
          → (delta, u_next)

    with ``delta = w · (ĉ′ − b)`` on ``newly = u_old ∧ ¬u′`` (zero
    elsewhere — already-applied coordinates cannot double-count) and
    ``u_next = u_old ∧ u′``.  The stored ``z`` and the carried masks are
    replicated (they live with the master); the neighbour tables shard
    their check rows over the mesh's first axis, so the builder serves
    both the sharded-tensor mesh ("model", "data") and the distributed
    runtime's ("workers", "data") layout.

    Returns ``(jitted_step, arg_specs)`` ready for AOT lower/compile.
    """
    N, p, nb = 2 * K, K, k // K
    axis = mesh.axis_names[0]
    sh = lambda *spec: NamedSharding(mesh, P(*spec))

    def fold_step(H_idx, H_val, z, remaining_mask, u_old, b, w):
        vals, erased = peel_fixed_sparse(H_idx, H_val,
                                         z.astype(jnp.float32),
                                         remaining_mask, decode_iters)
        g, u_new = blocked_epilogue(vals, erased, b, K=K, nb=nb)
        newly = u_old & ~u_new
        delta = jnp.where(newly, g, 0.0) * w
        return delta, u_old & u_new

    args = (
        jax.ShapeDtypeStruct((p, r), jnp.int32),      # H_idx
        jax.ShapeDtypeStruct((p, r), jnp.float32),    # H_val
        jax.ShapeDtypeStruct((N, nb), dtype),         # stored survivors
        jax.ShapeDtypeStruct((N,), jnp.bool_),        # remaining erasures
        jax.ShapeDtypeStruct((k,), jnp.bool_),        # unresolved carry
        jax.ShapeDtypeStruct((k,), jnp.float32),      # b
        jax.ShapeDtypeStruct((), jnp.float32),        # w(τ)
    )
    in_sh = (sh(axis, None), sh(axis, None), sh(), sh(), sh(), sh(), sh())
    return jax.jit(fold_step, in_shardings=in_sh,
                   out_shardings=(sh(), sh())), args
