import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (architecture x input-shape) on
the production meshes, with NO device allocation (ShapeDtypeStruct inputs).

  single-pod mesh: (16, 16)    -> ("data", "model")       256 chips
  multi-pod mesh : (2, 16, 16) -> ("pod", "data", "model") 512 chips

Per combination this prints compiled.memory_analysis() (fits?) and
cost_analysis() (FLOPs/bytes for the roofline), and writes a JSON artifact
under artifacts/dryrun/ that benchmarks/roofline.py consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all                 # 10 x 4 single-pod
  python -m repro.launch.dryrun --all --multi-pod     # + (2,16,16)
  python -m repro.launch.dryrun --arch ... --reduced  # tiny mesh smoke (2,2)
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax

from repro.configs import get_config, list_configs
from repro.launch.analysis import analyze_compiled, model_flops
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.specs import SHAPES
from repro.launch.steps import build_step

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

ASSIGNED = [
    "qwen3-1.7b", "codeqwen1.5-7b", "jamba-1.5-large-398b", "whisper-medium",
    "minitron-8b", "deepseek-v2-236b", "kimi-k2-1t-a32b", "qwen2-1.5b",
    "internvl2-2b", "rwkv6-3b",
]


def _lower_compile(cfg, mesh, shape, *, remat, attn_chunk, unroll, **step_kw):
    t0 = time.time()
    built = build_step(cfg, mesh, shape, remat=remat, attn_chunk=attn_chunk,
                       unroll=unroll, **step_kw)
    lowered = built.jitted.lower(*built.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    return built, compiled, t_lower, time.time() - t0


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            reduced: bool = False, remat: bool = True, attn_chunk: int = 512,
            verbose: bool = True, save: bool = True, variant: str = "",
            **step_kw) -> dict:
    import dataclasses
    from repro.launch.analysis import collective_bytes

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
        mesh = make_mesh((2, 2), ("data", "model"))
        mesh_desc = "2x2"
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
        mesh_desc = "2x16x16" if multi_pod else "16x16"
    chips = mesh.devices.size

    kind = SHAPES[shape].kind
    # Fully-unrolled 60-72-layer MoE/Mamba modules take XLA:CPU >1h to
    # compile.  For those, do the PROOF compile with the scan form (fast,
    # exact memory_analysis), and extrapolate per-layer flops/bytes/
    # collectives from 1-block and 2-block unrolled compiles — all numbers
    # still come from compiled artifacts (documented in EXPERIMENTS.md).
    heavy = (cfg.moe is not None or cfg.mamba is not None) and not reduced \
        and kind in ("train", "prefill")

    if not heavy:
        built, compiled, t_lower, t_compile = _lower_compile(
            cfg, mesh, shape, remat=remat, attn_chunk=attn_chunk, unroll=True,
            **step_kw)
        extrapolated = False
    else:
        built, compiled, t_lower, t_compile = _lower_compile(
            cfg, mesh, shape, remat=remat, attn_chunk=attn_chunk, unroll=False,
            **step_kw)
        extrapolated = True

    mflops = model_flops(cfg, built.model, built.args[0], built.kind,
                         SHAPES[shape].batch if not reduced else 2,
                         SHAPES[shape].seq if not reduced else 32)
    rep = analyze_compiled(compiled, arch=arch, shape=shape, mesh_desc=mesh_desc,
                           chips=chips, mflops=mflops)

    if heavy:
        # sub-model compiles: prefix + 1 block vs prefix + 2 blocks, unrolled
        pl_, per = built.model.prefix_len, built.model.period
        nb = built.model.n_blocks
        sub = {}
        for blocks in (1, 2):
            cfg_s = dataclasses.replace(cfg, n_layers=pl_ + blocks * per)
            _, comp_s, _, _ = _lower_compile(cfg_s, mesh, shape, remat=remat,
                                             attn_chunk=attn_chunk, unroll=True,
                                             **step_kw)
            cost = comp_s.cost_analysis()
            if isinstance(cost, list):
                cost = cost[0] if cost else {}
            sub[blocks] = {
                "flops": float(cost.get("flops", 0.0)),
                "bytes": float(cost.get("bytes accessed", 0.0)),
                "coll": collective_bytes(comp_s.as_text())["total"],
            }
        def extr(k):
            return sub[1][k] + (nb - 1) * (sub[2][k] - sub[1][k])
        from repro.launch.analysis import HW
        rep.hlo_gflops = extr("flops") / 1e9
        rep.hlo_gbytes = extr("bytes") / 1e9
        rep.coll_gbytes_local = extr("coll") / 1e9
        rep.compute_s = extr("flops") / HW["peak_flops"]
        rep.memory_s = extr("bytes") / HW["hbm_bw"]
        rep.collective_s = extr("coll") / HW["ici_bw"]
        g = extr("flops") * chips
        rep.useful_ratio = mflops / g if g else 0.0

    if verbose:
        print(f"== {arch} x {shape} on {mesh_desc} ({chips} chips) ==")
        print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
        try:
            print("   memory_analysis:", compiled.memory_analysis())
        except Exception as e:  # CPU backend may not implement it
            print("   memory_analysis: <unavailable>", e)
        print("   cost_analysis: flops=%.3e bytes=%.3e" %
              (rep.hlo_gflops * 1e9, rep.hlo_gbytes * 1e9))
        print(f"   collectives: {rep.coll_counts}")
        print(f"   roofline: compute={rep.compute_s*1e3:.3f}ms "
              f"memory={rep.memory_s*1e3:.3f}ms "
              f"collective={rep.collective_s*1e3:.3f}ms -> {rep.dominant}-bound")

    result = {
        "arch": arch, "shape": shape, "mesh": mesh_desc, "chips": chips,
        "ok": True, "extrapolated": extrapolated, "variant": variant,
        "lower_s": t_lower, "compile_s": t_compile,
        "hlo_gflops": rep.hlo_gflops, "hlo_gbytes": rep.hlo_gbytes,
        "coll_gbytes_local": rep.coll_gbytes_local,
        "coll_counts": rep.coll_counts,
        "compute_s": rep.compute_s, "memory_s": rep.memory_s,
        "collective_s": rep.collective_s, "dominant": rep.dominant,
        "model_gflops": rep.model_gflops, "useful_ratio": rep.useful_ratio,
        "bytes_per_device": rep.bytes_per_device,
    }
    if save:
        ARTIFACTS.mkdir(parents=True, exist_ok=True)
        tag = f"+{variant}" if variant else ""
        out = ARTIFACTS / f"{arch}__{shape}{tag}__{mesh_desc.replace('x', '_')}.json"
        out.write_text(json.dumps(result, indent=2))
    return result


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, choices=list_configs() + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true", help="all assigned archs x shapes")
    ap.add_argument("--archs", default=None,
                    help="comma-separated arch subset (with --all semantics)")
    ap.add_argument("--reduced", action="store_true", help="tiny mesh (2,2) smoke")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--continue-on-error", action="store_true")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip pairs whose artifact JSON already exists")
    ap.add_argument("--variant", default="", help="artifact tag for A/B runs")
    ap.add_argument("--seq-shard-kv", action="store_true",
                    help="§Perf H1: shard decode KV caches over seq dim when "
                         "KV heads don't divide the model axis")
    ap.add_argument("--moe-groups", type=int, default=None,
                    help="§Perf H2: data-aligned MoE routing groups")
    ap.add_argument("--mamba-chunk", type=int, default=None,
                    help="chunked parallel-in-time SSM prefill (assoc scan)")
    args = ap.parse_args(argv)

    pairs = []
    if args.all or args.archs:
        archs = args.archs.split(",") if args.archs else ASSIGNED
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                pairs.append((a, s))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all/--archs")
        pairs = [(args.arch, args.shape)]

    if args.skip_existing:
        mesh_desc = ("2x16x16" if args.multi_pod else "16x16").replace("x", "_")
        def exists(a, s):
            return (ARTIFACTS / f"{a}__{s}__{mesh_desc}.json").exists()
        pairs = [(a, s) for a, s in pairs if not exists(a, s)]

    failures = []
    for a, s in pairs:
        try:
            run_one(a, s, multi_pod=args.multi_pod, reduced=args.reduced,
                    remat=not args.no_remat, variant=args.variant,
                    seq_shard_kv=args.seq_shard_kv,
                    moe_groups=args.moe_groups,
                    mamba_chunk=args.mamba_chunk)
        except Exception as e:
            failures.append((a, s, repr(e)))
            print(f"!! FAILED {a} x {s}: {e}")
            traceback.print_exc()
            if not args.continue_on_error:
                sys.exit(1)
    if failures:
        print(f"{len(failures)} failures: {failures}")
        sys.exit(1)
    print(f"dry-run OK: {len(pairs)} combination(s)")


if __name__ == "__main__":
    main()
