"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e hardware constants (the TARGET; the runtime here is CPU):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

Terms (per step, per chip — the HLO after SPMD partitioning is the
per-device program, so compiled.as_text() shapes are LOCAL):

  compute_s    = HLO_FLOPs / (chips x peak)     [cost_analysis is global]
  memory_s     = HLO_bytes / (chips x HBM_bw)
  collective_s = collective_bytes_local / link_bw

collective_bytes is not in cost_analysis: we parse the partitioned HLO and
sum the result-shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute, with an x2 factor for all-reduce (ring
AR = RS + AG).  This is a standard first-order traffic model, documented in
EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

import numpy as np

__all__ = ["HW", "RooflineReport", "collective_bytes", "analyze_compiled",
           "model_flops"]

HW = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # B/s per chip
    "ici_bw": 50e9,         # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(?([a-z0-9]+)\[([\d,]*)\][^)]*\)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_FACTORS = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Sum modeled collective traffic (bytes) per op kind from HLO text."""
    out: dict[str, float] = {k: 0.0 for k in _FACTORS}
    out["count"] = 0
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.search(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(?:-start|-done)?\(", line)
        if not m or not line.startswith("%") and " = " not in line:
            continue
        kind = m.group(1)
        if "-done(" in line:
            continue  # count the -start, not the -done
        lhs = line.split(" = ", 1)
        if len(lhs) != 2:
            continue
        shapes = _SHAPE_RE.findall(lhs[1].split("(", 1)[0])
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        out[kind] += nbytes * _FACTORS[kind]
        out["count"] += 1
    out["total"] = sum(out[k] for k in _FACTORS)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_gflops: float          # global, from cost_analysis
    hlo_gbytes: float          # global bytes accessed
    coll_gbytes_local: float   # per-chip collective traffic (modeled)
    coll_counts: dict
    compute_s: float
    memory_s: float
    collective_s: float
    model_gflops: float        # 6*N_active*D (train) / 2*N_active*B (decode)
    useful_ratio: float        # model_flops / hlo_flops
    bytes_per_device: dict     # memory_analysis fields (may be {})

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s * 1e3:.3f} | {self.memory_s * 1e3:.3f} | "
                f"{self.collective_s * 1e3:.3f} | {self.dominant} | "
                f"{self.useful_ratio:.2f} |")


def model_flops(cfg, model, params_shapes, kind: str, batch: int, seq: int) -> float:
    """MODEL_FLOPS: 6*N*D for training (N = active params), 2*N per decoded
    token; prefill = 2*N*D forward-only."""
    n_active = 0
    import jax
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        sz = int(np.prod(leaf.shape))
        if "experts" in keys and cfg.moe:
            sz = sz * cfg.moe.top_k // cfg.moe.n_experts
        if "embed" in keys or "unembed" in keys:
            continue  # lookups aren't matmul flops (unembed added below)
        n_active += sz
    unembed = cfg.vocab * cfg.d_model
    tokens = batch * (seq if kind in ("train", "prefill") else 1)
    per_tok = 2 * (n_active + unembed)
    mult = 3.0 if kind == "train" else 1.0  # fwd + 2x bwd
    return mult * per_tok * tokens


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_desc: str,
                     chips: int, mflops: float) -> RooflineReport:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # some backends return [dict]
        cost = cost[0] if cost else {}
    # NOTE (verified empirically): compiled.cost_analysis() reports the
    # PER-DEVICE partitioned module — flops(8 devices) == flops(1)/8.
    hlo_flops = float(cost.get("flops", 0.0))          # per chip
    hlo_bytes = float(cost.get("bytes accessed", 0.0))  # per chip
    coll = collective_bytes(compiled.as_text())
    mem = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for f in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes"):
                v = getattr(ma, f, None)
                if v is not None:
                    mem[f] = int(v)
    except Exception:
        pass
    compute_s = hlo_flops / HW["peak_flops"]
    memory_s = hlo_bytes / HW["hbm_bw"]
    collective_s = coll["total"] / HW["ici_bw"]
    global_flops = hlo_flops * chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_desc, chips=chips,
        hlo_gflops=hlo_flops / 1e9, hlo_gbytes=hlo_bytes / 1e9,
        coll_gbytes_local=coll["total"] / 1e9,
        coll_counts={k: v for k, v in coll.items() if k != "total"},
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_gflops=mflops / 1e9,
        useful_ratio=(mflops / global_flops) if global_flops else 0.0,
        bytes_per_device=mem,
    )
