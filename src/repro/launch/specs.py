"""Input specs for the dry-run: ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, NO device allocation).

The four assigned input shapes:

  train_4k     seq=4,096    global_batch=256   (training)
  prefill_32k  seq=32,768   global_batch=32    (inference prefill)
  decode_32k   seq=32,768   global_batch=128   (inference decode: ONE new
                                                token + a seq-length cache)
  long_500k    seq=524,288  global_batch=1     (long-context decode)

long_500k policy (DESIGN.md §shape/skip): attention mixers use the
sliding-window ring cache (cfg.sliding_window); MLA keeps the FULL latent
cache (576 B/token makes 500k affordable — that's the MLA selling point);
Mamba/RWKV state is O(1) regardless.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import Model


class ShapeSpec(NamedTuple):
    kind: str  # train | prefill | decode
    seq: int
    batch: int
    window: int | None = None  # decode-time attention window override


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode", 32_768, 128),
    "long_500k": ShapeSpec("decode", 524_288, 1, window=None),  # window from cfg
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def batch_specs(cfg: ArchConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs mirroring repro.data.batches.make_batch."""
    dt = cfg.jdtype
    if cfg.family == "vlm":
        return {
            "tokens": _sds((batch, seq - cfg.n_patches), jnp.int32),
            "patches": _sds((batch, cfg.n_patches, cfg.d_model), dt),
        }
    if cfg.family == "audio":
        return {
            "tokens": _sds((batch, seq), jnp.int32),
            "labels": _sds((batch, seq), jnp.int32),
            "frames": _sds((batch, cfg.enc_seq, cfg.d_model), dt),
        }
    return {
        "tokens": _sds((batch, seq), jnp.int32),
        "labels": _sds((batch, seq), jnp.int32),
    }


def decode_window(cfg: ArchConfig, shape: ShapeSpec) -> int | None:
    """Attention-cache window for a decode shape (None = full seq)."""
    if shape.seq <= 40_000:
        return None  # decode_32k: full cache
    if cfg.mla is not None:
        return None  # MLA latent cache is cheap at 500k — keep it full
    # long_500k with plain attention mixers: sliding window variant
    return cfg.sliding_window


def cache_specs(cfg: ArchConfig, model: Model, batch: int, seq: int,
                window: int | None) -> Any:
    return jax.eval_shape(
        lambda: model.init_cache(batch, seq, window=window))


def input_specs(cfg: ArchConfig, shape_name: str, model: Model | None = None):
    """Returns (kind, kwargs_dict_of_ShapeDtypeStructs) for the step fn."""
    shape = SHAPES[shape_name]
    model = model or Model(cfg)
    if shape.kind == "train":
        return shape.kind, {"batch": batch_specs(cfg, shape.batch, shape.seq)}
    if shape.kind == "prefill":
        cache = cache_specs(cfg, model, shape.batch, shape.seq, None)
        return shape.kind, {"batch": batch_specs(cfg, shape.batch, shape.seq),
                            "cache": cache}
    # decode: one token at position seq-1, with a seq-length (or windowed) cache
    window = decode_window(cfg, shape)
    cache = cache_specs(cfg, model, shape.batch, shape.seq, window)
    return shape.kind, {
        "token": _sds((shape.batch, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
        "cache": cache,
    }
