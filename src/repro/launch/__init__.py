# Launcher package. NOTE: dryrun.py must be executed as a script/module
# (python -m repro.launch.dryrun) so its XLA_FLAGS lines run before jax init.
