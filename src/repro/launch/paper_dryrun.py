import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import (same contract as dryrun.py).
"""Production-scale dry-run of the PAPER'S OWN workload: LDPC moment-encoded
PGD (Scheme 2, blocked) on the (16,16) / (2,16,16) meshes.

This is the "most representative of the paper's technique" §Perf pair: a
k-feature linear model whose encoded moment C = G·M is sharded over the
mesh (rows → "model", feature columns → "data"), worker products are the
sharded matvec z = Cθ, and the master-side peeling decode runs as D
unrolled flooding rounds over a sharded parity-check matrix.

  python -m repro.launch.paper_dryrun --k 32768 --multi-pod
  python -m repro.launch.paper_dryrun --k 32768 --dtype bf16 --decode-iters 4

Writes artifacts/dryrun/paper-coded-gd__scheme2-k<k>-D<D>-<dtype>__<mesh>.json
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.launch.analysis import HW, analyze_compiled
from repro.launch.mesh import make_production_mesh

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def build_coded_gd_step(k: int, K: int, decode_iters: int, dtype,
                        mesh, *, decode: str = "dense", r: int = 6):
    """Functional Scheme2Blocked step at scale, with explicit shardings.

    Shapes: N = 2K (rate-1/2), nb = k/K blocks, p = N - K checks.
    C_blocks (nb, N, k) sharded (None, model, data);
    theta/b (k,) replicated.

    decode variants (the §Perf hillclimb):
      dense       — paper-faithful baseline: H and its boolean mask Hb are
                    two dense (p, N) operands per round (3 passes over H).
      dense-fused — Hb computed on the fly from H (one dense operand/round).
      sparse      — H stored as (p, r) neighbour indices + edge values
                    (the Tanner graph IS r-regular): decode rounds become
                    gathers/scatters, no dense (p, N) traffic at all.
    """
    N, p, nb = 2 * K, K, k // K
    dax = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    dspec = dax if len(dax) > 1 else dax[0]
    sh = lambda *spec: NamedSharding(mesh, P(*spec))

    def epilogue(vals, erased, theta, b, lr):
        unresolved = erased[:K]                           # same for all blocks
        c_hat = jnp.where(unresolved[:, None], 0.0, vals[:K])  # (K, nb)
        c_flat = c_hat.T.reshape(-1)                      # (k,)
        b_hat = jnp.where(jnp.tile(unresolved, nb), 0.0, b)
        return theta - lr * (c_flat - b_hat)

    def worker_products(C_blocks, theta, mask):
        z = jnp.einsum("bnk,k->nb", C_blocks, theta.astype(C_blocks.dtype))
        return jnp.where(mask[:, None], 0.0, z.astype(jnp.float32))  # (N, nb)

    c_spec = jax.ShapeDtypeStruct((nb, N, k), dtype)
    common = (
        jax.ShapeDtypeStruct((k,), jnp.float32),          # theta
        jax.ShapeDtypeStruct((k,), jnp.float32),          # b
        jax.ShapeDtypeStruct((N,), jnp.bool_),            # mask
        jax.ShapeDtypeStruct((), jnp.float32),            # lr
    )
    common_sh = (sh(), sh(), sh(), sh())

    if decode in ("dense", "dense-fused"):
        def step(C_blocks, H, theta, b, mask, lr):
            z = worker_products(C_blocks, theta, mask)
            erased, vals = mask, z
            Hb = (H != 0.0).astype(jnp.float32)
            for _ in range(decode_iters):
                e = erased.astype(jnp.float32)
                cnt = Hb @ e
                known = vals * (1.0 - e)[:, None]
                sums = H @ known
                idx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), H.shape)
                emask = (Hb > 0) & (e[None, :] > 0)
                pos = jnp.max(jnp.where(emask, idx, -1), axis=1)
                coeff = jnp.sum(H * (idx == pos[:, None]), axis=1)
                solvable = cnt == 1.0
                new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
                safe = jnp.where(solvable, pos, N)
                vals = vals.at[safe].set(new_val, mode="drop")
                erased = erased.at[safe].set(False, mode="drop")
            return epilogue(vals, erased, theta, b, lr)

        if decode == "dense":
            # paper-faithful: Hb is a SECOND materialized dense operand
            def step_dense(C_blocks, H, Hb_in, theta, b, mask, lr):
                z = worker_products(C_blocks, theta, mask)
                erased, vals = mask, z
                for _ in range(decode_iters):
                    e = erased.astype(jnp.float32)
                    cnt = Hb_in @ e
                    known = vals * (1.0 - e)[:, None]
                    sums = H @ known
                    idx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32),
                                           H.shape)
                    emask = (Hb_in > 0) & (e[None, :] > 0)
                    pos = jnp.max(jnp.where(emask, idx, -1), axis=1)
                    coeff = jnp.sum(H * (idx == pos[:, None]), axis=1)
                    solvable = cnt == 1.0
                    new_val = -sums / jnp.where(coeff == 0.0, 1.0,
                                                coeff)[:, None]
                    safe = jnp.where(solvable, pos, N)
                    vals = vals.at[safe].set(new_val, mode="drop")
                    erased = erased.at[safe].set(False, mode="drop")
                return epilogue(vals, erased, theta, b, lr)

            args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32),
                    jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
            in_sh = (sh(None, "model", dspec), sh("model", None),
                     sh("model", None), *common_sh)
            return jax.jit(step_dense, in_shardings=in_sh,
                           out_shardings=sh()), args

        args = (c_spec, jax.ShapeDtypeStruct((p, N), jnp.float32), *common)
        in_sh = (sh(None, "model", dspec), sh("model", None), *common_sh)
        return jax.jit(step, in_shardings=in_sh, out_shardings=sh()), args

    # sparse decode: H as neighbour lists (p, r) — the Tanner graph is
    # r-regular, so this is exact, and removes ALL dense (p, N) traffic.
    def step_sparse(C_blocks, H_idx, H_val, theta, b, mask, lr):
        z = worker_products(C_blocks, theta, mask)
        erased, vals = mask, z
        for _ in range(decode_iters):
            e = erased.astype(jnp.float32)
            neigh_e = e[H_idx]                            # (p, r)
            cnt = neigh_e.sum(axis=1)
            neigh_v = vals[H_idx]                         # (p, r, nb)
            known = neigh_v * (1.0 - neigh_e)[:, :, None]
            sums = jnp.einsum("prb,pr->pb", known, H_val)
            slot = jnp.argmax(neigh_e, axis=1)            # (p,)
            pos = jnp.take_along_axis(H_idx, slot[:, None], 1)[:, 0]
            coeff = jnp.take_along_axis(H_val, slot[:, None], 1)[:, 0]
            solvable = cnt == 1.0
            new_val = -sums / jnp.where(coeff == 0.0, 1.0, coeff)[:, None]
            safe = jnp.where(solvable, pos, N)
            vals = vals.at[safe].set(new_val, mode="drop")
            erased = erased.at[safe].set(False, mode="drop")
        return epilogue(vals, erased, theta, b, lr)

    args = (c_spec, jax.ShapeDtypeStruct((p, r), jnp.int32),
            jax.ShapeDtypeStruct((p, r), jnp.float32), *common)
    in_sh = (sh(None, "model", dspec), sh("model", None), sh("model", None),
             *common_sh)
    return jax.jit(step_sparse, in_shardings=in_sh, out_shardings=sh()), args


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=32768)
    ap.add_argument("--K", type=int, default=16384)
    ap.add_argument("--decode-iters", type=int, default=8)
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--decode", default="dense",
                    choices=["dense", "dense-fused", "sparse"])
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    mesh_desc = "2x16x16" if args.multi_pod else "16x16"
    dtype = jnp.bfloat16 if args.dtype == "bf16" else jnp.float32

    t0 = time.time()
    jitted, specs = build_coded_gd_step(args.k, args.K, args.decode_iters,
                                        dtype, mesh, decode=args.decode)
    lowered = jitted.lower(*specs)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # MODEL_FLOPS for this workload: the useful work is z = Cθ (2·N·k·nb)
    # plus the decode matmuls (2·p·N·nb per round).
    N, p, nb = 2 * args.K, args.K, args.k // args.K
    mflops = 2 * N * args.k * nb + args.decode_iters * 2 * p * N * nb
    shape_tag = (f"scheme2-k{args.k}-D{args.decode_iters}-{args.dtype}"
                 f"-{args.decode}")
    rep = analyze_compiled(compiled, arch="paper-coded-gd", shape=shape_tag,
                           mesh_desc=mesh_desc, chips=mesh.devices.size,
                           mflops=float(mflops))
    print(f"== paper-coded-gd {shape_tag} on {mesh_desc} ==")
    print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
    try:
        print("   memory_analysis:", compiled.memory_analysis())
    except Exception as e:
        print("   memory_analysis unavailable:", e)
    print("   cost_analysis: flops=%.3e bytes=%.3e (per chip)" %
          (rep.hlo_gflops * 1e9, rep.hlo_gbytes * 1e9))
    print(f"   collectives: {rep.coll_counts}")
    print(f"   roofline: compute={rep.compute_s*1e3:.3f}ms "
          f"memory={rep.memory_s*1e3:.3f}ms "
          f"collective={rep.collective_s*1e3:.3f}ms -> {rep.dominant}-bound")
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out = {
        "arch": "paper-coded-gd", "shape": shape_tag, "mesh": mesh_desc,
        "chips": mesh.devices.size, "ok": True, "extrapolated": False,
        "lower_s": t_lower, "compile_s": t_compile,
        "hlo_gflops": rep.hlo_gflops, "hlo_gbytes": rep.hlo_gbytes,
        "coll_gbytes_local": rep.coll_gbytes_local,
        "coll_counts": rep.coll_counts, "compute_s": rep.compute_s,
        "memory_s": rep.memory_s, "collective_s": rep.collective_s,
        "dominant": rep.dominant, "model_gflops": rep.model_gflops,
        "useful_ratio": rep.useful_ratio,
    }
    (ARTIFACTS / f"paper-coded-gd__{shape_tag}__{mesh_desc.replace('x','_')}.json"
     ).write_text(json.dumps(out, indent=2))


if __name__ == "__main__":
    main()
