import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import (same contract as dryrun.py).
"""Production-scale dry-run of the PAPER'S OWN workload: LDPC moment-encoded
PGD (Scheme 2, blocked) on the (16,16) / (2,16,16) meshes.

This is the "most representative of the paper's technique" §Perf pair: a
k-feature linear model whose encoded moment C = G·M is sharded over the
mesh (rows → "model", feature columns → "data"), worker products are the
sharded matvec z = Cθ, and the master-side peeling decode runs as D
flooding rounds over a sharded parity-check matrix.

This launcher is a thin client: the step itself is built by
``repro.launch.steps.build_coded_gd_step``, which composes the SHARED
engine stages (``repro.core.decoder`` fixed-D loops +
``repro.core.engine.blocked_epilogue``) — the decode variants measured here
are exactly the backends the rest of the codebase runs, not launcher-local
copies.

  python -m repro.launch.paper_dryrun --k 32768 --multi-pod
  python -m repro.launch.paper_dryrun --k 32768 --dtype bf16 --decode-iters 4

``--distributed`` switches to the sharded coded-WORKER runtime's step
(:func:`repro.distributed.master.build_distributed_gd_step`): the mesh
becomes an explicit ``("workers", "data")`` 16x16 layout, the worker
matvec is a ``shard_map`` over the workers axis (θ sharded over "data",
one psum), the straggler mask is per-WORKER, and the master decode runs on
the gathered survivors — the AOT roofline then reports the real
master/worker collective mix instead of an undifferentiated sharded step.

  python -m repro.launch.paper_dryrun --k 32768 --distributed --decode sparse

``--seeded`` (with ``--decode pallas``) swaps the decode for the SEEDED
kernel: the step carries NO (p, N) parity-check operand — each check tile
is regenerated from ``(seed, row)`` inside the kernel — so the dry-run
lowers and compiles at K where even materializing H would exceed host
memory (e.g. ``--k 131072 --K 131072``: H alone would be 128 GiB f32).

  python -m repro.launch.paper_dryrun --k 131072 --K 131072 \\
      --decode pallas --seeded

``--seeded-mode`` picks the seeded round kernel: ``dense_tile``
regenerates dense check tiles, ``gather`` generates only the r (column,
weight) pairs per check row (edge-proportional FLOPs — the artifact gains
a ``-gather`` suffix), ``auto`` resolves via the
:mod:`repro.core.hwcaps` crossover.

  python -m repro.launch.paper_dryrun --k 131072 --K 131072 \\
      --decode pallas --seeded --seeded-mode gather

``--pipeline`` additionally lowers and analyzes the pipelined runtime's
LATE-FOLD program (:func:`repro.launch.steps.build_pipeline_fold_step`):
the sparse re-decode of a stored survivor vector plus the
staleness-weighted delta on newly-resolved coordinates.  It runs on the
same mesh as the main step — including the ``--distributed``
("workers", "data") layout — so the roofline shows what the fold path
adds to the master's budget at production scale.

  python -m repro.launch.paper_dryrun --k 32768 --distributed \\
      --decode sparse --pipeline

Writes artifacts/dryrun/paper-coded-gd__scheme2-k<k>-D<D>-<dtype>__<mesh>.json
(and a ``...-fold`` sibling with ``--pipeline``)
"""
import argparse
import json
import time
from pathlib import Path

import jax.numpy as jnp

from repro.launch.analysis import analyze_compiled
from repro.launch.mesh import make_mesh, make_production_mesh
from repro.launch.steps import build_coded_gd_step
from repro.obs import ObsSession, metrics as _obs_metrics
from repro.obs.trace import span as _span

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _record_aot(shape_tag: str, out: dict) -> None:
    reg = _obs_metrics.active()
    if reg is None:
        return
    reg.gauge("aot.lower_s", shape=shape_tag).set(out["lower_s"])
    reg.gauge("aot.compile_s", shape=shape_tag).set(out["compile_s"])
    reg.info("aot.report", out, shape=shape_tag)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--k", type=int, default=32768)
    ap.add_argument("--K", type=int, default=16384)
    ap.add_argument("--decode-iters", type=int, default=8)
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--decode", default="dense",
                    choices=["dense", "dense-fused", "sparse", "pallas"])
    ap.add_argument("--seeded", action="store_true",
                    help="seeded on-the-fly H decode (pallas only): no "
                         "(p, N) parity-check operand; compiles at K where "
                         "materializing H would exceed host memory")
    ap.add_argument("--seeded-mode", default="dense_tile",
                    choices=["auto", "dense_tile", "gather"],
                    help="seeded round kernel: dense regenerated tiles, "
                         "edge-proportional gather/segment-sum, or the "
                         "hwcaps FLOPs-crossover auto dispatch")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="master/worker runtime step: explicit "
                         "(workers, data) mesh, shard_map worker matvec, "
                         "per-worker straggler mask (decode: dense|sparse)")
    ap.add_argument("--pipeline", action="store_true",
                    help="also lower+analyze the pipelined runtime's "
                         "late-fold program (sparse re-decode + weighted "
                         "delta) on the same mesh")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="export obs metrics JSONL (+ .trace.json with "
                         "aot/lower and aot/compile spans) to PATH")
    args = ap.parse_args(argv)
    session = ObsSession.start(args.obs_out)

    dtype = jnp.bfloat16 if args.dtype == "bf16" else jnp.float32

    if args.seeded and args.decode != "pallas":
        raise SystemExit("--seeded requires --decode pallas (the seeded "
                         "on-the-fly H generation is a Pallas kernel)")

    t0 = time.time()
    if args.distributed:
        if args.seeded:
            raise SystemExit("--seeded is for the sharded-tensor step; "
                             "drop --distributed")
        if args.multi_pod:
            raise SystemExit("--distributed is single-pod only (16x16 "
                             "workers x data); drop --multi-pod")
        if args.decode not in ("dense", "sparse"):
            raise SystemExit(f"--distributed supports --decode dense|sparse "
                             f"(the master decode is single-program; got "
                             f"{args.decode!r})")
        from repro.distributed.master import build_distributed_gd_step

        mesh = make_mesh((16, 16), ("workers", "data"))
        mesh_desc = "16wx16d"
        jitted, specs = build_distributed_gd_step(
            args.k, args.K, args.decode_iters, dtype, mesh,
            decode=args.decode)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)
        mesh_desc = "2x16x16" if args.multi_pod else "16x16"
        jitted, specs = build_coded_gd_step(args.k, args.K, args.decode_iters,
                                            dtype, mesh, decode=args.decode,
                                            seed=0 if args.seeded else None,
                                            seeded_mode=args.seeded_mode)
    with _span("aot/lower", lane="aot"):
        lowered = jitted.lower(*specs)
    t_lower = time.time() - t0
    t0 = time.time()
    with _span("aot/compile", lane="aot"):
        compiled = lowered.compile()
    t_compile = time.time() - t0

    # MODEL_FLOPS for this workload: the useful work is z = Cθ (2·N·k·nb)
    # plus the decode matmuls (2·p·N·nb per round).
    N, p, nb = 2 * args.K, args.K, args.k // args.K
    mflops = 2 * N * args.k * nb + args.decode_iters * 2 * p * N * nb
    shape_tag = (f"scheme2-k{args.k}-D{args.decode_iters}-{args.dtype}"
                 f"-{args.decode}" + ("-seeded" if args.seeded else "")
                 + ("-gather" if args.seeded
                    and args.seeded_mode == "gather" else "")
                 + ("-dist" if args.distributed else ""))
    rep = analyze_compiled(compiled, arch="paper-coded-gd", shape=shape_tag,
                           mesh_desc=mesh_desc, chips=mesh.devices.size,
                           mflops=float(mflops))
    print(f"== paper-coded-gd {shape_tag} on {mesh_desc} ==")
    print(f"   lower {t_lower:.1f}s compile {t_compile:.1f}s")
    try:
        print("   memory_analysis:", compiled.memory_analysis())
    except Exception as e:
        print("   memory_analysis unavailable:", e)
    print("   cost_analysis: flops=%.3e bytes=%.3e (per chip)" %
          (rep.hlo_gflops * 1e9, rep.hlo_gbytes * 1e9))
    print(f"   collectives: {rep.coll_counts}")
    print(f"   roofline: compute={rep.compute_s*1e3:.3f}ms "
          f"memory={rep.memory_s*1e3:.3f}ms "
          f"collective={rep.collective_s*1e3:.3f}ms -> {rep.dominant}-bound")
    ARTIFACTS.mkdir(parents=True, exist_ok=True)
    out = {
        "arch": "paper-coded-gd", "shape": shape_tag, "mesh": mesh_desc,
        "chips": mesh.devices.size, "ok": True, "extrapolated": False,
        "lower_s": t_lower, "compile_s": t_compile,
        "hlo_gflops": rep.hlo_gflops, "hlo_gbytes": rep.hlo_gbytes,
        "coll_gbytes_local": rep.coll_gbytes_local,
        "coll_counts": rep.coll_counts, "compute_s": rep.compute_s,
        "memory_s": rep.memory_s, "collective_s": rep.collective_s,
        "dominant": rep.dominant, "model_gflops": rep.model_gflops,
        "useful_ratio": rep.useful_ratio,
    }
    (ARTIFACTS / f"paper-coded-gd__{shape_tag}__{mesh_desc.replace('x','_')}.json"
     ).write_text(json.dumps(out, indent=2))
    _record_aot(shape_tag, out)

    if args.pipeline:
        from repro.launch.steps import build_pipeline_fold_step

        t0 = time.time()
        fold_jitted, fold_specs = build_pipeline_fold_step(
            args.k, args.K, args.decode_iters, dtype, mesh)
        with _span("aot/lower", lane="aot", shape="fold"):
            fold_lowered = fold_jitted.lower(*fold_specs)
        tf_lower = time.time() - t0
        t0 = time.time()
        with _span("aot/compile", lane="aot", shape="fold"):
            fold_compiled = fold_lowered.compile()
        tf_compile = time.time() - t0
        # useful work of a fold: the decode matmuls only (no worker matvec)
        fold_mflops = args.decode_iters * 2 * p * N * nb
        fold_tag = shape_tag + "-fold"
        frep = analyze_compiled(fold_compiled, arch="paper-coded-gd",
                                shape=fold_tag, mesh_desc=mesh_desc,
                                chips=mesh.devices.size,
                                mflops=float(fold_mflops))
        print(f"== paper-coded-gd {fold_tag} on {mesh_desc} ==")
        print(f"   lower {tf_lower:.1f}s compile {tf_compile:.1f}s")
        print("   cost_analysis: flops=%.3e bytes=%.3e (per chip)" %
              (frep.hlo_gflops * 1e9, frep.hlo_gbytes * 1e9))
        print(f"   collectives: {frep.coll_counts}")
        print(f"   roofline: compute={frep.compute_s*1e3:.3f}ms "
              f"memory={frep.memory_s*1e3:.3f}ms "
              f"collective={frep.collective_s*1e3:.3f}ms -> "
              f"{frep.dominant}-bound")
        fold_out = {
            "arch": "paper-coded-gd", "shape": fold_tag, "mesh": mesh_desc,
            "chips": mesh.devices.size, "ok": True, "extrapolated": False,
            "lower_s": tf_lower, "compile_s": tf_compile,
            "hlo_gflops": frep.hlo_gflops, "hlo_gbytes": frep.hlo_gbytes,
            "coll_gbytes_local": frep.coll_gbytes_local,
            "coll_counts": frep.coll_counts, "compute_s": frep.compute_s,
            "memory_s": frep.memory_s, "collective_s": frep.collective_s,
            "dominant": frep.dominant, "model_gflops": frep.model_gflops,
            "useful_ratio": frep.useful_ratio,
        }
        (ARTIFACTS / f"paper-coded-gd__{fold_tag}__"
         f"{mesh_desc.replace('x', '_')}.json"
         ).write_text(json.dumps(fold_out, indent=2))
        _record_aot(fold_tag, fold_out)

    session.finish()


if __name__ == "__main__":
    main()
