"""Training launcher.

CPU-runnable driver over the architecture zoo (reduced or scaled dims) with
optional LDPC-coded gradient aggregation — the paper's technique as a
first-class training feature.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --coded-agg --straggler-q0 0.1

The full production configs are exercised via launch/dryrun.py (AOT
lower+compile on the placeholder meshes); this driver runs REAL steps.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro.configs import get_config, list_configs
from repro.data.batches import make_batch
from repro.models import Model
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def batch_iterator(cfg, batch, seq, seed=0):
    key = jax.random.PRNGKey(seed)
    while True:
        key, k = jax.random.split(key)
        yield make_batch(cfg, batch, seq, key=k)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--coded-agg", action="store_true")
    ap.add_argument("--n-shards", type=int, default=8)
    ap.add_argument("--straggler-q0", type=float, default=0.0)
    ap.add_argument("--decode-iters", type=int, default=8)
    ap.add_argument("--decode-backend", default="auto",
                    choices=["auto", "dense", "sparse", "pallas", "pallas_tiled"],
                    help="LDPC decode implementation (see core/decoder.py)")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, remat=False, attn_chunk=min(64, args.seq))
    params = model.init(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={model.param_count(params):,} "
          f"active={model.active_param_count(params):,}")

    tcfg = TrainerConfig(
        steps=args.steps, log_every=args.log_every,
        ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
        opt=AdamWConfig(lr=args.lr),
        coded_agg=args.coded_agg, n_shards=args.n_shards,
        straggler_q0=args.straggler_q0, decode_iters=args.decode_iters,
        decode_backend=args.decode_backend,
    )
    trainer = Trainer(model, tcfg)
    batches = batch_iterator(cfg, args.batch, args.seq)
    params, _, history = trainer.fit(params, batches)
    print(f"final loss {history[-1]:.4f} (from {history[0]:.4f})")
    return history


if __name__ == "__main__":
    main()
