"""Serving launcher: batched prefill + decode demo on CPU (reduced configs).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, list_configs
from repro.data.batches import make_batch
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--window", type=int, default=0, help="sliding window (0=full)")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    model = Model(cfg, remat=False, attn_chunk=16)
    params = model.init(jax.random.PRNGKey(0))

    total = args.prompt_len + args.gen + (cfg.n_patches if cfg.family == "vlm" else 0)
    batch = make_batch(cfg, args.batch, args.prompt_len +
                       (cfg.n_patches if cfg.family == "vlm" else 0))
    cache = model.init_cache(args.batch, total,
                             window=args.window or None)
    t0 = time.time()
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    print(f"prefill({args.prompt_len} tok x {args.batch}): {time.time()-t0:.2f}s")

    offset = cfg.n_patches if cfg.family == "vlm" else 0
    pos0 = offset + batch["tokens"].shape[1]
    step = jax.jit(model.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    key = jax.random.PRNGKey(1)
    for i in range(args.gen - 1):
        logits, cache = step(params, tok, jnp.int32(pos0 + i), cache)
        if args.temperature > 0:
            key, k = jax.random.split(key)
            tok = jax.random.categorical(k, logits[:, -1] / args.temperature)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
        out_tokens.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"decoded {args.gen - 1} steps x {args.batch} seqs in {dt:.2f}s "
          f"({(args.gen - 1) * args.batch / max(dt, 1e-9):.1f} tok/s)")
    print("sample token ids:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
