"""Checkpointing: flat-key npz for tensors + msgpack sidecar for metadata.

No external checkpoint deps; works for any pytree of arrays (params,
optimizer state).  Keys are '/'-joined tree paths, so checkpoints are
stable across process restarts and inspectable with numpy alone.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint"]


def _flatten(tree) -> dict[str, np.ndarray]:
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                       for k in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str | Path, step: int, params: Any,
                    opt_state: Any = None, metadata: dict | None = None) -> Path:
    d = Path(directory)
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"ckpt_{step:08d}"
    np.savez(str(path) + ".params.npz", **_flatten(params))
    if opt_state is not None:
        np.savez(str(path) + ".opt.npz", **_flatten(opt_state))
    meta = {"step": step, **(metadata or {})}
    (d / f"ckpt_{step:08d}.meta.json").write_text(json.dumps(meta, indent=2))
    (d / "latest").write_text(str(step))
    return path


def _restore_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(template)[0]:
        key = "/".join(str(getattr(k, "key", getattr(k, "name", getattr(k, "idx", k))))
                       for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != template {leaf.shape}")
        leaves.append(jnp.asarray(arr, dtype=leaf.dtype))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(directory: str | Path, template_params: Any,
                    template_opt: Any = None, step: int | None = None):
    """Returns (step, params[, opt_state]) restored into the given templates."""
    d = Path(directory)
    if step is None:
        step = int((d / "latest").read_text())
    base = d / f"ckpt_{step:08d}"
    params = _restore_into(template_params,
                           dict(np.load(str(base) + ".params.npz")))
    if template_opt is not None:
        opt = _restore_into(template_opt, dict(np.load(str(base) + ".opt.npz")))
        return step, params, opt
    return step, params
