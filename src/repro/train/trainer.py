"""Training loop with optional LDPC-coded gradient aggregation.

Two gradient paths:
  * plain      — standard jit'd value_and_grad (the mesh's data axis
                 all-reduces gradients; on one CPU device this is just SGD).
  * coded_agg  — the paper's insight applied to ANY loss (grad_agg.py):
                 the batch is split into K shards, per-shard gradients are
                 the systematic symbols of an LDGM code, parity "workers"
                 hold small shard unions, a straggler mask erases worker
                 symbols, and the master peels for D rounds.  Unresolved
                 shards are zero-filled => unbiased (1-q_D)-scaled gradient
                 (Lemma 1 verbatim).

This is the runnable CPU-scale driver (examples/train_llm.py); the
production-mesh path is exercised by launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

from repro.core.grad_agg import CodedAggregator, flatten_grads
from repro.core.straggler import BernoulliStragglers
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.train.checkpoint import save_checkpoint

__all__ = ["TrainerConfig", "Trainer"]


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 0              # 0 = no checkpoints
    ckpt_dir: str = "checkpoints"
    opt: AdamWConfig = AdamWConfig()
    # coded aggregation
    coded_agg: bool = False
    n_shards: int = 8
    redundancy: float = 0.5
    row_weight: int = 4
    decode_iters: int = 8
    decode_backend: str = "auto"  # dense|sparse|pallas|pallas_tiled|auto (decoder.py)
    straggler_q0: float = 0.0


class Trainer:
    def __init__(self, model: Model, tcfg: TrainerConfig):
        self.model = model
        self.tcfg = tcfg
        self.agg = (CodedAggregator.build(
            tcfg.n_shards, redundancy=tcfg.redundancy,
            row_weight=tcfg.row_weight, decode_iters=tcfg.decode_iters,
            decode_backend=tcfg.decode_backend)
            if tcfg.coded_agg else None)
        self.straggler = BernoulliStragglers(tcfg.straggler_q0)
        self._step_fn = self._build_step()

    def _build_step(self):
        model, tcfg, agg = self.model, self.tcfg, self.agg

        if not tcfg.coded_agg:
            @jax.jit
            def step(params, opt_state, batch, key):
                loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
                params, opt_state = adamw_update(params, grads, opt_state, tcfg.opt)
                return params, opt_state, loss, jnp.int32(0)
            return step

        K = tcfg.n_shards

        @jax.jit
        def step(params, opt_state, batch, key):
            # shard the batch leaves along the batch dim into K micro-shards
            def shard(leaf):
                B = leaf.shape[0]
                if B % K != 0:
                    raise ValueError(
                        f"batch size {B} not divisible by n_shards={K}")
                return leaf.reshape(K, B // K, *leaf.shape[1:])
            sharded = jax.tree.map(shard, batch)

            def shard_loss(params, i):
                b = jax.tree.map(lambda l: l[i], sharded)
                return model.loss_fn(params, b)

            def shard_grad(i):
                g = jax.grad(shard_loss)(params, i)
                flat, _ = flatten_grads(g)
                return flat / K  # each shard contributes 1/K of the mean loss

            partials = jax.lax.map(shard_grad, jnp.arange(K))  # (K, dim)
            mask = self.straggler.sample(key, agg.n_workers)
            total, unresolved = agg.aggregate(partials, mask)
            # grads have exactly the params tree structure/shapes
            _, unflat = flatten_grads(params)
            grads = unflat(total)
            loss = model.loss_fn(params, batch)
            params, opt_state = adamw_update(params, grads, opt_state, tcfg.opt)
            return params, opt_state, loss, unresolved

        return step

    def fit(self, params, batches: Iterator[dict], *, key=None,
            callback: Callable[[int, float], None] | None = None):
        key = key if key is not None else jax.random.PRNGKey(0)
        opt_state = adamw_init(params)
        history = []
        t0 = time.time()
        for step_i in range(self.tcfg.steps):
            batch = next(batches)
            key, k1 = jax.random.split(key)
            params, opt_state, loss, unresolved = self._step_fn(
                params, opt_state, batch, k1)
            loss = float(loss)
            history.append(loss)
            if callback:
                callback(step_i, loss)
            if self.tcfg.log_every and step_i % self.tcfg.log_every == 0:
                dt = time.time() - t0
                print(f"step {step_i:5d}  loss {loss:8.4f}  "
                      f"unresolved {int(unresolved)}  ({dt:.1f}s)")
            if self.tcfg.ckpt_every and (step_i + 1) % self.tcfg.ckpt_every == 0:
                save_checkpoint(self.tcfg.ckpt_dir, step_i + 1, params, opt_state,
                                {"loss": loss})
        return params, opt_state, history
